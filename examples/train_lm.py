"""Train a small LM end-to-end with the full substrate: sharded train step,
checkpoints (+restart), CKM activation monitor, compressive data balancing.

    PYTHONPATH=src python examples/train_lm.py --arch llama3.2-1b --steps 200

Uses the reduced (smoke) config by default so a few hundred steps run on CPU;
pass --full-config on real hardware.  Kill it mid-run and re-invoke: it
resumes from the latest checkpoint and reproduces the uninterrupted loss
curve exactly (deterministic data = f(seed, step)).
"""

import argparse

import jax.numpy as jnp

from repro.configs.base import ShapeConfig, get_config, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.train.train_loop import LoopConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config else get_smoke_config(args.arch)
    shape = ShapeConfig("example", args.seq, args.batch, "train")
    mesh = make_local_mesh()
    loop = LoopConfig(
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        monitor_k=4,  # CKM activation monitor: 4 clusters of pooled hiddens
        balance_every=50,  # compressive mixture re-balancing
        log_every=10,
        dtype=jnp.float32,
    )
    out = run(cfg, shape, mesh, loop, DataConfig(seed=0, n_domains=4))
    mres = out["monitor_result"]
    print("\nactivation-space clusters (CKM from the streaming sketch):")
    print("  mixture weights:", [f"{w:.3f}" for w in mres.weights])
    print("  final loss:", out["history"][-1]["loss"])


if __name__ == "__main__":
    main()
