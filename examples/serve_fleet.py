"""Multi-tenant sketch serving: one stacked fleet, decode-on-demand.

    PYTHONPATH=src python examples/serve_fleet.py
    PYTHONPATH=src python examples/serve_fleet.py --devices 4 --shards 4

Runs a small fleet end-to-end: per-tenant operators from ~70 B specs, a
burst of interleaved ``(tenant, batch)`` requests folded through the
segment-scatter ingest, decode-on-demand with the (tenant, version) LRU,
and evict/restore of a cold tenant — then prints the service stats and the
bitwise-isolation check against a standalone per-tenant engine.

Sharding flags:

``--shards P`` splits the tenant axis over P devices (a contiguous block of
``tenants / P`` rows per device, ``FleetEngine(sharding="mesh")``); the
flush then shard-routes interleaved requests host-side and the run prints
per-shard request counts and update throughput.  On a machine without P
real accelerators, ``--devices N`` forces N XLA host-platform (CPU)
devices by setting ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
— this MUST happen before jax initialises, which is why this script parses
argv and sets the flag before importing jax.  Host devices share the
physical cores, so they demonstrate placement and routing, not wall-clock
speedup; real speedup needs real devices (see docs/scaling.md).
"""

import argparse
import os
import tempfile
import time


def parse_args():
    p = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    p.add_argument(
        "--tenants", type=int, default=64,
        help="fleet size T (default 64); must be divisible by --shards",
    )
    p.add_argument(
        "--shards", type=int, default=1,
        help="tenant shards P: contiguous T/P-row blocks, one per device",
    )
    p.add_argument(
        "--devices", type=int, default=0,
        help="force this many XLA host-platform devices (0 = leave the "
        "platform alone); must be >= --shards",
    )
    p.add_argument(
        "--requests", type=int, default=200,
        help="interleaved (tenant, batch) requests to serve (default 200)",
    )
    return p.parse_args()


ARGS = parse_args()
if ARGS.devices:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={ARGS.devices}"
    ).strip()

import jax  # noqa: E402  (after XLA_FLAGS — device count is set at init)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import CKMConfig, FleetEngine, fleet_specs  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.launch.specs import SketchJobSpec  # noqa: E402
from repro.serve.fleet_service import FleetService  # noqa: E402

K, FEAT = 3, 4
M = 10 * K * FEAT


def main():
    job = SketchJobSpec(
        n_tenants=ARGS.tenants, tenant_shards=ARGS.shards
    ).validate()
    # Each tenant is an independent clustering problem: its own frequency
    # operator (rebuilt from a ~70 B spec) over its own data distribution.
    specs = fleet_specs(
        jax.random.PRNGKey(0), job.n_tenants, "dense", M, FEAT, 1.0
    )
    engine = FleetEngine(specs, **job.fleet_kwargs())
    print(f"{engine} holding {engine.state_bytes() / 1024:.0f} KiB of state "
          f"on {len(jax.devices())} device(s)")

    decode_cfg = CKMConfig(k=K)  # decoder defaults to sketch_shift in-service
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc = FleetService(
            engine, decode_cfg, checkpoint_dir=ckpt_dir,
            **{**job.service_kwargs(), "decode_cache_entries": 16},
        )

        # A burst of interleaved requests: random tenants, each batch drawn
        # from that tenant's own mixture.
        rng = np.random.default_rng(7)
        shard_requests = np.zeros(engine.tenant_shards, np.int64)
        t_serve = time.perf_counter()
        points = 0
        for step in range(ARGS.requests):
            t = int(rng.integers(job.n_tenants))
            x, _, _ = synthetic.gaussian_mixture(
                jax.random.fold_in(jax.random.PRNGKey(t), step),
                256, k=K, n=FEAT, c=6.0, return_labels=True,
            )
            svc.submit(t, x)
            shard_requests[engine.owner_shard(t)] += 1
            points += x.shape[0]
            if step % 8 == 7:  # flush every few requests, async staging
                svc.flush(async_ingest=True)
        svc.flush()
        jax.block_until_ready(svc.state)
        serve_s = time.perf_counter() - t_serve
        print(f"served {ARGS.requests} requests ({points} points) in "
              f"{serve_s:.3f}s -> {points / serve_s:,.0f} points/s")
        if engine.tenant_shards > 1:
            for s in range(engine.tenant_shards):
                lo = s * engine.shard_rows
                print(f"  shard {s}: tenants [{lo}, "
                      f"{lo + engine.shard_rows}) | "
                      f"{int(shard_requests[s])} requests | "
                      f"{shard_requests[s] * 256 / serve_s:,.0f} points/s")

        # Decode-on-demand: only the tenants somebody asks about pay decode.
        hot = [0, 1, 2, 0, 1, 0]
        for t in hot:
            res = svc.decode(t)
            tag = "cache hit " if res.cached else "fresh decode"
            print(f"tenant {t}: {tag} v{res.version} "
                  f"cost={float(res.cost):.4f}")

        # Evict a cold tenant (state row + spec -> checkpoint, row reset);
        # the next touch restores it transparently and bitwise.
        cold = 3
        before = engine.tenant_state(svc.state, cold)
        svc.evict(cold)
        restored = svc.decode(cold)  # auto-restore, then decode
        after = engine.tenant_state(svc.state, cold)
        bitwise = all(
            bool(jnp.array_equal(a, b))
            for a, b in zip(before, after)
        )
        print(f"tenant {cold}: evicted -> restored bitwise={bitwise}, "
              f"decode cost={float(restored.cost):.4f}")

        s = svc.stats
        print(f"requests={s.requests} points={s.points} "
              f"flushes={s.flushes} decodes={s.decodes} "
              f"hit_rate={s.hit_rate:.2f} "
              f"evictions={s.evictions} restores={s.restores}")


if __name__ == "__main__":
    main()
