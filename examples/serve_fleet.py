"""Multi-tenant sketch serving: one stacked fleet, decode-on-demand.

    PYTHONPATH=src python examples/serve_fleet.py

Runs a small fleet end-to-end: per-tenant operators from ~70 B specs, a
burst of interleaved ``(tenant, batch)`` requests folded through the
segment-scatter ingest, decode-on-demand with the (tenant, version) LRU,
and evict/restore of a cold tenant — then prints the service stats and the
bitwise-isolation check against a standalone per-tenant engine.
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CKMConfig, FleetEngine, fleet_specs
from repro.data import synthetic
from repro.serve.fleet_service import FleetService

N_TENANTS = 64
K, FEAT = 3, 4
M = 10 * K * FEAT


def main():
    # Each tenant is an independent clustering problem: its own frequency
    # operator (rebuilt from a ~70 B spec) over its own data distribution.
    specs = fleet_specs(
        jax.random.PRNGKey(0), N_TENANTS, "dense", M, FEAT, 1.0
    )
    engine = FleetEngine(specs)
    print(f"{engine} holding {engine.state_bytes() / 1024:.0f} KiB of state")

    decode_cfg = CKMConfig(k=K)  # decoder defaults to sketch_shift in-service
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc = FleetService(
            engine, decode_cfg, decode_cache_entries=16,
            checkpoint_dir=ckpt_dir,
        )

        # A burst of interleaved requests: random tenants, each batch drawn
        # from that tenant's own mixture.
        rng = np.random.default_rng(7)
        for step in range(200):
            t = int(rng.integers(N_TENANTS))
            x, _, _ = synthetic.gaussian_mixture(
                jax.random.fold_in(jax.random.PRNGKey(t), step),
                256, k=K, n=FEAT, c=6.0, return_labels=True,
            )
            svc.submit(t, x)
            if step % 8 == 7:  # flush every few requests, async staging
                svc.flush(async_ingest=True)
        svc.flush()

        # Decode-on-demand: only the tenants somebody asks about pay decode.
        hot = [0, 1, 2, 0, 1, 0]
        for t in hot:
            res = svc.decode(t)
            tag = "cache hit " if res.cached else "fresh decode"
            print(f"tenant {t}: {tag} v{res.version} "
                  f"cost={float(res.cost):.4f}")

        # Evict a cold tenant (state row + spec -> checkpoint, row reset);
        # the next touch restores it transparently and bitwise.
        cold = 3
        before = engine.tenant_state(svc.state, cold)
        svc.evict(cold)
        restored = svc.decode(cold)  # auto-restore, then decode
        after = engine.tenant_state(svc.state, cold)
        bitwise = all(
            bool(jnp.array_equal(a, b))
            for a, b in zip(before, after)
        )
        print(f"tenant {cold}: evicted -> restored bitwise={bitwise}, "
              f"decode cost={float(restored.cost):.4f}")

        s = svc.stats
        print(f"requests={s.requests} points={s.points} "
              f"flushes={s.flushes} decodes={s.decodes} "
              f"hit_rate={s.hit_rate:.2f} "
              f"evictions={s.evictions} restores={s.restores}")


if __name__ == "__main__":
    main()
