"""Quickstart: compressive K-means in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import ckm, lloyd
from repro.data import synthetic

key = jax.random.PRNGKey(0)
k_data, k_ckm, k_km = jax.random.split(key, 3)

# 50k points from 8 separated Gaussian clusters in R^6.
x, labels, means = synthetic.gaussian_mixture(
    k_data, 50_000, k=8, n=6, c=4.0, return_labels=True
)

# Compressive K-means: sketch once (one pass, m = 10*K*n numbers), then
# decode centroids from the sketch alone — the data could now be discarded.
cfg = ckm.CKMConfig(k=8)
result = ckm.fit(k_ckm, x, cfg)
print(f"sketch size m = {cfg.sketch_size(6)} (vs {x.size} dataset scalars)")
print(f"CKM    SSE/N = {float(ckm.sse(x, result.centroids)) / x.shape[0]:.4f}")

# Baseline: Lloyd-Max with 5 replicates (needs the full dataset every pass).
base = lloyd.kmeans(k_km, x, lloyd.LloydConfig(k=8, replicates=5, init="kpp"))
print(f"Lloyd5 SSE/N = {float(base.sse) / x.shape[0]:.4f}")
print(f"mixture weights alpha: {[f'{w:.3f}' for w in result.weights]}")
