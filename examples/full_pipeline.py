"""End-to-end driver (the paper's kind of workload): cluster a large dataset
through the full distributed pipeline.

    PYTHONPATH=src python examples/full_pipeline.py [--n 1000000]
                                                    [--backend sharded|xla|pallas]
                                                    [--decoder clompr|sketch_shift|amp]
                                                    [--topology allreduce|tree|ring]
                                                    [--ingest sync|async]
                                                    [--freq-op dense|structured]

Stages (all from the library, nothing bespoke):
1. 8 placeholder devices, (4 data x 2 model) mesh;
2. the dataset is sketched in ONE pass through the unified SketchEngine —
   backend is a flag: "sharded" (shard_map + psum-merge over the data axis,
   O(m) cross-device traffic), "xla" (chunked scan) or "pallas" (fused
   kernel; interpret mode off-TPU);
3. a registered decoder ("clompr", "sketch_shift" or "amp", the --decoder
   flag) decodes K centroids from the sketch alone;
4. a second, *streaming* CKM fit consumes the same data as a chunked
   iterator (fit_streaming) — out-of-core one-pass path;
5. Lloyd-Max x5 runs on the gathered data as the reference;
6. wall-clock + quality comparison (paper Fig. 4 protocol, container scale).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (
    BACKENDS,
    CKMConfig,
    available_decoders,
    available_freq_ops,
    decode_sketch,
    fit_streaming,
    sse,
)
from repro.core import available_topologies, ckm, freq_ops, lloyd
from repro.data import pipeline as pipe
from repro.data import synthetic
from repro.launch.specs import SketchJobSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--backend", choices=BACKENDS, default="sharded")
    ap.add_argument("--decoder", choices=available_decoders(), default="clompr",
                    help="sketch decoder (core.decoders registry): clompr = "
                         "paper Algorithm 1; sketch_shift = mean shift on the "
                         "sketched characteristic function; amp = CL-AMP "
                         "joint message passing (accurate at small m; pair "
                         "with --replicates-style restarts via CKMConfig)")
    ap.add_argument("--stream-chunk", type=int, default=0,
                    help="also run the one-pass streaming fit at this chunk "
                         "size (0 = skip)")
    ap.add_argument("--quantize", default="none",
                    help="universal sketch quantization (QCKM): none | 1bit "
                         "| <b>bit — integer accumulators, cheaper merges")
    ap.add_argument("--topology", choices=available_topologies(),
                    default="allreduce",
                    help="cross-device merge schedule of the sharded backend "
                         "(core.topology registry); same sketch either way, "
                         "different wire cost — see docs/scaling.md")
    ap.add_argument("--ingest", choices=("sync", "async"), default="sync",
                    help="streaming-fit ingest mode: async overlaps batch "
                         "production with sketch compute (core.ingest)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="async ingest queue depth (2 = double buffering)")
    ap.add_argument("--freq-op", choices=available_freq_ops(), default="dense",
                    help="frequency operator (core.freq_ops registry): dense "
                         "= the paper's materialized matrix; structured = "
                         "stacked fast-transform blocks (O(m·sqrt(d)) "
                         "projections, O(1) spec on the wire)")
    args = ap.parse_args()
    job = SketchJobSpec(
        backend=args.backend, reduce_topology=args.topology,
        ingest=args.ingest, ingest_prefetch=args.prefetch,
        sketch_quantization=args.quantize, freq_op=args.freq_op,
        decoder=args.decoder,
    ).validate()

    key = jax.random.PRNGKey(0)
    kd, kf, kdec, kl = jax.random.split(key, 4)
    x, labels, means = synthetic.gaussian_mixture(
        kd, args.n, args.k, args.dim, return_labels=True
    )

    cfg = CKMConfig(k=args.k, **job.ckm_overrides())
    m = cfg.sketch_size(args.dim)
    from repro.core import frequencies as fq
    from repro.core import quantize as qz

    sigma2 = fq.estimate_sigma2(kf, x[:2048])
    freqs = freq_ops.make_operator(args.freq_op, kf, m, args.dim, sigma2)

    mesh = None
    xin = x
    if args.backend == "sharded":
        mesh = jax.make_mesh((4, 2), ("data", "model"))
    quantizer = ckm.make_quantizer(kf, cfg, m)
    engine = ckm.make_engine(freqs, cfg, mesh, quantizer)
    if args.backend == "sharded":
        xin = engine.shard_points(x)

    t0 = time.perf_counter()
    z, lo, hi = engine.sketch(xin)
    jax.block_until_ready(z)
    t_sketch = time.perf_counter() - t0
    bits = qz.parse_bits(args.quantize)
    wire = qz.state_wire_bytes(m, args.n, bits)
    print(
        f"[1] sketch ({job.describe()}): {t_sketch:.2f}s  (m={m}, one pass, "
        f"merge wire bytes/state={wire}, operator leaves="
        f"{freqs.state_bytes()}B vs spec={freq_ops.spec_wire_bytes(freqs.spec())}B)"
    )

    t0 = time.perf_counter()
    cents, alphas, cost = decode_sketch(kdec, z, freqs, lo, hi, cfg)
    jax.block_until_ready(cents)
    t_decode = time.perf_counter() - t0
    sse_ckm = float(sse(x, cents)) / args.n
    print(
        f"[2] {args.decoder} decode (sketch only): {t_decode:.2f}s  "
        f"SSE/N={sse_ckm:.4f}"
    )

    if args.stream_chunk > 0:
        t0 = time.perf_counter()
        res = fit_streaming(
            key, pipe.chunked(x, args.stream_chunk), cfg, mesh
        )
        jax.block_until_ready(res.centroids)
        t_stream = time.perf_counter() - t0
        print(
            f"[2b] streaming fit ({args.stream_chunk}-pt chunks): "
            f"{t_stream:.2f}s  SSE/N={float(sse(x, res.centroids))/args.n:.4f}"
        )

    t0 = time.perf_counter()
    base = lloyd.kmeans(
        kl, x, lloyd.LloydConfig(k=args.k, replicates=5, init="range")
    )
    jax.block_until_ready(base.centroids)
    t_km = time.perf_counter() - t0
    print(f"[3] Lloyd-Max x5 (full data): {t_km:.2f}s  SSE/N={float(base.sse)/args.n:.4f}")
    print(
        f"[4] relative SSE {sse_ckm * args.n / float(base.sse):.3f}; "
        f"decode speedup vs kmeans x5: {t_km / t_decode:.1f}x; "
        f"memory {args.n * args.dim * 4 / (2*m+args.dim*m)/4:.0f}x smaller working set"
    )


if __name__ == "__main__":
    main()
