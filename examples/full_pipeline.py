"""End-to-end driver (the paper's kind of workload): cluster a large dataset
through the full distributed pipeline.

    PYTHONPATH=src python examples/full_pipeline.py [--n 1000000]

Stages (all from the library, nothing bespoke):
1. 8 placeholder devices, (4 data x 2 model) mesh;
2. the dataset is sharded over the data axis and sketched with ONE
   psum-merged pass (core.distributed_sketch) — O(m) cross-device traffic;
3. CLOMPR decodes K centroids from the sketch alone;
4. Lloyd-Max x5 runs on the gathered data as the reference;
5. wall-clock + quality comparison (paper Fig. 4 protocol, container scale).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import ckm, distributed_sketch as ds, lloyd
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--dim", type=int, default=10)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    kd, kf, kdec, kl = jax.random.split(key, 4)
    x, labels, means = synthetic.gaussian_mixture(
        kd, args.n, args.k, args.dim, return_labels=True
    )

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    xs = ds.shard_points(x, mesh, ("data",))

    cfg = ckm.CKMConfig(k=args.k)
    m = cfg.sketch_size(args.dim)
    from repro.core import frequencies as fq

    sigma2 = fq.estimate_sigma2(kf, x[:2048])
    freqs = fq.draw_frequencies(kf, m, args.dim, sigma2)

    t0 = time.perf_counter()
    z, lo, hi = ds.sharded_sketch(xs, freqs, mesh, ("data",))
    jax.block_until_ready(z)
    t_sketch = time.perf_counter() - t0
    print(f"[1] distributed sketch: {t_sketch:.2f}s  (m={m}, one pass, psum-merged)")

    t0 = time.perf_counter()
    cents, alphas, cost = ckm.decode_sketch(kdec, z, freqs, lo, hi, cfg)
    jax.block_until_ready(cents)
    t_decode = time.perf_counter() - t0
    sse_ckm = float(ckm.sse(x, cents)) / args.n
    print(f"[2] CKM decode (sketch only): {t_decode:.2f}s  SSE/N={sse_ckm:.4f}")

    t0 = time.perf_counter()
    base = lloyd.kmeans(
        kl, x, lloyd.LloydConfig(k=args.k, replicates=5, init="range")
    )
    jax.block_until_ready(base.centroids)
    t_km = time.perf_counter() - t0
    print(f"[3] Lloyd-Max x5 (full data): {t_km:.2f}s  SSE/N={float(base.sse)/args.n:.4f}")
    print(
        f"[4] relative SSE {sse_ckm * args.n / float(base.sse):.3f}; "
        f"decode speedup vs kmeans x5: {t_km / t_decode:.1f}x; "
        f"memory {args.n * args.dim * 4 / (2*m+args.dim*m)/4:.0f}x smaller working set"
    )


if __name__ == "__main__":
    main()
