"""Long-context serving with a CKM-compressed KV cache (beyond-paper demo).

    PYTHONPATH=src python examples/serve_kv_ckm.py

Prefills a small model on a long prompt, compresses each global-attention
layer's KV cache into weighted centroids (the paper's mixture-of-Diracs, on
keys), and decodes with [centroids + exact recent ring].  Reports the
attention-output fidelity vs the uncompressed cache and the memory ratio.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import layers as L
from repro.models import transformer as tfm
from repro.serve.kv_clustering import (
    attention_decode_compressed,
    build_compressed_cache,
)

S_PROMPT = 1024
N_CENTROIDS = 64
RING = 64


def main():
    cfg = get_smoke_config("llama3.2-1b")
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    dims = tfm.attn_dims(cfg, "attn")

    # A long prompt through layer 0's attention to get a real KV cloud.
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S_PROMPT), 0, cfg.vocab_size)
    x = L.embed(params["embed"], tokens, jnp.float32) * jnp.sqrt(cfg.d_model)
    pos = jnp.broadcast_to(jnp.arange(S_PROMPT), (1, S_PROMPT))
    layer0 = jax.tree.map(lambda l: l, params["groups"])  # stacked (G, ...)
    p0 = jax.tree.map(lambda l: l[0], params["groups"]["0"])
    h = L.rmsnorm(p0["norm1"], x)
    _, (k, v) = L.attention_apply(p0["mixer"], dims, h, pos, return_kv=True)

    # Compress with both clusterers from the paper's toolbox.
    q_tok = h[:, -1:, :]
    out_full, _, _ = L.attention_decode(
        p0["mixer"], dims,
        q_tok,
        jnp.pad(k, ((0, 0), (0, 1), (0, 0), (0, 0))),
        jnp.pad(v, ((0, 0), (0, 1), (0, 0), (0, 0))),
        jnp.asarray(S_PROMPT),
    )
    for method in ("lloyd", "ckm"):
        cache = build_compressed_cache(
            jax.random.PRNGKey(2), k, v, N_CENTROIDS, RING, method=method
        )
        out_c, _ = attention_decode_compressed(
            p0["mixer"], dims, q_tok, cache, jnp.asarray(S_PROMPT)
        )
        rel = float(
            jnp.linalg.norm(out_c - out_full) / jnp.linalg.norm(out_full)
        )
        ratio = (S_PROMPT) / (N_CENTROIDS + RING)
        print(
            f"random-init KV  {method:6s}: rel err {rel:.4f} "
            f"({ratio:.1f}x smaller cache; random-init keys have no cluster "
            f"structure — worst case)"
        )

    # Real pretrained KV clouds cluster heavily; emulate that regime.
    kc_, ka, kn = jax.random.split(jax.random.PRNGKey(3), 3)
    centers = jax.random.normal(kc_, (N_CENTROIDS, cfg.n_kv_heads, cfg.head_dim_)) * 4
    assign = jax.random.randint(ka, (S_PROMPT,), 0, N_CENTROIDS)
    kcl = centers[assign][None] + 0.1 * jax.random.normal(kn, k.shape)
    vcl = centers[assign][None] * 0.5
    out_full_c, _, _ = L.attention_decode(
        p0["mixer"], dims, q_tok,
        jnp.pad(kcl, ((0, 0), (0, 1), (0, 0), (0, 0))),
        jnp.pad(vcl, ((0, 0), (0, 1), (0, 0), (0, 0))),
        jnp.asarray(S_PROMPT),
    )
    for method in ("lloyd", "ckm"):
        cache = build_compressed_cache(
            jax.random.PRNGKey(4), kcl, vcl, N_CENTROIDS, RING, method=method
        )
        out_c, _ = attention_decode_compressed(
            p0["mixer"], dims, q_tok, cache, jnp.asarray(S_PROMPT)
        )
        rel = float(jnp.linalg.norm(out_c - out_full_c) / jnp.linalg.norm(out_full_c))
        print(f"clustered KV    {method:6s}: rel err {rel:.4f} (pretrained-cache regime)")
    print(
        "\nnote: for LOCAL offline compression Lloyd is the right clusterer; "
        "CKM earns its keep when the cache is sharded across hosts — each "
        "host sketches its shard (O(m) traffic) and CLOMPR decodes centrally "
        "(see core.distributed_sketch)."
    )


if __name__ == "__main__":
    main()
