"""Benchmark runner — one module per paper table/figure (+ kernels).

``PYTHONPATH=src python -m benchmarks.run [--full] [--only fig1,...]``
Prints ``name,us_per_call,derived`` CSV lines; JSON artifacts land in
experiments/paper/.  Default sizes are reduced for the CPU container
(noted inside each module); --full restores paper-scale.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, help="comma list: fig1,fig2,fig3,fig4,kernels")
    args = ap.parse_args()

    from benchmarks import (
        fig1_init,
        fig2_frequencies,
        fig3_spectral,
        fig4_scaling,
        kernels,
    )

    suites = {
        "fig1": fig1_init.run,
        "fig2": fig2_frequencies.run,
        "fig3": fig3_spectral.run,
        "fig4": fig4_scaling.run,
        "kernels": kernels.run,
    }
    wanted = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    failures = 0
    for name in wanted:
        try:
            suites[name](full=args.full)
        except Exception:
            traceback.print_exc()
            failures += 1
    if failures:
        sys.exit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
