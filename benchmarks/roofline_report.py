"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md tables.

Usage:  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh 16x16]
Prints markdown; also writes experiments/roofline_table.md.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"

ARCH_ORDER = [
    "internvl2-26b", "mistral-large-123b", "gemma3-1b", "smollm-360m",
    "llama3.2-1b", "kimi-k2-1t-a32b", "granite-moe-1b-a400m", "xlstm-125m",
    "whisper-small", "jamba-v0.1-52b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str) -> list[dict]:
    rows = []
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        rows.append(json.loads(p.read_text()))
    key = lambda r: (ARCH_ORDER.index(r["arch"]), SHAPE_ORDER.index(r["shape"]))
    return sorted(rows, key=key)


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (
            f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — | — |"
        )
    if r["status"] == "error":
        return f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |"
    mem = r.get("memory_analysis", {})
    tot_gb = (
        (mem.get("argument_size", 0) + mem.get("temp_size", 0)) / 2**30
        if isinstance(mem, dict)
        else float("nan")
    )
    return (
        f"| {r['arch']} | {r['shape']} | {tot_gb:.1f} | "
        f"{r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} | "
        f"{r['collective_s']*1e3:.1f} | {r['dominant']} | "
        f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} |"
    )


HEADER = (
    "| arch | shape | mem GB/chip | compute ms | memory ms | collective ms |"
    " bound | useful | roofline frac |\n"
    "|---|---|---|---|---|---|---|---|---|"
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, help="16x16 or 2x16x16; default both")
    args = ap.parse_args()
    meshes = [args.mesh] if args.mesh else ["16x16", "16x16__opt", "2x16x16"]
    out = []
    for mesh in meshes:
        rows = load(mesh)
        if not rows:
            continue
        chips = "512" if mesh.startswith("2x") else "256"
        label = mesh + (" (optimized: score_dtype=bf16)" if mesh.endswith("__opt") else "")
        out.append(f"\n### Mesh {label} ({chips} chips)\n")
        out.append(HEADER)
        for r in rows:
            out.append(fmt_row(r))
        ok = [r for r in rows if r["status"] == "ok"]
        out.append(
            f"\n{len(ok)} compiled, "
            f"{sum(1 for r in rows if r['status']=='skipped')} skipped "
            f"(long_500k on pure full-attention archs, per DESIGN.md §4), "
            f"{sum(1 for r in rows if r['status']=='error')} errors."
        )
    text = "\n".join(out)
    print(text)
    (ROOT / "experiments" / "roofline_table.md").write_text(text)


if __name__ == "__main__":
    main()
