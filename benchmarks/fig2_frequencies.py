"""Paper Fig. 2 — how many frequencies does CKM need?

Claim: relative SSE (CKM / kmeans) drops below 2 at m/(Kn) ~ 5, roughly
independent of n and K.  We sweep m/(Kn) for (K=10, n=10), plus shorter
sweeps varying n and K, and report the smallest ratio where relSSE < 2.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import csv_line, save, timed
from repro.core import ckm as ckm_mod
from repro.core import lloyd as lloyd_mod
from repro.data import synthetic

RATIOS = (1, 2, 3, 5, 8, 12)


def _rel_sse(key, n_points, k, n, ratio, trials):
    rels = []
    for t in range(trials):
        kd, kc, kl = jax.random.split(jax.random.PRNGKey(key + 31 * t), 3)
        x = synthetic.gaussian_mixture(kd, n_points, k, n)
        lres = lloyd_mod.kmeans(
            kl, x, lloyd_mod.LloydConfig(k=k, replicates=3, init="range")
        )
        cfg = ckm_mod.CKMConfig(k=k, m=max(int(ratio * k * n), 8))
        res = ckm_mod.fit(kc, x, cfg)
        rels.append(float(ckm_mod.sse(x, res.centroids)) / float(lres.sse))
    return float(np.mean(rels))


def run(full: bool = False):
    n_points = 100_000 if full else 20_000
    trials = 5 if full else 3
    results: dict = {"n_points": n_points, "trials": trials, "sweeps": {}}
    sweeps = [("K10_n10", 10, 10)]
    if full:
        sweeps += [("K10_n4", 10, 4), ("K10_n20", 10, 20), ("K5_n10", 5, 10),
                   ("K20_n10", 20, 10)]
    else:
        sweeps += [("K5_n10", 5, 10), ("K10_n4", 10, 4)]
    for name, k, n in sweeps:
        curve = {}
        for ratio in RATIOS:
            (rel), dt = timed(_rel_sse, 17, n_points, k, n, ratio, trials)
            curve[ratio] = rel
            csv_line(f"fig2_{name}_r{ratio}", dt, f"relSSE={rel:.3f}")
        crossing = next((r for r in RATIOS if curve[r] < 2.0), None)
        results["sweeps"][name] = {"curve": curve, "first_ratio_below_2": crossing}
    # Paper claim: the relSSE<2 crossing sits at m/(Kn) <= 5 for the paper's
    # regime (n >= 10 shows it cleanly; low n deviates, as the paper notes).
    main = results["sweeps"]["K10_n10"]["first_ratio_below_2"]
    results["claim_crossing_at_or_below_5"] = bool(main is not None and main <= 5)
    save("fig2_frequencies", results)
    return results


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
