"""Per-shape HBM byte breakdown for a dry-run cell (hillclimb profiler)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re, math, collections, dataclasses

from repro.configs.base import SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.utils import hlo

arch, shape_name = sys.argv[1], sys.argv[2]
overrides = dict(kv.split("=", 1) for kv in sys.argv[3:])
cfg = get_config(arch)
if overrides:
    cfg = dataclasses.replace(cfg, **overrides)
shape = SHAPES[shape_name]
mesh = make_production_mesh()

if shape.kind == "train":
    from repro.launch.train import jit_train_step
    from repro.launch.specs import train_batch_specs

    jitted, shapes, *_ = jit_train_step(cfg, shape, mesh)
    compiled = jitted.lower(shapes, train_batch_specs(cfg, shape)).compile()
elif shape.kind == "prefill":
    from repro.launch.serve import jit_prefill

    jitted, (ps, bs) = jit_prefill(cfg, shape, mesh)
    compiled = jitted.lower(ps, bs).compile()
else:
    from repro.launch.serve import jit_serve_step

    jitted, (ps, tok, cs, idx) = jit_serve_step(cfg, shape, mesh)
    compiled = jitted.lower(ps, tok, cs, idx).compile()

text = compiled.as_text()
comps, entry = hlo._parse_computations(text)

# exact recursive walk mirroring hlo._cost_computation but attributing bytes
agg = collections.Counter()

def walk(name, mult):
    instrs, types, producers, consumers = hlo._parse_instrs(comps.get(name, ()))
    for m in instrs:
        op = m.group("op")
        iname = m.group("name")
        out = m.group("out")
        rest = m.group("rest")
        ops_n = hlo._OPERAND_NAME_RE.findall(m.group("operands"))
        if op in hlo._COLLECTIVE_DONE or op in hlo._BOOKKEEPING:
            continue
        if op in hlo._COLLECTIVES:
            continue
        if op == "while":
            tm = hlo._TRIP_RE.search(rest)
            t = int(tm.group(1)) if tm else 1
            cm = re.search(r"body=%?([\w\.\-]+)", rest)
            if cm:
                walk(cm.group(1), mult * t)
            continue
        if op == "conditional":
            continue
        if hlo._is_convert(iname, producers):
            continue
        b = sum(hlo._effective_bytes(n, types, producers) for n in ops_n)
        if op == "dot":
            b += hlo._result_effective_bytes(iname, types, producers, consumers)
        else:
            b += hlo._shape_bytes(out)
        agg[(op, out[:52])] += b * mult

walk(entry, 1)
tot = sum(agg.values())
print(f"total {tot:.3e} bytes/device")
for (op, shp), b in agg.most_common(18):
    print(f"{b/2**40:9.3f} TiB {100*b/tot:5.1f}%  {op:9s} {shp}")
