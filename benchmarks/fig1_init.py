"""Paper Fig. 1 — initialization strategies (Range / Sample / K++).

Claim: CKM is almost insensitive to the init strategy; Lloyd-Max is not
(only K++ makes it competitive).  Gaussian mixture, K=10, n=10, m=1000.
Reduced defaults: N=30k, 10 trials (paper: N=300k, 100 trials) — --full
restores the paper sizes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, save, stats, timed
from repro.core import ckm as ckm_mod
from repro.core import lloyd as lloyd_mod
from repro.data import synthetic

STRATEGIES = ("range", "sample", "kpp")


def run(full: bool = False, trials: int | None = None, n_points: int | None = None):
    k, n, m = 10, 10, 1000
    n_points = n_points or (300_000 if full else 30_000)
    trials = trials or (20 if full else 8)
    results: dict = {"n_points": n_points, "trials": trials}
    for strat in STRATEGIES:
        sses_ckm, sses_km, t_ckm = [], [], []
        for t in range(trials):
            kd, kc, kl = jax.random.split(jax.random.PRNGKey(1000 + t), 3)
            x = synthetic.gaussian_mixture(kd, n_points, k, n)
            cfg = ckm_mod.CKMConfig(k=k, m=m, init=strat)
            res, dt = timed(ckm_mod.fit, kc, x, cfg)
            sses_ckm.append(float(ckm_mod.sse(x, res.centroids)) / n_points)
            t_ckm.append(dt)
            lres = lloyd_mod.kmeans(
                kl, x, lloyd_mod.LloydConfig(k=k, init=strat)
            )
            sses_km.append(float(lres.sse) / n_points)
        results[strat] = {
            "ckm_sse": stats(sses_ckm),
            "kmeans_sse": stats(sses_km),
        }
        csv_line(
            f"fig1_{strat}",
            float(np.mean(t_ckm)),
            f"ckm_sse={np.mean(sses_ckm):.3f}±{np.std(sses_ckm):.3f};"
            f"km_sse={np.mean(sses_km):.3f}±{np.std(sses_km):.3f}",
        )
    # Paper claim checks: CKM variance across strategies is small; kmeans
    # std with random init exceeds CKM's.
    ckm_means = [results[s]["ckm_sse"]["mean"] for s in STRATEGIES]
    results["ckm_strategy_spread"] = float(np.max(ckm_means) - np.min(ckm_means))
    results["claim_ckm_insensitive"] = bool(
        results["ckm_strategy_spread"] < 0.15 * float(np.mean(ckm_means))
    )
    results["claim_kmeans_init_sensitive"] = bool(
        results["range"]["kmeans_sse"]["std"] > results["range"]["ckm_sse"]["std"]
    )
    save("fig1_init", results)
    return results


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
