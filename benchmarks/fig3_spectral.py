"""Paper Fig. 3 — spectral clustering, SSE + ARI vs N, 1 vs 5 replicates.

The paper's MNIST+SIFT+FLANN pipeline is not reproducible offline; per
DESIGN.md §8 we keep the protocol (spectral embedding -> K-means -> ARI
against ground truth) on an SBM graph whose normalised-Laplacian eigenvectors
give the same kind of 10-dim features.  Claims preserved:
- kmeans improves a lot from 1 -> 5 replicates; CKM barely changes;
- CKM's ARI is competitive with (or better than) kmeans x5.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import csv_line, save, stats, timed
from repro.core import ckm as ckm_mod
from repro.core import lloyd as lloyd_mod
from repro.data import synthetic


def _one(seed, n_nodes, k, trials):
    out = {"ckm1": [], "ckm5": [], "km1": [], "km5": [],
           "ckm1_ari": [], "ckm5_ari": [], "km1_ari": [], "km5_ari": []}
    for t in range(trials):
        feats, labels = synthetic.sbm_spectral(seed + t, n_nodes, k=k)
        x = jax.numpy.asarray(feats)
        n_pts = x.shape[0]
        for reps, tag in ((1, "1"), (5, "5")):
            kc, kl = jax.random.split(jax.random.PRNGKey(seed + 100 * t + reps))
            cfg = ckm_mod.CKMConfig(k=k, m=10 * k * feats.shape[1],
                                    replicates=reps)
            res = ckm_mod.fit(kc, x, cfg)
            out[f"ckm{tag}"].append(float(ckm_mod.sse(x, res.centroids)) / n_pts)
            pred = np.asarray(ckm_mod.predict(x, res.centroids))
            out[f"ckm{tag}_ari"].append(synthetic.adjusted_rand_index(labels, pred))
            lres = lloyd_mod.kmeans(
                kl, x, lloyd_mod.LloydConfig(k=k, replicates=reps, init="range")
            )
            out[f"km{tag}"].append(float(lres.sse) / n_pts)
            pred = np.asarray(ckm_mod.predict(x, lres.centroids))
            out[f"km{tag}_ari"].append(synthetic.adjusted_rand_index(labels, pred))
    return out


def run(full: bool = False):
    sizes = (1000, 2000, 4000) if full else (800, 1600)
    trials = 5 if full else 3
    k = 10
    results: dict = {"sizes": list(sizes), "trials": trials}
    for n_nodes in sizes:
        res, dt = timed(_one, 7, n_nodes, k, trials)
        packed = {key: stats(v) for key, v in res.items()}
        results[str(n_nodes)] = packed
        csv_line(
            f"fig3_N{n_nodes}", dt,
            f"ckm1_ari={packed['ckm1_ari']['mean']:.3f};"
            f"km1_ari={packed['km1_ari']['mean']:.3f};"
            f"km5_ari={packed['km5_ari']['mean']:.3f}",
        )
    big = results[str(sizes[-1])]
    results["claim_ckm_stable_1_vs_5"] = bool(
        abs(big["ckm1"]["mean"] - big["ckm5"]["mean"])
        <= abs(big["km1"]["mean"] - big["km5"]["mean"]) + 1e-9
    )
    results["claim_ckm_ari_competitive"] = bool(
        big["ckm1_ari"]["mean"] >= big["km5_ari"]["mean"] - 0.05
    )
    save("fig3_spectral", results)
    return results


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
