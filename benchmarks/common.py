"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "experiments" / "paper"


def save(name: str, payload: dict):
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{name}.json").write_text(json.dumps(payload, indent=2))


def timed(fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


def csv_line(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds*1e6:.1f},{derived}")


def stats(xs) -> dict:
    xs = np.asarray(xs, np.float64)
    return {"mean": float(xs.mean()), "std": float(xs.std()), "n": int(xs.size)}
