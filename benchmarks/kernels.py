"""Kernel microbenchmarks: fused Pallas fourier_sketch / assign_argmin.

On this CPU container the Pallas kernels run in interpret mode (correctness),
so wall-clock speedups are NOT meaningful; what we report per kernel is
- interpret-mode equivalence error vs the jnp oracle, and
- the HBM-traffic model: bytes moved by the unfused jnp path (projection
  matrix materialised) vs the fused kernel (inputs+outputs only), which is
  the quantity the TPU roofline converts into time.
Also times the jnp fallback paths (the actual CPU execution path), reports
the QCKM rows: dequantization error of the quantized sketch and the
sketch bytes-on-the-wire per backend (float vs minimal-width integer
accumulators) — the bandwidth the quantized subsystem saves at merge time —
and the decoder-comparison rows: SSE + decode wall-clock of every registered
decoder on the fig-1 blobs protocol, from one shared sketch, so
``kernels.json`` tracks per-decoder quality/latency across PRs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, save, timed
from repro.core import available_decoders
from repro.core import ckm as ckm_mod
from repro.core import engine as eng_mod
from repro.core import quantize as qz
from repro.core import sketch as core_sk
from repro.kernels import ops, ref


def run_engine_backends(results: dict, n_pts=4096, feat=16, m=1024):
    """SketchEngine backend matrix on one shape: parity vs the reference
    sketch + wall time of each backend's actual CPU execution path (pallas
    interpret mode is excluded from timing — it is a correctness mode)."""
    key = jax.random.PRNGKey(7)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (n_pts, feat))
    w = jax.random.normal(kw, (feat, m))
    z_ref = np.asarray(core_sk.sketch(x, w))
    engines = {
        "xla": eng_mod.SketchEngine(w, "xla"),
        "pallas": eng_mod.SketchEngine(w, "pallas", block_n=512, block_m=256),
    }
    for name, e in engines.items():
        z, _, _ = e.sketch(x[:2048] if name == "pallas" else x)
        ref_z = np.asarray(core_sk.sketch(x[:2048], w)) if name == "pallas" else z_ref
        err = float(np.max(np.abs(np.asarray(z) - ref_z)))
        row = {"parity_max_err": err}
        if name == "xla":
            _, t = timed(lambda: e.sketch(x))
            _, t = timed(lambda: e.sketch(x))  # warm
            row["seconds"] = t
            csv_line(f"engine_{name}_N{n_pts}_m{m}", t, f"err={err:.2e}")
        else:
            csv_line(f"engine_{name}_N{n_pts}_m{m}", 0.0, f"err={err:.2e}")
        results[f"engine_{name}"] = row
        assert err < 1e-4, (name, err)
    return results


def run_quantized(results: dict, n_pts=8192, feat=16, m=1024):
    """QCKM quantized-sketch rows: dequantization error vs the float sketch,
    bitwise xla/pallas parity of the int32 accumulators, and the
    bytes-on-the-wire of one partial state — float f32 accumulators vs the
    minimal-width integer accumulators (``core.quantize.state_wire_bytes``),
    one row that applies to every backend's merge."""
    key = jax.random.PRNGKey(3)
    kx, kw, kd = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n_pts, feat))
    w = jax.random.normal(kw, (feat, m)) * 0.5
    z_ref = np.asarray(core_sk.sketch(x, w))
    sl = 2048  # pallas interpret mode is slow: parity on a slice
    for spec in ("1bit", "8bit"):
        q = qz.make_quantizer(kd, m, spec)
        e_x = eng_mod.SketchEngine(w, "xla", quantizer=q)
        z, _, _ = e_x.sketch(x)
        rel = float(
            np.linalg.norm(np.asarray(z) - z_ref) / np.linalg.norm(z_ref)
        )
        e_p = eng_mod.SketchEngine(
            w, "pallas", block_n=512, block_m=256, quantizer=q
        )
        s_x = e_x.update(e_x.init_state(), x[:sl])
        s_p = e_p.update(e_p.init_state(), x[:sl])
        int_mismatch = int(
            jnp.sum(s_x.qcos_acc != s_p.qcos_acc)
            + jnp.sum(s_x.qsin_acc != s_p.qsin_acc)
        )
        assert int_mismatch == 0, (spec, int_mismatch)
        results[f"quantized_{spec}"] = {
            "dequant_rel_l2_err": rel,
            "pallas_int_mismatches": int_mismatch,
        }
        csv_line(f"quantized_{spec}_N{n_pts}_m{m}", 0.0, f"rel_err={rel:.3f}")
    # Bytes-on-the-wire of one partial state's accumulators.  The number is a
    # property of the state representation, not of how it was computed, so a
    # single row applies to every backend: it is what the sharded backend's
    # psum moves per merge, and what xla/pallas hosts ship when partials are
    # combined off-device.
    wire = {
        spec: qz.state_wire_bytes(m, n_pts, bits)
        for spec, bits in {"float": None, "1bit": 1, "8bit": 8}.items()
    }
    wire["reduction_1bit"] = wire["float"] / wire["1bit"]
    wire["applies_to_backends"] = list(eng_mod.BACKENDS)
    results["sketch_wire_bytes"] = wire
    csv_line(
        f"wire_N{n_pts}_m{m}", 0.0,
        f"float={wire['float']}B;1bit={wire['1bit']}B;"
        f"x{wire['reduction_1bit']:.1f}",
    )
    return results


def run_decoders(results: dict, n_pts=8192, k=5, feat=4):
    """Decoder-comparison rows (paper Fig. 1 blobs protocol at container
    scale): every registered decoder decodes the SAME sketch; we record the
    data-domain SSE, the sketch-domain cost, and the decode wall-clock (warm,
    jitted — the real CPU execution path).  The smoke assertion pins the
    tentpole acceptance: ``sketch_shift`` stays within 10% of CLOMPR's SSE.
    """
    key = jax.random.PRNGKey(11)
    from repro.data import synthetic

    x, _, _ = synthetic.gaussian_mixture(
        key, n_pts, k=k, n=feat, c=6.0, return_labels=True
    )
    base = ckm_mod.CKMConfig(k=k)
    z, w, _, (lo, hi) = ckm_mod.compute_sketch(jax.random.PRNGKey(1), x, base)
    m = base.sketch_size(feat)
    sses = {}
    for name in available_decoders():
        cfg = ckm_mod.CKMConfig(k=k, decoder=name)

        def run_decode():
            out = ckm_mod.decode_sketch(jax.random.PRNGKey(2), z, w, lo, hi, cfg)
            return out

        (cents, _, cost), _ = timed(run_decode)
        (cents, _, cost), t = timed(run_decode)  # warm (jit cached)
        sse_val = float(ckm_mod.sse(x, cents)) / n_pts
        sses[name] = sse_val
        results[f"decoder_{name}"] = {
            "sse_per_n": sse_val,
            "sketch_cost": float(cost),
            "decode_seconds": t,
        }
        csv_line(
            f"decoder_{name}_N{n_pts}_K{k}_m{m}", t, f"sse_per_n={sse_val:.4f}"
        )
    rel = sses["sketch_shift"] / sses["clompr"]
    results["decoder_sketch_shift"]["sse_vs_clompr"] = rel
    assert rel < 1.10, sses
    return results


def run(full: bool = False):
    results = {}
    shapes = [(4096, 16, 1024), (16384, 10, 1000)] if not full else [
        (4096, 16, 1024), (65536, 10, 1000), (262144, 16, 2048)]
    for n_pts, feat, m in shapes:
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (n_pts, feat))
        w = jax.random.normal(kw, (feat, m))
        beta = jnp.full((n_pts,), 1.0 / n_pts)
        # interpret-mode equivalence on a slice (full interpret is slow)
        sl = slice(0, min(n_pts, 2048))
        zk = ops.fourier_sketch(x[sl], w, beta[sl] * (n_pts / 2048),
                                interpret=True, block_n=256, block_m=256)
        ck, sk_ = ref.fourier_sketch_ref(x[sl], w, beta[sl] * (n_pts / 2048))
        err = float(jnp.max(jnp.abs(zk - jnp.concatenate([ck, -sk_]))))
        # jnp (unfused) wall time — the real CPU path
        f = jax.jit(lambda x, w, b: ref.fourier_sketch_ref(x, w, b))
        _, t_ref = timed(f, x, w, beta)
        _, t_ref = timed(f, x, w, beta)  # warm
        # traffic model (f32): unfused writes+reads the (N, m) projection 3x
        unfused = 4 * (n_pts * feat + feat * m + 3 * n_pts * m + 2 * m)
        fused = 4 * (n_pts * feat + feat * m + 2 * m)
        name = f"sketch_N{n_pts}_n{feat}_m{m}"
        results[name] = {
            "interpret_max_err": err,
            "jnp_seconds": t_ref,
            "bytes_unfused": unfused,
            "bytes_fused": fused,
            "traffic_reduction": unfused / fused,
        }
        csv_line(name, t_ref, f"err={err:.2e};traffic_x{unfused/fused:.1f}")
        assert err < 1e-3
    # assign_argmin
    for n_pts, feat, k in [(16384, 16, 64), (65536, 10, 10)]:
        kx, kc = jax.random.split(jax.random.PRNGKey(1))
        x = jax.random.normal(kx, (n_pts, feat))
        c = jax.random.normal(kc, (k, feat))
        sl = slice(0, 2048)
        ik, dk = ops.assign_argmin(x[sl], c, interpret=True, block_n=256)
        ir, dr = ref.assign_argmin_ref(x[sl], c)
        agree = float(jnp.mean((ik == ir).astype(jnp.float32)))
        f = jax.jit(lambda x, c: ref.assign_argmin_ref(x, c))
        _, t_ref = timed(f, x, c)
        _, t_ref = timed(f, x, c)
        unfused = 4 * (n_pts * feat + k * feat + 2 * n_pts * k + 2 * n_pts)
        fused = 4 * (n_pts * feat + k * feat + 2 * n_pts)
        name = f"assign_N{n_pts}_n{feat}_K{k}"
        results[name] = {
            "interpret_agreement": agree,
            "jnp_seconds": t_ref,
            "traffic_reduction": unfused / fused,
        }
        csv_line(name, t_ref, f"agree={agree:.4f};traffic_x{unfused/fused:.1f}")
        assert agree == 1.0
    run_engine_backends(results)
    run_quantized(results)
    run_decoders(results)
    save("kernels", results)
    return results


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
