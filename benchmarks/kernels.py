"""Kernel microbenchmarks: fused Pallas fourier_sketch / assign_argmin.

On this CPU container the Pallas kernels run in interpret mode (correctness),
so wall-clock speedups are NOT meaningful; what we report per kernel is
- interpret-mode equivalence error vs the jnp oracle, and
- the HBM-traffic model: bytes moved by the unfused jnp path (projection
  matrix materialised) vs the fused kernel (inputs+outputs only), which is
  the quantity the TPU roofline converts into time.
Also times the jnp fallback paths (the actual CPU execution path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, save, timed
from repro.core import engine as eng_mod
from repro.core import sketch as core_sk
from repro.kernels import ops, ref


def run_engine_backends(results: dict, n_pts=4096, feat=16, m=1024):
    """SketchEngine backend matrix on one shape: parity vs the reference
    sketch + wall time of each backend's actual CPU execution path (pallas
    interpret mode is excluded from timing — it is a correctness mode)."""
    key = jax.random.PRNGKey(7)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (n_pts, feat))
    w = jax.random.normal(kw, (feat, m))
    z_ref = np.asarray(core_sk.sketch(x, w))
    engines = {
        "xla": eng_mod.SketchEngine(w, "xla"),
        "pallas": eng_mod.SketchEngine(w, "pallas", block_n=512, block_m=256),
    }
    for name, e in engines.items():
        z, _, _ = e.sketch(x[:2048] if name == "pallas" else x)
        ref_z = np.asarray(core_sk.sketch(x[:2048], w)) if name == "pallas" else z_ref
        err = float(np.max(np.abs(np.asarray(z) - ref_z)))
        row = {"parity_max_err": err}
        if name == "xla":
            _, t = timed(lambda: e.sketch(x))
            _, t = timed(lambda: e.sketch(x))  # warm
            row["seconds"] = t
            csv_line(f"engine_{name}_N{n_pts}_m{m}", t, f"err={err:.2e}")
        else:
            csv_line(f"engine_{name}_N{n_pts}_m{m}", 0.0, f"err={err:.2e}")
        results[f"engine_{name}"] = row
        assert err < 1e-4, (name, err)
    return results


def run(full: bool = False):
    results = {}
    shapes = [(4096, 16, 1024), (16384, 10, 1000)] if not full else [
        (4096, 16, 1024), (65536, 10, 1000), (262144, 16, 2048)]
    for n_pts, feat, m in shapes:
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (n_pts, feat))
        w = jax.random.normal(kw, (feat, m))
        beta = jnp.full((n_pts,), 1.0 / n_pts)
        # interpret-mode equivalence on a slice (full interpret is slow)
        sl = slice(0, min(n_pts, 2048))
        zk = ops.fourier_sketch(x[sl], w, beta[sl] * (n_pts / 2048),
                                interpret=True, block_n=256, block_m=256)
        ck, sk_ = ref.fourier_sketch_ref(x[sl], w, beta[sl] * (n_pts / 2048))
        err = float(jnp.max(jnp.abs(zk - jnp.concatenate([ck, -sk_]))))
        # jnp (unfused) wall time — the real CPU path
        f = jax.jit(lambda x, w, b: ref.fourier_sketch_ref(x, w, b))
        _, t_ref = timed(f, x, w, beta)
        _, t_ref = timed(f, x, w, beta)  # warm
        # traffic model (f32): unfused writes+reads the (N, m) projection 3x
        unfused = 4 * (n_pts * feat + feat * m + 3 * n_pts * m + 2 * m)
        fused = 4 * (n_pts * feat + feat * m + 2 * m)
        name = f"sketch_N{n_pts}_n{feat}_m{m}"
        results[name] = {
            "interpret_max_err": err,
            "jnp_seconds": t_ref,
            "bytes_unfused": unfused,
            "bytes_fused": fused,
            "traffic_reduction": unfused / fused,
        }
        csv_line(name, t_ref, f"err={err:.2e};traffic_x{unfused/fused:.1f}")
        assert err < 1e-3
    # assign_argmin
    for n_pts, feat, k in [(16384, 16, 64), (65536, 10, 10)]:
        kx, kc = jax.random.split(jax.random.PRNGKey(1))
        x = jax.random.normal(kx, (n_pts, feat))
        c = jax.random.normal(kc, (k, feat))
        sl = slice(0, 2048)
        ik, dk = ops.assign_argmin(x[sl], c, interpret=True, block_n=256)
        ir, dr = ref.assign_argmin_ref(x[sl], c)
        agree = float(jnp.mean((ik == ir).astype(jnp.float32)))
        f = jax.jit(lambda x, c: ref.assign_argmin_ref(x, c))
        _, t_ref = timed(f, x, c)
        _, t_ref = timed(f, x, c)
        unfused = 4 * (n_pts * feat + k * feat + 2 * n_pts * k + 2 * n_pts)
        fused = 4 * (n_pts * feat + k * feat + 2 * n_pts)
        name = f"assign_N{n_pts}_n{feat}_K{k}"
        results[name] = {
            "interpret_agreement": agree,
            "jnp_seconds": t_ref,
            "traffic_reduction": unfused / fused,
        }
        csv_line(name, t_ref, f"agree={agree:.4f};traffic_x{unfused/fused:.1f}")
        assert agree == 1.0
    run_engine_backends(results)
    save("kernels", results)
    return results


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
