"""Kernel microbenchmarks: fused Pallas fourier_sketch / assign_argmin.

On this CPU container the Pallas kernels run in interpret mode (correctness),
so wall-clock speedups are NOT meaningful; what we report per kernel is
- interpret-mode equivalence error vs the jnp oracle, and
- the HBM-traffic model: bytes moved by the unfused jnp path (projection
  matrix materialised) vs the fused kernel (inputs+outputs only), which is
  the quantity the TPU roofline converts into time.
Also times the jnp fallback paths (the actual CPU execution path), reports
the QCKM rows: dequantization error of the quantized sketch and the
sketch bytes-on-the-wire per backend (float vs minimal-width integer
accumulators) — the bandwidth the quantized subsystem saves at merge time —
and the decoder-comparison rows: SSE + decode wall-clock of every registered
decoder on the fig-1 blobs protocol, from one shared sketch, so
``kernels.json`` tracks per-decoder quality/latency across PRs.

SSE-vs-m frontier rows (ISSUE 6, ``run_amp``): amp vs clompr vs sketch_shift
fits at m = {2, 4, 10}·K·n on blobs, best-of-3 replicates — the CL-AMP
acceptance is ``amp`` at 4·K·n within 5% of CLOMPR at 10·K·n.

Frequency-operator rows (ISSUE 5, ``run_freq_ops``): per-operator sketch
throughput (dense vs structured fast transform), operator-state /
spec-wire bytes (the spec-not-matrix acceptance), a roofline cross-check
of the structured flops model against compiled HLO, and the
structured-vs-dense SSE acceptance (within 5% on blobs).

Fleet rows (ISSUE 7, ``run_fleet``): multi-tenant serving throughput — one
vmapped stacked ``FleetEngine.update`` over T=1024 tenants vs a Python loop
of 1024 per-tenant ``SketchEngine`` updates (same operators, bitwise-equal
states).  The acceptance is the batched dispatch >= 5x faster at T=1024;
parity is asserted here on the full fleet and pinned exhaustively in
``tests/test_fleet.py``.

Fleet-sharding rows (ISSUE 10, ``run_fleet_shard``): the T=1024 fleet update
mesh-sharded over 4 forced host devices vs the single-device stacked path,
with the zero-collective HLO check, per-tenant bitwise parity against
isolated engines (float + quantized), and an honest ``speedup_basis`` field
— wall clock when the host has a core per shard, the per-shard critical
path otherwise (host devices time-share cores).  Acceptance: >= 2.5x.

Scaling rows (PR 4):
- ingest: sync vs async ``fit_streaming`` over an I/O-bound blobs stream
  (per-batch latency calibrated to the measured sketch-compute time, the
  worst case for overlap bookkeeping and the regime the paper targets —
  data arriving from storage).  Records wall clocks, speedup (acceptance:
  >= 1.3x) and the measured overlap efficiency of the ingest pipeline.
- topologies: per-topology host-level merge latency over 8 quantized partial
  states + the alpha-beta wire cost model (bytes/device, serialized hops)
  for float vs 1-bit states; asserts all registered topologies finalize
  **bitwise identical** sketches on the quantized path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import dataclasses
import time

from benchmarks.common import csv_line, save, timed
from repro.core import available_decoders, available_topologies
from repro.core import ckm as ckm_mod
from repro.core import engine as eng_mod
from repro.core import freq_ops as fo
from repro.core import ingest as ingest_mod
from repro.core import quantize as qz
from repro.core import sketch as core_sk
from repro.core import topology as topo_mod
from repro.data import pipeline as pipe
from repro.kernels import ops, ref


def run_engine_backends(results: dict, n_pts=4096, feat=16, m=1024):
    """SketchEngine backend matrix on one shape: parity vs the reference
    sketch + wall time of each backend's actual CPU execution path (pallas
    interpret mode is excluded from timing — it is a correctness mode)."""
    key = jax.random.PRNGKey(7)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (n_pts, feat))
    w = jax.random.normal(kw, (feat, m))
    z_ref = np.asarray(core_sk.sketch(x, w))
    engines = {
        "xla": eng_mod.SketchEngine(w, "xla"),
        "pallas": eng_mod.SketchEngine(w, "pallas", block_n=512, block_m=256),
    }
    for name, e in engines.items():
        z, _, _ = e.sketch(x[:2048] if name == "pallas" else x)
        ref_z = np.asarray(core_sk.sketch(x[:2048], w)) if name == "pallas" else z_ref
        err = float(np.max(np.abs(np.asarray(z) - ref_z)))
        row = {"parity_max_err": err}
        if name == "xla":
            _, t = timed(lambda: e.sketch(x))
            _, t = timed(lambda: e.sketch(x))  # warm
            row["seconds"] = t
            csv_line(f"engine_{name}_N{n_pts}_m{m}", t, f"err={err:.2e}")
        else:
            csv_line(f"engine_{name}_N{n_pts}_m{m}", 0.0, f"err={err:.2e}")
        results[f"engine_{name}"] = row
        assert err < 1e-4, (name, err)
    return results


def run_quantized(results: dict, n_pts=8192, feat=16, m=1024):
    """QCKM quantized-sketch rows: dequantization error vs the float sketch,
    bitwise xla/pallas parity of the int32 accumulators, and the
    bytes-on-the-wire of one partial state — float f32 accumulators vs the
    minimal-width integer accumulators (``core.quantize.state_wire_bytes``),
    one row that applies to every backend's merge."""
    key = jax.random.PRNGKey(3)
    kx, kw, kd = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n_pts, feat))
    w = jax.random.normal(kw, (feat, m)) * 0.5
    z_ref = np.asarray(core_sk.sketch(x, w))
    sl = 2048  # pallas interpret mode is slow: parity on a slice
    for spec in ("1bit", "8bit"):
        q = qz.make_quantizer(kd, m, spec)
        e_x = eng_mod.SketchEngine(w, "xla", quantizer=q)
        z, _, _ = e_x.sketch(x)
        rel = float(
            np.linalg.norm(np.asarray(z) - z_ref) / np.linalg.norm(z_ref)
        )
        e_p = eng_mod.SketchEngine(
            w, "pallas", block_n=512, block_m=256, quantizer=q
        )
        s_x = e_x.update(e_x.init_state(), x[:sl])
        s_p = e_p.update(e_p.init_state(), x[:sl])
        int_mismatch = int(
            jnp.sum(s_x.qcos_acc != s_p.qcos_acc)
            + jnp.sum(s_x.qsin_acc != s_p.qsin_acc)
        )
        assert int_mismatch == 0, (spec, int_mismatch)
        results[f"quantized_{spec}"] = {
            "dequant_rel_l2_err": rel,
            "pallas_int_mismatches": int_mismatch,
        }
        csv_line(f"quantized_{spec}_N{n_pts}_m{m}", 0.0, f"rel_err={rel:.3f}")
    # Bytes-on-the-wire of one partial state's accumulators.  The number is a
    # property of the state representation, not of how it was computed, so a
    # single row applies to every backend: it is what the sharded backend's
    # psum moves per merge, and what xla/pallas hosts ship when partials are
    # combined off-device.
    wire = {
        spec: qz.state_wire_bytes(m, n_pts, bits)
        for spec, bits in {"float": None, "1bit": 1, "8bit": 8}.items()
    }
    wire["reduction_1bit"] = wire["float"] / wire["1bit"]
    wire["applies_to_backends"] = list(eng_mod.BACKENDS)
    results["sketch_wire_bytes"] = wire
    csv_line(
        f"wire_N{n_pts}_m{m}", 0.0,
        f"float={wire['float']}B;1bit={wire['1bit']}B;"
        f"x{wire['reduction_1bit']:.1f}",
    )
    return results


def run_decoders(results: dict, n_pts=8192, k=5, feat=4):
    """Decoder-comparison rows (paper Fig. 1 blobs protocol at container
    scale): every registered decoder decodes the SAME sketch; we record the
    data-domain SSE, the sketch-domain cost, and the decode wall-clock (warm,
    jitted — the real CPU execution path).  The smoke assertion pins the
    tentpole acceptance: ``sketch_shift`` stays within 10% of CLOMPR's SSE.
    """
    key = jax.random.PRNGKey(11)
    from repro.data import synthetic

    x, _, _ = synthetic.gaussian_mixture(
        key, n_pts, k=k, n=feat, c=6.0, return_labels=True
    )
    base = ckm_mod.CKMConfig(k=k)
    z, w, _, (lo, hi) = ckm_mod.compute_sketch(jax.random.PRNGKey(1), x, base)
    m = base.sketch_size(feat)
    sses = {}
    for name in available_decoders():
        cfg = ckm_mod.CKMConfig(k=k, decoder=name)

        def run_decode():
            out = ckm_mod.decode_sketch(jax.random.PRNGKey(2), z, w, lo, hi, cfg)
            return out

        (cents, _, cost), _ = timed(run_decode)
        (cents, _, cost), t = timed(run_decode)  # warm (jit cached)
        sse_val = float(ckm_mod.sse(x, cents)) / n_pts
        sses[name] = sse_val
        results[f"decoder_{name}"] = {
            "sse_per_n": sse_val,
            "sketch_cost": float(cost),
            "decode_seconds": t,
        }
        csv_line(
            f"decoder_{name}_N{n_pts}_K{k}_m{m}", t, f"sse_per_n={sse_val:.4f}"
        )
    rel = sses["sketch_shift"] / sses["clompr"]
    results["decoder_sketch_shift"]["sse_vs_clompr"] = rel
    assert rel < 1.10, sses
    return results


def run_amp(results: dict, n_pts=8000, k=5, feat=4):
    """SSE-vs-m frontier per decoder (ISSUE 6): amp vs clompr vs sketch_shift
    on the blobs protocol at m = {2, 4, 10}·K·n, best-of-3 replicates each
    (CL-AMP's own protocol — random restarts selected by the shared
    sketch-domain cost).  The acceptance pins the tentpole claim: ``amp`` at
    m = 4·K·n lands within 5% of CLOMPR's SSE at m = 10·K·n — message
    passing stays accurate at sketch sizes where greedy decoding degrades.
    """
    from repro.data import synthetic

    x, _, _ = synthetic.gaussian_mixture(
        jax.random.PRNGKey(42), n_pts, k=k, n=feat, c=6.0, return_labels=True
    )
    kn = k * feat
    frontier = {}
    for mult in (2, 4, 10):
        m = mult * kn
        for name in ("amp", "clompr", "sketch_shift"):
            cfg = ckm_mod.CKMConfig(k=k, m=m, decoder=name, replicates=3)

            def run_fit():
                return ckm_mod.fit(jax.random.PRNGKey(0), x, cfg)

            res, _ = timed(run_fit)
            res, t = timed(run_fit)  # warm (jit cached)
            sse_val = float(ckm_mod.sse(x, res.centroids)) / n_pts
            frontier[(name, mult)] = sse_val
            results[f"frontier_{name}_m{mult}kn"] = {
                "decoder": name,
                "m": m,
                "m_over_kn": mult,
                "replicates": 3,
                "sse_per_n": sse_val,
                "sketch_cost": float(res.cost),
                "fit_seconds": t,
            }
            csv_line(
                f"frontier_{name}_m{m}_N{n_pts}_K{k}",
                t,
                f"sse_per_n={sse_val:.4f}",
            )
    rel = frontier[("amp", 4)] / frontier[("clompr", 10)]
    results["frontier_amp_m4kn"]["sse_vs_clompr_10kn"] = rel
    assert rel <= 1.05, frontier
    return results


def run_ingest(results: dict, n_batches=40, batch=4096, feat=16, m=512, k=3):
    """Async-vs-sync ``fit_streaming`` on the blobs streaming benchmark.

    The stream models the paper's target regime — batches arriving from host
    I/O: **numpy (host-memory) buffers** behind a per-batch latency
    (``data.pipeline.with_latency``) calibrated to 2x the measured per-batch
    sketch time (an I/O-bound stream, the common case for a 10^7-point pass
    over storage; host buffers also keep the producer off the device stream,
    like a real reader).  What is compared is the two *backpressure
    policies* of ``fit_streaming``: sync = strict fold-block-discard (one
    resident batch, the O(m) working-set contract), which pays
    produce+compute serially; async = a bounded double buffer
    (``CKMConfig.ingest="async"``) that hides sketch compute under the
    producer's I/O wait at prefetch+2 resident batches.  (Letting JAX's
    async dispatch run unthrottled would also overlap, but with a
    runtime-defined in-flight window of dozens of batches — not a streaming
    memory policy.)  Expected speedup (P+C+D)/(P+D) ~= 1.4 at P=2C with a
    small decode D.  Acceptance (ISSUE 4): async >= 1.3x faster, identical
    sketches.
    """
    from repro.data import synthetic

    key = jax.random.PRNGKey(5)
    x, _, _ = synthetic.gaussian_mixture(
        key, n_batches * batch, k=k, n=feat, c=6.0, return_labels=True
    )
    x = np.asarray(x)  # host-resident, as if read from storage
    cfg = ckm_mod.CKMConfig(
        k=k, m=m, sigma2=1.0,  # fixed scale: the benchmark times the sketch
        decoder="sketch_shift",  # cheapest registered decode — the benchmark
        shift_steps=20, shift_polish_steps=40, nnls_iters=25,  # times ingest
        sketch_chunk=batch,
    )

    # Calibrate: mean per-batch update time of the engine's real CPU path
    # under streaming backpressure (block per batch, like the fit).
    w = jax.random.normal(jax.random.PRNGKey(6), (feat, m)) * 0.5
    eng = eng_mod.SketchEngine(w, "xla", chunk=batch)
    state = eng.update(eng.init_state(), x[:batch])  # warm the jit caches
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for i in range(4):
        state = eng.update(state, x[i * batch : (i + 1) * batch])
        jax.block_until_ready(state)
    t_batch = (time.perf_counter() - t0) / 4

    def source():
        return pipe.with_latency(pipe.chunked(x, batch), 2.0 * t_batch)

    # Overlap efficiency of the ingest pipeline itself (engine-level).
    _, stats = ingest_mod.ingest_stream(eng, source(), prefetch=2)

    key_fit = jax.random.PRNGKey(7)
    # Pre-warm the decode jit cache on the same (m, k) shapes so neither
    # timed run pays compilation (the sync run would otherwise eat it and
    # inflate the speedup).
    ckm_mod.fit_streaming(key_fit, pipe.chunked(x[: 2 * batch], batch), cfg)
    res_sync, t_sync = timed(
        ckm_mod.fit_streaming, key_fit, source(), cfg
    )
    res_async, t_async = timed(
        ckm_mod.fit_streaming, key_fit, source(),
        dataclasses.replace(cfg, ingest="async"),
    )
    assert bool(jnp.array_equal(res_sync.sketch, res_async.sketch)), (
        "async ingest changed the sketch"
    )
    speedup = t_sync / t_async
    results["ingest_async"] = {
        "n_batches": n_batches,
        "batch": batch,
        "per_batch_latency_s": 2.0 * t_batch,
        "sync_fit_seconds": t_sync,
        "async_fit_seconds": t_async,
        "speedup": speedup,
        "overlap_efficiency": stats.overlap_efficiency,
        "produce_s": stats.produce_s,
        "compute_s": stats.compute_s,
        "consumer_wait_s": stats.consumer_wait_s,
    }
    results["ingest_async"]["meets_1p3x_acceptance"] = bool(speedup >= 1.3)
    csv_line(
        f"ingest_async_B{n_batches}x{batch}_m{m}", t_async,
        f"sync={t_sync:.2f}s;speedup=x{speedup:.2f};"
        f"overlap={stats.overlap_efficiency:.2f}",
    )
    return results


def run_freq_ops(results: dict, n_pts=4096, feat=2048, m=2048, sigma2=1.0):
    """Frequency-operator rows (ISSUE 5): per-operator sketch throughput,
    state/wire bytes, and the roofline sanity check of the structured path.

    - correctness: the structured fast transform vs the explicit-Hadamard
      matmul oracle (``kernels.ref.structured_project_ref``);
    - throughput: warm jitted wall time of the projection (``op.apply``) and
      of the full engine sketch, per operator, on the real CPU path — the
      acceptance row is the measured apply speedup at ``n >= 512``
      (``feat=2048`` here; on CPU the crossover sits near n ~ 2k, on TPU the
      fused WHT kernel moves it far lower);
    - state bytes: operator leaves (what a by-value carry ships) and the O(1)
      ``spec()`` (what engine state/checkpoints/broadcast actually carry)
      vs the 4·n·m dense matrix — proving the spec-not-matrix acceptance;
    - roofline: ``utils.roofline.freq_transform_model`` cross-checked
      against the *compiled* HLO dot-flops of both projections
      (``utils.hlo.analyze_compiled``), asserting the structured path's
      arithmetic-intensity model (sub-dense flops, dot-flops ratio within
      2x of the model's);
    - quality: structured CKM SSE within 5% of dense on the fig-1 blobs
      protocol, decoded from the same config/keys.
    """
    from repro.core import freq_ops as fo
    from repro.data import synthetic
    from repro.utils import hlo as hlo_mod
    from repro.utils import roofline as roof

    key = jax.random.PRNGKey(21)
    kx, kf = jax.random.split(key)
    x = jax.random.normal(kx, (n_pts, feat))
    ops_by_name = {
        name: fo.make_operator(name, kf, m, feat, sigma2)
        for name in fo.available_freq_ops()
    }

    # Correctness of the fast transform vs an independent dense oracle.
    s_op = ops_by_name["structured"]
    sl = 256
    ref_proj = ref.structured_project_ref(x[:sl], s_op.diags, s_op.radii)[:, :m]
    got = s_op.apply(x[:sl])
    rel_err = float(
        jnp.max(jnp.abs(got - ref_proj)) / jnp.maximum(jnp.max(jnp.abs(ref_proj)), 1e-9)
    )
    assert rel_err < 1e-4, rel_err

    dense_matrix_bytes = 4 * feat * m
    times, flops = {}, {}
    for name, op in ops_by_name.items():
        apply_f = jax.jit(lambda xx, o=op: o.apply(xx))
        jax.block_until_ready(apply_f(x))
        _, t_apply = timed(apply_f, x)
        _, t_apply = timed(apply_f, x)  # warm
        eng = eng_mod.SketchEngine(op, "xla", chunk=n_pts)
        _, t_sk = timed(eng.sketch, x)
        _, t_sk = timed(eng.sketch, x)  # warm
        compiled = apply_f.lower(x).compile()
        hlo_flops = hlo_mod.analyze_compiled(compiled).flops
        times[name], flops[name] = t_apply, hlo_flops
        spec_bytes = fo.spec_wire_bytes(op.spec())
        results[f"freq_op_{name}"] = {
            "n_pts": n_pts, "n": feat, "m": m,
            "apply_seconds": t_apply,
            "sketch_seconds": t_sk,
            "points_per_second": n_pts / t_sk,
            "hlo_dot_flops": hlo_flops,
            "operator_state_bytes": op.state_bytes(),
            "spec_wire_bytes": spec_bytes,
            "dense_matrix_bytes": dense_matrix_bytes,
        }
        csv_line(
            f"freq_op_{name}_N{n_pts}_n{feat}_m{m}", t_sk,
            f"apply={t_apply*1e3:.0f}ms;state={op.state_bytes()}B;"
            f"spec={spec_bytes}B",
        )
        # Spec-not-matrix acceptance: the rebuild recipe every operator's
        # checkpoints/broadcast carry is O(1) — negligible next to the matrix.
        assert spec_bytes < 0.01 * dense_matrix_bytes, (name, spec_bytes)

    # Roofline sanity: model vs compiled-HLO dot flops.
    model = roof.freq_transform_model(n_pts, feat, m, s_op.d, s_op.nblocks)
    meas_ratio = flops["dense"] / max(flops["structured"], 1.0)
    results["freq_op_roofline"] = {
        **model,
        "hlo_flops_dense": flops["dense"],
        "hlo_flops_structured": flops["structured"],
        "hlo_flops_ratio": meas_ratio,
        "apply_speedup_structured": times["dense"] / times["structured"],
    }
    assert model["structured_flops"] < model["dense_flops"]
    # The compiled dot-flops must track the analytic model on both sides.
    assert 0.5 < flops["dense"] / model["dense_flops"] < 2.0, flops
    assert 0.5 < meas_ratio / model["flops_ratio"] < 2.0, (meas_ratio, model)
    # Measured throughput acceptance: the fast transform wins at this n.
    speedup = times["dense"] / times["structured"]
    results["freq_op_roofline"]["meets_speedup_acceptance"] = bool(speedup > 1.0)
    csv_line(
        f"freq_op_speedup_n{feat}", times["structured"],
        f"x{speedup:.2f};model_flops_x{model['flops_ratio']:.1f};"
        f"hlo_flops_x{meas_ratio:.1f}",
    )

    # Quality acceptance: structured CKM SSE within 5% of dense on the
    # fig-1 blobs protocol (same keys, same decode budget).
    xb, _, _ = synthetic.gaussian_mixture(
        jax.random.PRNGKey(11), 8192, k=5, n=4, c=6.0, return_labels=True
    )
    sses = {}
    for name in ops_by_name:
        cfg = ckm_mod.CKMConfig(k=5, freq_op=name)
        res = ckm_mod.fit(jax.random.PRNGKey(1), xb, cfg)
        sses[name] = float(ckm_mod.sse(xb, res.centroids)) / xb.shape[0]
    rel = sses["structured"] / sses["dense"]
    results["freq_op_sse"] = {**sses, "structured_vs_dense": rel}
    csv_line("freq_op_sse_blobs", 0.0, f"ratio={rel:.4f}")
    assert rel < 1.05, sses
    return results


def run_fleet(results: dict, n_tenants=1024, batch=32, feat=8, m=64):
    """Multi-tenant fleet row (ISSUE 7): stacked-vs-looped update throughput.

    The fleet ingests one aligned block — one ``(batch, n)`` batch per tenant
    — two ways: ONE vmapped ``FleetEngine.update`` dispatch over the stacked
    ``(T, ...)`` state, and a Python loop of T per-tenant ``SketchEngine``
    updates (the same trace the fleet vmaps, so the states must match
    bitwise).  Both paths are warm (jit caches populated) and timed on the
    real CPU execution path; the speedup is pure dispatch/batching win, which
    is the point — per-tenant serving cost is dominated by T Python+XLA
    dispatches, not by the O(batch·n·m) math.  Acceptance: >= 5x at T=1024.
    """
    from repro.core import fleet as fl

    specs = fl.fleet_specs(
        jax.random.PRNGKey(17), n_tenants, "dense", m, feat, 1.0
    )
    fleet = fl.FleetEngine(specs, chunk=batch)
    xs = jax.random.normal(jax.random.PRNGKey(18), (n_tenants, batch, feat))

    state0 = fleet.init_state()
    jax.block_until_ready(fleet.update(state0, xs))  # warm the vmapped jit
    state, t_stacked = timed(fleet.update, state0, xs)

    engines = [fleet.tenant_engine(t) for t in range(n_tenants)]
    inits = [e.init_state() for e in engines]
    jax.block_until_ready(engines[0].update(inits[0], xs[0]))  # warm

    def looped():
        return [
            e.update(s, xs[t]) for t, (e, s) in enumerate(zip(engines, inits))
        ]

    rows, t_looped = timed(looped)

    # Bitwise parity across the whole fleet: restack the looped rows and
    # compare every leaf (tests/test_fleet.py pins this per backend/flavour).
    ref_stack = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *rows)
    parity = all(
        bool(jnp.array_equal(a, b))
        for a, b in zip(
            jax.tree_util.tree_leaves(state),
            jax.tree_util.tree_leaves(ref_stack),
        )
    )
    assert parity, "stacked fleet update diverged from the per-tenant loop"

    speedup = t_looped / t_stacked
    results["fleet_update"] = {
        "n_tenants": n_tenants,
        "batch": batch,
        "n": feat,
        "m": m,
        "stacked_seconds": t_stacked,
        "looped_seconds": t_looped,
        "speedup": speedup,
        "bitwise_parity": parity,
        "fleet_state_bytes": fleet.state_bytes(),
        "meets_5x_acceptance": bool(speedup >= 5.0),
    }
    csv_line(
        f"fleet_update_T{n_tenants}_B{batch}_m{m}", t_stacked,
        f"looped={t_looped:.3f}s;speedup=x{speedup:.1f}",
    )
    return results


def run_fleet_shard(results: dict, n_tenants=1024, batch=32, feat=8, m=64,
                    devices=4):
    """Multi-device fleet sharding row (ISSUE 10): mesh-sharded vs
    single-device stacked update at T=1024.

    Runs in a subprocess with ``--xla_force_host_platform_device_count=4``
    (the flag must precede jax init) and measures three update paths, all
    warm:

    - ``single_device_seconds``: the unsharded stacked fleet, T=1024 rows on
      one device — the PR 7 baseline.
    - ``sharded_wall_seconds``: the same traffic through the mesh-sharded
      engine, 4 shards x 256 rows.
    - ``per_shard_block_seconds``: a T=256 stacked fleet on one device — the
      critical path ONE shard executes under 4-way sharding.

    Host-platform devices time-share the physical cores, so on a machine
    with fewer cores than shards the sharded *wall clock* cannot beat the
    single-device run no matter how the work is placed; the architectural
    speedup is ``single / per_shard_block`` (each device runs a T/P block
    concurrently), which is valid precisely because the compiled sharded
    update contains **zero cross-shard collectives** — the subprocess scans
    the HLO and the row records any found.  ``speedup_basis`` says which
    measurement backs the reported ``speedup``: real wall clock when the
    host has >= one core per shard, the per-shard critical path otherwise.
    Parity is never simulated: every tenant's sharded row is asserted
    bitwise equal to an isolated ``SketchEngine`` run, float and quantized.
    Acceptance: >= 2.5x at T=1024 over 4 devices.
    """
    import json
    import os
    import subprocess
    import sys
    import textwrap

    prog = textwrap.dedent(
        f"""
        import json, os, time
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}"
        )
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core import fleet as fl

        T, B, N, M, P = {n_tenants}, {batch}, {feat}, {m}, {devices}
        assert len(jax.devices()) == P
        specs = fl.fleet_specs(jax.random.PRNGKey(17), T, "dense", M, N, 1.0)
        xs = jax.random.normal(jax.random.PRNGKey(18), (T, B, N))

        def timeit(fn, *args):
            jax.block_until_ready(fn(*args))  # warm the jit cache
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            return time.perf_counter() - t0

        single = fl.FleetEngine(specs, chunk=B)
        t_single = timeit(single.update, single.init_state(), xs)

        sharded = fl.FleetEngine(specs, chunk=B, sharding="mesh",
                                 tenant_shards=P)
        s_state = sharded.init_state()
        t_wall = timeit(sharded.update, s_state, xs)

        hlo = sharded.mesh_update_hlo(s_state, xs).lower()
        collectives = [op for op in ("all-reduce", "all-gather",
                                     "collective-permute", "all-to-all")
                       if op in hlo]

        block = fl.FleetEngine(specs[: T // P], chunk=B)
        t_block = timeit(block.update, block.init_state(), xs[: T // P])

        def bitwise_vs_isolated(quant):
            quants = fl.fleet_quantizers(jax.random.PRNGKey(7), T, M, quant)
            eng = fl.FleetEngine(specs, chunk=B, quantizers=quants,
                                 sharding="mesh", tenant_shards=P)
            state = eng.update(eng.init_state(), xs)
            for t in range(T):
                e = eng.tenant_engine(t)
                iso = e.update(e.init_state(), xs[t])
                row = eng.tenant_state(state, t)
                if not all(bool(jnp.array_equal(a, b)) for a, b in zip(
                        jax.tree_util.tree_leaves(row),
                        jax.tree_util.tree_leaves(iso))):
                    return False
            return True

        print("RESULT " + json.dumps({{
            "single_device_seconds": t_single,
            "sharded_wall_seconds": t_wall,
            "per_shard_block_seconds": t_block,
            "hot_path_collectives": collectives,
            "bitwise_parity_float": bitwise_vs_isolated("none"),
            "bitwise_parity_quantized": bitwise_vs_isolated("1bit"),
        }}))
        """
    )
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", prog], env=env, capture_output=True,
        text=True, timeout=1800,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    child = json.loads(
        next(l for l in out.stdout.splitlines() if l.startswith("RESULT "))
        [len("RESULT "):]
    )

    host_cores = os.cpu_count() or 1
    wall_speedup = child["single_device_seconds"] / child["sharded_wall_seconds"]
    device_parallel_speedup = (
        child["single_device_seconds"] / child["per_shard_block_seconds"]
    )
    # One XLA host device per physical core is what makes the wall clock an
    # honest measure of device parallelism; below that, forced host devices
    # time-share cores and the per-shard critical path is the honest number
    # (backed by the zero-collective HLO: shards never wait on each other).
    basis = (
        "wall_clock" if host_cores >= devices else "per_device_critical_path"
    )
    speedup = wall_speedup if basis == "wall_clock" else device_parallel_speedup
    parity = (
        child["bitwise_parity_float"] and child["bitwise_parity_quantized"]
    )
    results["fleet_shard"] = {
        "n_tenants": n_tenants,
        "batch": batch,
        "n": feat,
        "m": m,
        "devices": devices,
        "host_cores": host_cores,
        **child,
        "wall_speedup": wall_speedup,
        "device_parallel_speedup": device_parallel_speedup,
        "speedup": speedup,
        "speedup_basis": basis,
        "meets_2p5x_acceptance": bool(
            speedup >= 2.5
            and parity
            and not child["hot_path_collectives"]
        ),
    }
    csv_line(
        f"fleet_shard_T{n_tenants}_P{devices}_m{m}",
        child["sharded_wall_seconds"],
        f"single={child['single_device_seconds']:.3f}s;"
        f"speedup=x{speedup:.1f}({basis});parity={parity}",
    )
    return results


def run_window(results: dict, n_tenants=256, batch=32, feat=8, m=64,
               buckets=8, steps=16, gamma=0.9):
    """Temporal-window row (ISSUE 9): windowed-vs-lifetime fleet update cost.

    The same aligned traffic — ``steps`` update blocks of one ``(batch, n)``
    batch per tenant — folds into a plain lifetime ``FleetEngine`` and into a
    ``SketchWindow`` ring (W buckets, advancing one tick per block) over a
    decayed fleet.  The windowed path pays the decayed fold (stamp/gamma
    bookkeeping + the fold-time ``gamma**dt`` scale) and the ring's O(1)
    host-side slot claim per update, but touches exactly ONE bucket — the
    other W-1 are merged on *read*, never copied on write.  Acceptance: the
    per-update wall clock stays <= 1.3x the lifetime fleet update.
    """
    from repro.core import fleet as fl
    from repro.core.window import SketchWindow

    specs = fl.fleet_specs(
        jax.random.PRNGKey(23), n_tenants, "dense", m, feat, 1.0
    )
    lifetime = fl.FleetEngine(specs, chunk=batch)
    windowed = SketchWindow(
        fl.FleetEngine(specs, chunk=batch, decay=gamma), buckets=buckets
    )
    xs = jax.random.normal(jax.random.PRNGKey(24), (n_tenants, batch, feat))

    def run_lifetime():
        s = lifetime.init_state()
        for _ in range(steps):
            s = lifetime.update(s, xs)
        return s

    def run_windowed():
        ws = windowed.init_state()
        for k in range(steps):
            ws = windowed.update(ws, xs, t=float(k))
        return ws.buckets  # the pytree timed() can block on

    _, t_life = timed(run_lifetime)  # first call pays compilation
    _, t_life = timed(run_lifetime)
    _, t_win = timed(run_windowed)
    ring, t_win = timed(run_windowed)

    ratio = t_win / t_life
    results["window_update"] = {
        "n_tenants": n_tenants,
        "batch": batch,
        "n": feat,
        "m": m,
        "window_buckets": buckets,
        "decay": gamma,
        "steps": steps,
        "lifetime_seconds_per_update": t_life / steps,
        "windowed_seconds_per_update": t_win / steps,
        "overhead_ratio": ratio,
        "ring_state_bytes": int(
            sum(
                leaf.size * leaf.dtype.itemsize
                for b in ring
                for leaf in jax.tree_util.tree_leaves(b)
            )
        ),
        "meets_1p3x_acceptance": bool(ratio <= 1.3),
    }
    csv_line(
        f"window_update_T{n_tenants}_W{buckets}_m{m}", t_win / steps,
        f"lifetime={t_life/steps*1e6:.1f}us;ratio=x{ratio:.2f}",
    )
    return results


def run_obs_overhead(
    results: dict, n_pts=4096, feat=16, m=1024, inner=40, trials=7
):
    """Disabled-telemetry tax on the hot path (ISSUE 8 acceptance).

    ``SketchEngine.update`` with telemetry OFF is one module-attribute read +
    branch in front of the raw fold; this row times the instrumented update
    against a direct ``_merge_states(state, _partial_state(batch))`` loop —
    the exact code the guard falls through to — min-of-``trials`` over
    ``inner``-call loops, the two paths alternated so machine-load drift
    cannot bias one side.  Acceptance: the guard costs <= 2%.
    """
    from repro import obs

    obs.disable()
    kx, kw = jax.random.split(jax.random.PRNGKey(23))
    x = jax.random.normal(kx, (n_pts, feat))
    w = jax.random.normal(kw, (feat, m))
    eng = eng_mod.SketchEngine(w, "xla")
    state0 = eng.init_state()

    def raw_step(s):
        return eng_mod._merge_states(s, eng._partial_state(x, None))

    def obs_step(s):
        return eng.update(s, x)

    jax.block_until_ready(raw_step(state0))  # compile both paths
    jax.block_until_ready(obs_step(state0))

    def trial(step):
        s = state0
        t0 = time.perf_counter()
        for _ in range(inner):
            s = step(s)
        jax.block_until_ready(s)
        return (time.perf_counter() - t0) / inner

    t_raw, t_obs = float("inf"), float("inf")
    for _ in range(trials):
        t_raw = min(t_raw, trial(raw_step))
        t_obs = min(t_obs, trial(obs_step))
    overhead = (t_obs - t_raw) / t_raw
    results["obs_overhead"] = {
        "n": feat,
        "m": m,
        "batch": n_pts,
        "raw_update_seconds": t_raw,
        "guarded_update_seconds": t_obs,
        "overhead_frac": overhead,
        "meets_2pct_acceptance": bool(overhead <= 0.02),
    }
    csv_line(
        f"obs_overhead_N{n_pts}_m{m}", t_obs,
        f"raw={t_raw*1e6:.1f}us;overhead={overhead*100:.2f}%",
    )
    return results


def run_topologies(results: dict, p=8, n_pts=16384, feat=16, m=1024):
    """Per-topology merge rows: latency of reducing ``p`` quantized partial
    states through every registered schedule, the alpha-beta wire cost model
    (bytes/device + serialized hops, float vs 1-bit states), and the bitwise
    acceptance — every topology finalizes the identical quantized sketch
    (int32 addition is exactly associative/commutative)."""
    key = jax.random.PRNGKey(13)
    kx, kw, kd = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n_pts, feat))
    w = jax.random.normal(kw, (feat, m)) * 0.5
    q = qz.make_quantizer(kd, m, "1bit")
    eng = eng_mod.SketchEngine(w, "xla", quantizer=q)
    shard = n_pts // p
    parts = [
        eng.update(eng.init_state(), x[i * shard : (i + 1) * shard])
        for i in range(p)
    ]
    jax.block_until_ready(parts)

    wire_1bit = qz.state_wire_bytes(m, shard, 1)
    wire_float = qz.state_wire_bytes(m, shard, None)
    finals = {}
    for name in available_topologies():
        merged, _ = timed(topo_mod.reduce_states, eng.merge, parts, name)
        merged, t = timed(topo_mod.reduce_states, eng.merge, parts, name)  # warm
        z, _, _ = eng.finalize(merged)
        finals[name] = (
            np.asarray(merged.qcos_acc),
            np.asarray(merged.qsin_acc),
            np.asarray(z),
        )
        cost_q = topo_mod.wire_cost_model(wire_1bit, p, name)
        cost_f = topo_mod.wire_cost_model(wire_float, p, name)
        results[f"topology_{name}"] = {
            "p": p,
            "merge_seconds": t,
            "hops": cost_q["hops"],
            "bytes_per_device_1bit": cost_q["bytes_per_device"],
            "bytes_per_device_float": cost_f["bytes_per_device"],
        }
        # User-registered topologies have no closed-form cost (None fields).
        fmt = lambda v: "?" if v is None else f"{v:.0f}"  # noqa: E731
        csv_line(
            f"topology_{name}_p{p}_m{m}", t,
            f"hops={cost_q['hops']};1bit_B={fmt(cost_q['bytes_per_device'])};"
            f"float_B={fmt(cost_f['bytes_per_device'])}",
        )
    names = list(finals)
    for other in names[1:]:
        same = all(
            np.array_equal(a, b) for a, b in zip(finals[names[0]], finals[other])
        )
        assert same, f"quantized merge/finalize differs: {names[0]} vs {other}"
    results["topology_bitwise_identical"] = {
        "topologies": names,
        "quantized_path": True,
        "finalized_sketch_bitwise": True,
    }
    return results


def run(full: bool = False):
    results = {}
    shapes = [(4096, 16, 1024), (16384, 10, 1000)] if not full else [
        (4096, 16, 1024), (65536, 10, 1000), (262144, 16, 2048)]
    for n_pts, feat, m in shapes:
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (n_pts, feat))
        w = jax.random.normal(kw, (feat, m))
        beta = jnp.full((n_pts,), 1.0 / n_pts)
        # interpret-mode equivalence on a slice (full interpret is slow)
        sl = slice(0, min(n_pts, 2048))
        w_op = fo.as_operator(w)  # kernel wrappers reject raw matrices (PR 6)
        zk = ops.fourier_sketch(x[sl], w_op, beta[sl] * (n_pts / 2048),
                                interpret=True, block_n=256, block_m=256)
        ck, sk_ = ref.fourier_sketch_ref(x[sl], w, beta[sl] * (n_pts / 2048))
        err = float(jnp.max(jnp.abs(zk - jnp.concatenate([ck, -sk_]))))
        # jnp (unfused) wall time — the real CPU path
        f = jax.jit(lambda x, w, b: ref.fourier_sketch_ref(x, w, b))
        _, t_ref = timed(f, x, w, beta)
        _, t_ref = timed(f, x, w, beta)  # warm
        # traffic model (f32): unfused writes+reads the (N, m) projection 3x
        unfused = 4 * (n_pts * feat + feat * m + 3 * n_pts * m + 2 * m)
        fused = 4 * (n_pts * feat + feat * m + 2 * m)
        name = f"sketch_N{n_pts}_n{feat}_m{m}"
        results[name] = {
            "interpret_max_err": err,
            "jnp_seconds": t_ref,
            "bytes_unfused": unfused,
            "bytes_fused": fused,
            "traffic_reduction": unfused / fused,
        }
        csv_line(name, t_ref, f"err={err:.2e};traffic_x{unfused/fused:.1f}")
        assert err < 1e-3
    # assign_argmin
    for n_pts, feat, k in [(16384, 16, 64), (65536, 10, 10)]:
        kx, kc = jax.random.split(jax.random.PRNGKey(1))
        x = jax.random.normal(kx, (n_pts, feat))
        c = jax.random.normal(kc, (k, feat))
        sl = slice(0, 2048)
        ik, dk = ops.assign_argmin(x[sl], c, interpret=True, block_n=256)
        ir, dr = ref.assign_argmin_ref(x[sl], c)
        agree = float(jnp.mean((ik == ir).astype(jnp.float32)))
        f = jax.jit(lambda x, c: ref.assign_argmin_ref(x, c))
        _, t_ref = timed(f, x, c)
        _, t_ref = timed(f, x, c)
        unfused = 4 * (n_pts * feat + k * feat + 2 * n_pts * k + 2 * n_pts)
        fused = 4 * (n_pts * feat + k * feat + 2 * n_pts)
        name = f"assign_N{n_pts}_n{feat}_K{k}"
        results[name] = {
            "interpret_agreement": agree,
            "jnp_seconds": t_ref,
            "traffic_reduction": unfused / fused,
        }
        csv_line(name, t_ref, f"agree={agree:.4f};traffic_x{unfused/fused:.1f}")
        assert agree == 1.0
    run_engine_backends(results)
    run_quantized(results)
    run_decoders(results)
    run_amp(results)
    run_freq_ops(results)
    run_ingest(results)
    run_topologies(results)
    run_fleet(results)
    run_fleet_shard(results)
    run_window(results)
    run_obs_overhead(results)
    save("kernels", results)
    # Acceptance checked AFTER save so a perf flake on a loaded machine
    # cannot discard the other rows computed in the same invocation.
    ia = results["ingest_async"]
    assert ia["meets_1p3x_acceptance"], (
        f"async ingest speedup {ia['speedup']:.2f}x < 1.3x acceptance "
        f"(sync {ia['sync_fit_seconds']:.2f}s, "
        f"async {ia['async_fit_seconds']:.2f}s)"
    )
    fu = results["fleet_update"]
    assert fu["meets_5x_acceptance"], (
        f"fleet stacked update speedup {fu['speedup']:.1f}x < 5x acceptance "
        f"(stacked {fu['stacked_seconds']:.3f}s, "
        f"looped {fu['looped_seconds']:.3f}s)"
    )
    fs = results["fleet_shard"]
    assert fs["meets_2p5x_acceptance"], (
        f"sharded fleet update speedup {fs['speedup']:.2f}x "
        f"({fs['speedup_basis']}) < 2.5x acceptance, or parity/collective "
        f"check failed: parity_float={fs['bitwise_parity_float']} "
        f"parity_quantized={fs['bitwise_parity_quantized']} "
        f"collectives={fs['hot_path_collectives']}"
    )
    wu = results["window_update"]
    assert wu["meets_1p3x_acceptance"], (
        f"windowed fleet update overhead {wu['overhead_ratio']:.2f}x > 1.3x "
        f"acceptance (lifetime "
        f"{wu['lifetime_seconds_per_update']*1e6:.1f}us/update, windowed "
        f"{wu['windowed_seconds_per_update']*1e6:.1f}us/update)"
    )
    oo = results["obs_overhead"]
    assert oo["meets_2pct_acceptance"], (
        f"disabled-telemetry engine.update overhead "
        f"{oo['overhead_frac']*100:.2f}% > 2% acceptance "
        f"(raw {oo['raw_update_seconds']*1e6:.1f}us, "
        f"guarded {oo['guarded_update_seconds']*1e6:.1f}us)"
    )
    return results


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
