"""Paper Fig. 4 — time/memory of CKM vs kmeans as N grows.

Claims: given the sketch, CKM's decode time and working memory are
INDEPENDENT of N; kmeans' grow linearly; at the paper's largest N, one CKM
run beats kmeans x5 by ~two orders of magnitude.  Container scale: N up to
1e6 (paper: 1e7) — the N-independence claim is the scale-free one.

Memory is reported analytically (bytes actually required by each algorithm's
working set: the sketch + frequencies vs the full dataset), matching the
paper's "relative memory" panel.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_line, save, timed
from repro.core import ckm as ckm_mod
from repro.core import lloyd as lloyd_mod
from repro.data import synthetic


def run(full: bool = False):
    k, n, m = 10, 10, 1000
    sizes = (10_000, 100_000, 1_000_000) if full else (10_000, 100_000, 300_000)
    results: dict = {"sizes": list(sizes), "k": k, "n": n, "m": m}
    cfg = ckm_mod.CKMConfig(k=k, m=m)
    for n_points in sizes:
        kd, kc, kl = jax.random.split(jax.random.PRNGKey(5), 3)
        x = synthetic.gaussian_mixture(kd, n_points, k, n)
        # sketch (one pass over X)
        (z_pack), t_sketch = timed(ckm_mod.compute_sketch, kc, x, cfg)
        z, w, s2, (lo, hi) = z_pack
        # CKM decode: data-independent
        (_dec), t_decode = timed(
            ckm_mod.decode_sketch, jax.random.PRNGKey(6), z, w, lo, hi, cfg
        )
        cents, _, _ = _dec
        sse_ckm = float(ckm_mod.sse(x, cents))
        # kmeans x1 and x5
        (l1), t_km1 = timed(
            lloyd_mod.kmeans, kl, x, lloyd_mod.LloydConfig(k=k, init="range")
        )
        (l5), t_km5 = timed(
            lloyd_mod.kmeans, kl, x,
            lloyd_mod.LloydConfig(k=k, replicates=5, init="range"),
        )
        mem_ckm = (2 * m + n * m + 4 * n) * 4  # sketch + freqs + bounds (B)
        mem_km = n_points * n * 4  # kmeans must hold the dataset
        results[str(n_points)] = {
            "t_sketch": t_sketch, "t_ckm_decode": t_decode,
            "t_km1": t_km1, "t_km5": t_km5,
            "rel_sse_vs_km5": sse_ckm / float(l5.sse),
            "mem_ckm_bytes": mem_ckm, "mem_km_bytes": mem_km,
        }
        csv_line(
            f"fig4_N{n_points}", t_decode,
            f"decode={t_decode:.2f}s;km5={t_km5:.2f}s;"
            f"speedup_vs_km5={t_km5/t_decode:.1f}x;"
            f"mem_ratio={mem_km/mem_ckm:.1f}x",
        )
    t0 = results[str(sizes[0])]["t_ckm_decode"]
    t1 = results[str(sizes[-1])]["t_ckm_decode"]
    results["claim_decode_time_n_independent"] = bool(t1 < 2.0 * t0)
    results["claim_faster_than_km5_at_largest_n"] = bool(
        results[str(sizes[-1])]["t_km5"] > results[str(sizes[-1])]["t_ckm_decode"]
    )
    save("fig4_scaling", results)
    return results


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
