"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import freq_ops as fo
from repro.kernels import ops, ref


def _data(seed, n_pts, feat, m):
    key = jax.random.PRNGKey(seed)
    kx, kw, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n_pts, feat), jnp.float32) * 2.0
    w = jax.random.normal(kw, (feat, m), jnp.float32)
    beta = jax.random.uniform(kb, (n_pts,), jnp.float32)
    return x, w, beta


class TestFourierSketchKernel:
    @pytest.mark.parametrize(
        "n_pts,feat,m",
        [
            (128, 8, 128),  # exactly aligned
            (100, 10, 130),  # ragged everywhere
            (1, 3, 7),  # degenerate small
            (2048, 16, 512),  # multiple grid steps both axes
            (513, 1, 1),  # single feature / frequency
            (333, 24, 257),
        ],
    )
    def test_matches_ref(self, n_pts, feat, m):
        x, w, beta = _data(0, n_pts, feat, m)
        z = ops.fourier_sketch(
            x, fo.as_operator(w), beta, block_n=128, block_m=128, interpret=True
        )
        cos_ref, sin_ref = ref.fourier_sketch_ref(x, w, beta)
        np.testing.assert_allclose(np.asarray(z[:m]), np.asarray(cos_ref), atol=1e-4)
        np.testing.assert_allclose(np.asarray(z[m:]), np.asarray(-sin_ref), atol=1e-4)

    def test_matches_core_sketch(self):
        """Kernel is a drop-in for core.sketch.sketch (same stacked-real)."""
        from repro.core import sketch as sk

        x, w, _ = _data(1, 400, 6, 64)
        z_kernel = ops.fourier_sketch(
            x, fo.as_operator(w), interpret=True, block_n=128, block_m=128
        )
        z_core = sk.sketch(x, w)
        np.testing.assert_allclose(np.asarray(z_kernel), np.asarray(z_core), atol=1e-4)

    @pytest.mark.parametrize("block_n,block_m", [(8, 128), (64, 128), (256, 512)])
    def test_block_shape_invariance(self, block_n, block_m):
        x, w, beta = _data(2, 300, 12, 200)
        z = ops.fourier_sketch(
            x, fo.as_operator(w), beta, block_n=block_n, block_m=block_m,
            interpret=True,
        )
        cos_ref, sin_ref = ref.fourier_sketch_ref(x, w, beta)
        np.testing.assert_allclose(np.asarray(z[:200]), np.asarray(cos_ref), atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_input_dtypes(self, dtype):
        """Inputs in bf16 are upcast to f32 accumulate in the wrapper."""
        x, w, beta = _data(3, 256, 8, 128)
        z = ops.fourier_sketch(
            x.astype(dtype), fo.as_operator(w.astype(dtype)), beta,
            interpret=True, block_n=128, block_m=128,
        )
        cos_ref, _ = ref.fourier_sketch_ref(x.astype(dtype), w.astype(dtype), beta)
        atol = 1e-4 if dtype == jnp.float32 else 0.3
        np.testing.assert_allclose(np.asarray(z[:128]), np.asarray(cos_ref), atol=atol)


class TestAssignArgminKernel:
    @pytest.mark.parametrize(
        "n_pts,feat,k",
        [
            (128, 8, 8),
            (100, 10, 10),  # ragged
            (1, 4, 3),
            (2048, 16, 64),
            (777, 5, 13),
        ],
    )
    def test_matches_ref(self, n_pts, feat, k):
        key = jax.random.PRNGKey(10)
        kx, kc = jax.random.split(key)
        x = jax.random.normal(kx, (n_pts, feat)) * 3
        c = jax.random.normal(kc, (k, feat)) * 3
        idx, dist = ops.assign_argmin(x, c, block_n=128, interpret=True)
        idx_ref, dist_ref = ref.assign_argmin_ref(x, c)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
        np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_ref), atol=1e-3)

    def test_matches_lloyd_assign(self):
        """Kernel agrees with the Lloyd-Max internal assignment."""
        from repro.core.lloyd import _assign

        key = jax.random.PRNGKey(11)
        kx, kc = jax.random.split(key)
        x = jax.random.normal(kx, (500, 6))
        c = jax.random.normal(kc, (9, 6))
        idx, dist = ops.assign_argmin(x, c, interpret=True)
        idx_ref, dist_ref = _assign(x, c)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
        np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_ref), atol=1e-3)

    def test_ties_resolve_to_lowest_index(self):
        """argmin tie-breaking must match jnp (first minimum wins)."""
        x = jnp.zeros((16, 4))
        c = jnp.zeros((5, 4))  # all centroids identical -> all ties
        idx, _ = ops.assign_argmin(x, c, interpret=True)
        np.testing.assert_array_equal(np.asarray(idx), np.zeros(16, np.int32))


class TestSketchShiftKernel:
    def _problem(self, seed, p_cand, feat, m):
        key = jax.random.PRNGKey(seed)
        kc, kw, kz = jax.random.split(key, 3)
        c = jax.random.normal(kc, (p_cand, feat)) * 2.0
        w = jax.random.normal(kw, (feat, m)) * 0.7
        z = jax.random.normal(kz, (2 * m,)) * 0.3
        return c, fo.as_operator(w), z

    @pytest.mark.parametrize(
        "p_cand,feat,m",
        [
            (8, 8, 128),  # exactly aligned
            (37, 5, 300),  # ragged everywhere
            (1, 2, 7),  # degenerate small
            (40, 4, 200),  # the decoder's default swarm shape
        ],
    )
    def test_pallas_matches_ref(self, p_cand, feat, m):
        c, w, z = self._problem(0, p_cand, feat, m)
        f, g = ops.sketch_shift_scores(
            c, w, z, impl="pallas", block_p=8, block_m=128, interpret=True
        )
        f_ref, g_ref = ref.sketch_shift_scores_ref(c, w.materialize(), z)
        np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-5)

    def test_xla_matches_ref(self):
        """The decoder's default impl vs the complex-arithmetic oracle."""
        c, w, z = self._problem(1, 25, 6, 250)
        f, g = ops.sketch_shift_scores(c, w, z, impl="xla")
        f_ref, g_ref = ref.sketch_shift_scores_ref(c, w.materialize(), z)
        np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-5)

    def test_gradient_is_density_gradient(self):
        """g must be the autodiff gradient of f (the op returns both fused)."""
        c, w, z = self._problem(2, 6, 4, 96)

        def f_single(ci):
            f, _ = ops.sketch_shift_scores(ci[None, :], w, z, impl="xla")
            return f[0]

        g_auto = jax.vmap(jax.grad(f_single))(c)
        _, g = ops.sketch_shift_scores(c, w, z, impl="xla")
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_auto), atol=1e-5)

    def test_unknown_impl_raises(self):
        c, w, z = self._problem(3, 4, 3, 64)
        with pytest.raises(ValueError, match="impl"):
            ops.sketch_shift_scores(c, w, z, impl="cuda")


class TestFlashAttentionKernel:
    @pytest.mark.parametrize(
        "b,s,h,kv,hd,causal,window",
        [
            (1, 128, 4, 4, 32, True, 0),     # MHA causal
            (2, 128, 4, 2, 32, True, 0),     # GQA rep=2
            (1, 256, 4, 1, 32, True, 64),    # MQA + sliding window
            (1, 96, 2, 2, 16, True, 0),      # ragged seq (padding path)
            (1, 128, 2, 2, 32, False, 0),    # non-causal (encoder)
        ],
    )
    def test_matches_ref(self, b, s, h, kv, hd, causal, window):
        key = jax.random.PRNGKey(0)
        kq, kk, kv_ = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, s, h, hd), jnp.float32)
        k = jax.random.normal(kk, (b, s, kv, hd), jnp.float32)
        v = jax.random.normal(kv_, (b, s, kv, hd), jnp.float32)
        out = ops.flash_attention(
            q, k, v, causal=causal, window=window,
            block_q=64, block_k=64, interpret=True,
        )
        rep = h // kv
        qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        kf = k.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
        vf = v.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
        expect = ref.flash_attention_ref(qf, kf, vf, rep, causal, window)
        expect = expect.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b, s, h * hd)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), atol=2e-3, rtol=1e-2
        )

    def test_matches_model_attention(self):
        """Flash output == the model's q-chunked XLA attention (post-rope)."""
        from repro.models import layers as L

        dims = L.AttnDims(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                          q_block=32)
        params = L.init_attention(jax.random.PRNGKey(0), dims)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
        ref_out = L.attention_apply(params, dims, x, pos)
        q, k, v = L._qkv(params, dims, x, pos)
        flash = ops.flash_attention(q, k, v, causal=True, block_q=32,
                                    block_k=32, interpret=True)
        flash = flash @ params["wo"]
        np.testing.assert_allclose(
            np.asarray(flash), np.asarray(ref_out), atol=2e-3, rtol=1e-2
        )

    def test_bf16(self):
        key = jax.random.PRNGKey(2)
        kq, kk, kv_ = jax.random.split(key, 3)
        q = jax.random.normal(kq, (1, 128, 2, 32), jnp.bfloat16)
        k = jax.random.normal(kk, (1, 128, 2, 32), jnp.bfloat16)
        v = jax.random.normal(kv_, (1, 128, 2, 32), jnp.bfloat16)
        out = ops.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
        assert out.dtype == jnp.bfloat16
        qf = q.transpose(0, 2, 1, 3).reshape(2, 128, 32)
        expect = ref.flash_attention_ref(
            qf,
            k.transpose(0, 2, 1, 3).reshape(2, 128, 32),
            v.transpose(0, 2, 1, 3).reshape(2, 128, 32),
            1, True, 0,
        )
        np.testing.assert_allclose(
            np.asarray(out[0]).reshape(128, 2, 32).transpose(1, 0, 2).astype(np.float32),
            np.asarray(expect).astype(np.float32),
            atol=3e-2, rtol=3e-2,
        )
