"""SketchEngine: monoid laws, backend parity, streaming end-to-end.

The engine's contract (core/engine.py) is that the sketch state is a
commutative monoid and every backend computes the same sketch.  The property
tests draw arbitrary batch splits / merge orders; the parity tests pin the
three backends (pallas in interpret mode on CPU) to the reference
``core.sketch.sketch`` within 1e-4.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import ckm as ckm_mod
from repro.core import engine as eng_mod
from repro.core import frequencies as fq
from repro.core import sketch as sk
from repro.data import pipeline as pipe


def _data(seed, npts=400, n=4, m=24):
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (npts, n)) * 2.0
    w = fq.draw_frequencies(kw, m, n, 1.0)
    return x, w


class TestMonoidLaws:
    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        cut_a=st.integers(1, 197),
        cut_b=st.integers(199, 398),
    )
    def test_merge_associative_and_commutative(self, seed, cut_a, cut_b):
        """(a+b)+c == a+(b+c) and a+b == b+a for arbitrary 3-way splits."""
        x, w = _data(seed)
        e = eng_mod.SketchEngine(w, "xla", chunk=64)
        parts = [x[:cut_a], x[cut_a:cut_b], x[cut_b:]]
        a, b, c = (e.update(e.init_state(), p) for p in parts)
        left = e.merge(e.merge(a, b), c)
        right = e.merge(a, e.merge(b, c))
        for zl, zr in zip(e.finalize(left), e.finalize(right)):
            np.testing.assert_allclose(np.asarray(zl), np.asarray(zr), atol=1e-5)
        ab, ba = e.merge(a, b), e.merge(b, a)
        for zl, zr in zip(e.finalize(ab), e.finalize(ba)):
            np.testing.assert_allclose(np.asarray(zl), np.asarray(zr), atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_chunks=st.integers(1, 9))
    def test_update_splits_equal_one_shot_sketch(self, seed, n_chunks):
        """update-then-finalize over any batch split == core.sketch.sketch."""
        x, w = _data(seed)
        e = eng_mod.SketchEngine(w, "xla", chunk=128)
        size = max(1, x.shape[0] // n_chunks)
        state = e.init_state()
        for batch in pipe.chunked(x, size):
            state = e.update(state, batch)
        z, lo, hi = e.finalize(state)
        np.testing.assert_allclose(
            np.asarray(z), np.asarray(sk.sketch(x, w)), atol=1e-4
        )
        np.testing.assert_allclose(np.asarray(lo), np.asarray(x.min(0)), atol=1e-6)
        np.testing.assert_allclose(np.asarray(hi), np.asarray(x.max(0)), atol=1e-6)

    def test_identity_element(self, rng):
        x, w = _data(3)
        e = eng_mod.SketchEngine(w, "xla")
        s = e.update(e.init_state(), x)
        for combined in (e.merge(s, e.init_state()), e.merge(e.init_state(), s)):
            for za, zb in zip(e.finalize(combined), e.finalize(s)):
                np.testing.assert_allclose(np.asarray(za), np.asarray(zb))

    def test_weighted_updates(self, rng):
        """Engine with explicit weights == weighted core sketch."""
        x, w = _data(7, npts=200)
        kb = jax.random.PRNGKey(11)
        beta = jax.random.uniform(kb, (200,), minval=0.1)
        e = eng_mod.SketchEngine(w, "xla")
        s = e.update(e.init_state(), x[:90], beta[:90])
        s = e.update(s, x[90:], beta[90:])
        z, *_ = e.finalize(s)
        ref = sk.sketch(x, w, weights=beta / jnp.sum(beta))
        np.testing.assert_allclose(np.asarray(z), np.asarray(ref), atol=1e-4)


class TestZeroWeightFinalize:
    """Regression (PR 6): finalize divides by ``weight_sum``; an empty stream
    (or an all-zero-weight shard) must produce the *zero sketch* — explicitly
    guarded, not left to ``0 / denom-floor`` luck — for the float and the
    quantized state flavours alike."""

    def _engines(self, quantized):
        from repro.core import quantize as qz

        _, w = _data(5, npts=8, m=24)
        q = (
            qz.make_quantizer(jax.random.PRNGKey(3), 24, "1bit")
            if quantized
            else None
        )
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        return {
            "xla": eng_mod.SketchEngine(w, "xla", quantizer=q),
            "pallas": eng_mod.SketchEngine(
                w, "pallas", block_n=128, block_m=128, quantizer=q
            ),
            "sharded": eng_mod.SketchEngine(
                w, "sharded", mesh=mesh, quantizer=q
            ),
        }

    @pytest.mark.parametrize("quantized", [False, True], ids=["float", "1bit"])
    def test_empty_stream_finalizes_to_zero_sketch(self, quantized):
        for name, e in self._engines(quantized).items():
            z, _, _ = e.finalize(e.init_state())
            np.testing.assert_array_equal(
                np.asarray(z), np.zeros(48, np.float32), err_msg=name
            )

    def test_zero_weight_updates_finalize_to_zero_sketch(self):
        # Float states only: the quantized flavour rejects per-point weights
        # (integer counts), so its zero-weight case is the empty stream above.
        x, _ = _data(5, npts=64, m=24)
        for name, e in self._engines(False).items():
            if name == "sharded":
                continue  # shard_points needs >= data-axis rows; covered above
            s = e.update(e.init_state(), x, jnp.zeros((64,)))
            z, _, _ = e.finalize(s)
            np.testing.assert_array_equal(
                np.asarray(z), np.zeros(48, np.float32), err_msg=name
            )
            assert float(getattr(s, "weight_sum")) == 0.0


class TestBackendParity:
    def test_pallas_matches_xla_within_1e4(self):
        """Acceptance: pallas (interpret on CPU) == xla backend within 1e-4."""
        x, w = _data(0, npts=777, n=6, m=100)  # ragged N, unaligned m
        z_x, lo_x, hi_x = eng_mod.SketchEngine(w, "xla").sketch(x)
        z_p, lo_p, hi_p = eng_mod.SketchEngine(
            w, "pallas", block_n=256, block_m=128
        ).sketch(x)
        np.testing.assert_allclose(np.asarray(z_p), np.asarray(z_x), atol=1e-4)
        np.testing.assert_allclose(np.asarray(lo_p), np.asarray(lo_x), atol=1e-6)
        np.testing.assert_allclose(np.asarray(hi_p), np.asarray(hi_x), atol=1e-6)

    def test_all_backends_match_reference_sketch(self):
        """Acceptance: every backend == core.sketch.sketch within 1e-4
        (sharded runs in a subprocess with a forced 8-device host platform)."""
        import os
        import subprocess
        import sys
        import textwrap

        prog = textwrap.dedent(
            """
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, numpy as np
            from repro.core import engine as eng_mod
            from repro.core import frequencies as fq
            from repro.core import sketch as sk

            key = jax.random.PRNGKey(0)
            kx, kw = jax.random.split(key)
            x = jax.random.normal(kx, (4096, 6))
            w = fq.draw_frequencies(kw, 48, 6, 1.0)
            z_ref = np.asarray(sk.sketch(x, w))

            mesh = jax.make_mesh((4, 2), ("data", "model"))
            engines = {
                "xla": eng_mod.SketchEngine(w, "xla", chunk=512),
                "pallas": eng_mod.SketchEngine(w, "pallas", block_n=512,
                                               block_m=128),
                "sharded": eng_mod.SketchEngine(w, "sharded", mesh=mesh,
                                                chunk=512),
            }
            for name, e in engines.items():
                xin = e.shard_points(x) if name == "sharded" else x
                z, lo, hi = e.sketch(xin)
                err = float(np.max(np.abs(np.asarray(z) - z_ref)))
                assert err < 1e-4, (name, err)
                np.testing.assert_allclose(np.asarray(lo), np.asarray(x.min(0)),
                                           atol=1e-6)
            # Ragged streaming through the sharded backend: tail chunks not
            # divisible by the data-axis extent are zero-weight padded.
            from repro.data.pipeline import chunked
            e = engines["sharded"]
            z, lo, hi = e.sketch_stream(chunked(x[:4003], 1000))
            err = float(np.max(np.abs(
                np.asarray(z) - np.asarray(sk.sketch(x[:4003], w)))))
            assert err < 1e-4, ("sharded-ragged", err)
            np.testing.assert_allclose(np.asarray(lo),
                                       np.asarray(x[:4003].min(0)), atol=1e-6)
            print("OK")
            """
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-c", prog], env=env, capture_output=True,
            text=True, timeout=300,
        )
        assert out.returncode == 0, out.stderr[-3000:]
        assert "OK" in out.stdout

    def test_bad_backend_rejected(self):
        _, w = _data(0, npts=8)
        with pytest.raises(ValueError):
            eng_mod.SketchEngine(w, "tpu9000")
        with pytest.raises(ValueError):
            eng_mod.SketchEngine(w, "sharded")  # no mesh


@pytest.mark.slow
class TestStreamingCKM:
    def test_fit_streaming_recovers_blobs(self, gaussian_blobs):
        """Acceptance: one-pass fit over a chunked iterator localises every
        true mean (Hungarian-matched error < 1.0), like in-memory fit."""
        x, _, means = gaussian_blobs
        cfg = ckm_mod.CKMConfig(k=5)
        res = ckm_mod.fit_streaming(
            jax.random.PRNGKey(0), pipe.chunked(x, 1000), cfg
        )
        d = np.linalg.norm(
            np.asarray(means)[:, None] - np.asarray(res.centroids)[None], axis=-1
        ).copy()
        errs = []
        for _ in range(means.shape[0]):
            i, j = np.unravel_index(np.argmin(d), d.shape)
            errs.append(d[i, j])
            d[i, :] = np.inf
            d[:, j] = np.inf
        assert np.all(np.array(errs) < 1.0), errs

    def test_streaming_sketch_equals_in_memory_sketch(self, gaussian_blobs):
        """Same key -> streaming and in-memory fits see the same (z, w, l, u)."""
        x, _, _ = gaussian_blobs
        cfg = ckm_mod.CKMConfig(k=5, sigma2=1.0, sigma2_sample=1000)
        key = jax.random.PRNGKey(9)
        z_mem, op_mem, _, (lo_m, hi_m) = ckm_mod.compute_sketch(key, x, cfg)
        z_st, op_st, _, (lo_s, hi_s), _ = ckm_mod.compute_sketch_streaming(
            key, pipe.chunked(x, 1000), cfg
        )
        # Same key -> the same operator spec (and hence identical frequencies).
        assert op_st.spec() == op_mem.spec()
        np.testing.assert_allclose(
            np.asarray(op_st.materialize()), np.asarray(op_mem.materialize())
        )
        np.testing.assert_allclose(np.asarray(z_st), np.asarray(z_mem), atol=1e-4)
        np.testing.assert_allclose(np.asarray(lo_s), np.asarray(lo_m), atol=1e-6)
        np.testing.assert_allclose(np.asarray(hi_s), np.asarray(hi_m), atol=1e-6)

    def test_embedding_stream_feeds_engine(self):
        """The data pipeline's embedding stream plugs into the engine."""
        from repro.configs.base import ShapeConfig, get_smoke_config
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = get_smoke_config("llama3.2-1b")
        shape = ShapeConfig("t", 16, 8, "train")
        src = SyntheticLM(cfg, shape, DataConfig(seed=0, embed_dim=8))
        w = fq.draw_frequencies(jax.random.PRNGKey(0), 16, 8, 1.0)
        e = eng_mod.SketchEngine(w, "xla")
        z, lo, hi = e.sketch_stream(src.embedding_stream(0, 4))
        assert z.shape == (32,) and np.all(np.isfinite(np.asarray(z)))
        assert bool(jnp.all(lo <= hi))
