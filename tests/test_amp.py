"""CL-AMP decoder suite (marker: amp).

Four layers, mirroring how the ``sketch_shift`` decoder shipped:

- **registry round-trip** — ``"amp"`` is a first-class registry entry,
  selectable via ``CKMConfig(decoder="amp")``;
- **kernel parity** — the fused ``amp_denoise`` op (truncated-Gaussian
  posterior moments, the GAMP input channel) matches the pure-jnp oracle in
  ``kernels/ref.py`` to 1e-5 for both ``impl="xla"`` and the Pallas kernel in
  interpret mode, including the tail edge cases that motivated the hardening
  pass (far-out pseudo-data, tiny/huge variances, half-open boxes);
- **end-to-end** — quantized sketches and streaming fits decode with
  ``decoder="amp"``;
- **SSE-vs-m acceptance** — on separated blobs, amp at m = 4·K·n lands
  within 5% of clompr's SSE at m = 10·K·n (the issue's headline claim: AMP
  stays accurate at sketch sizes where greedy decoding needs headroom).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CKMConfig, available_decoders, decode_sketch, get_decoder
from repro.core import ckm as ckm_mod
from repro.core.decoders import AMPConfig, cl_amp
from repro.data import pipeline as pipe
from repro.kernels import ops, ref

pytestmark = pytest.mark.amp

# Shrunk-but-converging budgets (same spirit as test_decoders.FAST): the
# e2e tests check *plumbing*, the acceptance test uses real budgets.
FAST = dict(amp_iters=40, amp_polish_steps=150, nnls_iters=60)


class TestRegistry:
    def test_amp_registered(self):
        assert "amp" in available_decoders()

    def test_round_trip_through_config(self, gaussian_blobs):
        """decode_sketch(decoder="amp") == the direct cl_amp call on the
        replicate-0 key, through the registry adapter."""
        x, _, _ = gaussian_blobs
        cfg = CKMConfig(k=5, m=80, decoder="amp", **FAST)
        z, w, _, (lo, hi) = ckm_mod.compute_sketch(jax.random.PRNGKey(1), x, cfg)
        key = jax.random.PRNGKey(2)
        via_registry = decode_sketch(key, z, w, lo, hi, cfg)
        direct = cl_amp(
            jax.random.fold_in(key, 0), z, w, lo, hi, cfg.amp_config()
        )
        for got, want in zip(via_registry, direct):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_get_decoder_is_the_adapter(self):
        fn = get_decoder("amp")
        assert fn.__name__ == "decode_amp"

    def test_amp_config_mirrors_knobs(self):
        cfg = CKMConfig(k=4, amp_iters=17, amp_damp=0.2, amp_impl="pallas")
        acfg = cfg.amp_config()
        assert isinstance(acfg, AMPConfig)
        assert (acfg.k, acfg.iters, acfg.damp, acfg.impl) == (4, 17, 0.2, "pallas")


class TestAMPDenoiseKernel:
    """xla | pallas vs the ref.py oracle, 1e-5 everywhere."""

    def _case(self, seed, k_est, feat, spread=4.0):
        key = jax.random.PRNGKey(seed)
        kr, kl, kh = jax.random.split(key, 3)
        r = jax.random.normal(kr, (k_est, feat)) * spread
        lo = -jnp.abs(jax.random.normal(kl, (feat,))) - 0.1
        hi = jnp.abs(jax.random.normal(kh, (feat,))) + 0.1
        return r, lo, hi

    @pytest.mark.parametrize("impl,interpret", [("xla", False), ("pallas", True)])
    @pytest.mark.parametrize("k_est,feat", [(8, 128), (37, 130), (3, 4), (256, 16)])
    @pytest.mark.parametrize("q", [0.5, 1e-4, 25.0])
    def test_matches_ref(self, impl, interpret, k_est, feat, q):
        r, lo, hi = self._case(0, k_est, feat)
        mean, var = ops.amp_denoise(
            r, q, lo, hi, impl=impl, block_k=8, interpret=interpret
        )
        mean_ref, var_ref = ref.amp_denoise_ref(r, q, lo, hi)
        # 1e-5 in the natural units of each moment: the mean scales with the
        # posterior std (erf-vs-ndtr f32 ulps are amplified by sigma), the
        # variance with q.
        tol_m = 1e-5 * max(1.0, float(np.sqrt(q)))
        tol_v = 1e-5 * max(1.0, q)
        np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_ref), atol=tol_m)
        np.testing.assert_allclose(np.asarray(var), np.asarray(var_ref), atol=tol_v)

    @pytest.mark.parametrize("impl,interpret", [("xla", False), ("pallas", True)])
    def test_deep_tail_pseudo_data(self, impl, interpret):
        """r far outside the box: the naive erf difference underflows to 0 in
        f32 here — the tail-stable branch must keep mean/var finite, inside
        the box, and matching the oracle (the bug this PR hardens against)."""
        feat = 8
        r = jnp.array([[1e6] * feat, [-1e6] * feat, [50.0] * feat])
        lo, hi = jnp.full((feat,), -1.0), jnp.full((feat,), 1.0)
        mean, var = ops.amp_denoise(
            r, 1.0, lo, hi, impl=impl, block_k=8, interpret=interpret
        )
        mean_ref, var_ref = ref.amp_denoise_ref(r, 1.0, lo, hi)
        assert np.all(np.isfinite(np.asarray(mean)))
        assert np.all(np.asarray(mean) >= -1.0) and np.all(np.asarray(mean) <= 1.0)
        assert np.all(np.asarray(var) > 0)
        np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(var), np.asarray(var_ref), atol=1e-5)

    @pytest.mark.parametrize("impl,interpret", [("xla", False), ("pallas", True)])
    def test_half_open_and_open_boxes(self, impl, interpret):
        """±inf bounds: the boundary terms t·phi(t) must be guarded to 0, and
        the fully-open box reduces to the identity denoiser (mean=r, var=q)."""
        r = jnp.array([[0.3, -2.0, 5.0, -5.0]])
        lo = jnp.array([-jnp.inf, -1.0, -jnp.inf, -1.0])
        hi = jnp.array([jnp.inf, jnp.inf, 1.0, 1.0])
        mean, var = ops.amp_denoise(
            r, 2.0, lo, hi, impl=impl, block_k=8, interpret=interpret
        )
        mean_ref, var_ref = ref.amp_denoise_ref(r, 2.0, lo, hi)
        np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(var), np.asarray(var_ref), atol=1e-5)
        # fully-open coordinate: posterior == prior pseudo-data
        np.testing.assert_allclose(float(mean[0, 0]), 0.3, atol=1e-5)
        np.testing.assert_allclose(float(var[0, 0]), 2.0, atol=1e-4)

    def test_unknown_impl_raises(self):
        r, lo, hi = self._case(1, 4, 8)
        with pytest.raises(ValueError, match="impl"):
            ops.amp_denoise(r, 1.0, lo, hi, impl="cuda")


@pytest.mark.slow
class TestEndToEnd:
    def test_quantized_fit(self, gaussian_blobs):
        """decoder="amp" decodes a 1-bit quantized sketch to finite in-box
        centroids with probability weights."""
        x, _, _ = gaussian_blobs
        cfg = CKMConfig(
            k=5, m=120, decoder="amp", sketch_quantization="1bit", **FAST
        )
        res = ckm_mod.fit(jax.random.PRNGKey(0), x, cfg)
        c = np.asarray(res.centroids)
        wts = np.asarray(res.weights)
        assert np.all(np.isfinite(c))
        assert np.all(wts >= 0) and abs(wts.sum() - 1.0) < 1e-5

    def test_streaming_fit_recovers_blobs(self, gaussian_blobs):
        """One-pass fit_streaming(decoder="amp") localises every true mean.
        (sigma2 pinned: the streaming path estimates it from the first batch
        only, so leaving it free would change the drawn frequencies vs fit.)"""
        x, _, means = gaussian_blobs
        cfg = CKMConfig(k=5, m=120, decoder="amp", sigma2=1.0, replicates=2)
        res = ckm_mod.fit_streaming(
            jax.random.PRNGKey(0), pipe.chunked(x, 1024), cfg
        )
        d = np.linalg.norm(
            np.asarray(means)[:, None] - np.asarray(res.centroids)[None],
            axis=-1,
        ).copy()
        errs = []
        for _ in range(means.shape[0]):
            i, j = np.unravel_index(np.argmin(d), d.shape)
            errs.append(d[i, j])
            d[i, :] = np.inf
            d[:, j] = np.inf
        assert np.all(np.array(errs) < 1.0), errs

    def test_sse_acceptance_amp_4kn_vs_clompr_10kn(self, gaussian_blobs):
        """The issue's acceptance: amp @ m=4Kn within 5% of clompr @ m=10Kn
        (K=5, n=4 -> m=80 vs m=200), best-of-3 replicates, real budgets."""
        x, _, _ = gaussian_blobs
        n_pts = x.shape[0]
        amp_cfg = CKMConfig(k=5, m=80, decoder="amp", replicates=3)
        clompr_cfg = CKMConfig(k=5, m=200, decoder="clompr", replicates=3)
        res_amp = ckm_mod.fit(jax.random.PRNGKey(0), x, amp_cfg)
        res_clompr = ckm_mod.fit(jax.random.PRNGKey(0), x, clompr_cfg)
        sse_amp = float(ckm_mod.sse(x, res_amp.centroids)) / n_pts
        sse_clompr = float(ckm_mod.sse(x, res_clompr.centroids)) / n_pts
        assert sse_amp <= 1.05 * sse_clompr, (sse_amp, sse_clompr)

    def test_structured_freq_op_decodes(self, gaussian_blobs):
        """AMP touches w only via apply/adjoint/col_sq_norms, so the
        fast-transform family must decode without materialization."""
        x, _, _ = gaussian_blobs
        cfg = CKMConfig(k=5, m=128, decoder="amp", freq_op="structured", **FAST)
        res = ckm_mod.fit(jax.random.PRNGKey(3), x, cfg)
        assert np.all(np.isfinite(np.asarray(res.centroids)))

    def test_pallas_impl_fits(self, gaussian_blobs):
        """amp_impl="pallas" end-to-end (interpret mode off-TPU is wired
        through AMPConfig.impl -> ops.amp_denoise auto-interpret)."""
        x, _, _ = gaussian_blobs
        cfg = CKMConfig(
            k=5, m=80, decoder="amp", amp_impl="pallas",
            amp_iters=10, amp_polish_steps=50, nnls_iters=40,
        )
        res = ckm_mod.fit(jax.random.PRNGKey(4), x, cfg)
        assert np.all(np.isfinite(np.asarray(res.centroids)))
