"""Unit + property tests for the sketching operator (paper §3.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import frequencies as fq
from repro.core import sketch as sk


def _freqs(key, n, m, sigma2=1.0):
    return fq.draw_frequencies(key, m, n, sigma2)


class TestSketchOperator:
    def test_matches_definition(self, rng):
        """Sk(Y, b)_j == sum_l b_l exp(-i w_j^T y_l), vs naive complex numpy."""
        kx, kw, kb = jax.random.split(rng, 3)
        x = jax.random.normal(kx, (50, 3))
        w = _freqs(kw, 3, 17)
        beta = jax.random.uniform(kb, (50,))
        zc = np.asarray(sk.sketch_complex(x, w, weights=beta))
        proj = np.asarray(x) @ np.asarray(w)
        expected = (np.asarray(beta) @ np.exp(-1j * proj)).astype(np.complex64)
        np.testing.assert_allclose(zc, expected, rtol=1e-4, atol=1e-5)

    def test_uniform_weights_default(self, rng):
        kx, kw = jax.random.split(rng)
        x = jax.random.normal(kx, (64, 4))
        w = _freqs(kw, 4, 8)
        z1 = sk.sketch(x, w)
        z2 = sk.sketch(x, w, weights=jnp.full((64,), 1 / 64))
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-5)

    def test_chunking_invariance(self, rng):
        """Chunked accumulation must not change the value (incl. ragged N)."""
        kx, kw = jax.random.split(rng)
        x = jax.random.normal(kx, (1000, 5))
        w = _freqs(kw, 5, 32)
        z_big = sk.sketch(x, w, chunk=1024)
        z_small = sk.sketch(x, w, chunk=96)  # does not divide 1000
        np.testing.assert_allclose(np.asarray(z_big), np.asarray(z_small), atol=1e-4)

    def test_linearity_in_distribution(self, rng):
        """Sk is linear: sketch of union = weighted average of sketches."""
        kx, ky, kw = jax.random.split(rng, 3)
        xa = jax.random.normal(kx, (30, 3))
        xb = jax.random.normal(ky, (70, 3))
        w = _freqs(kw, 3, 16)
        za = sk.sketch(xa, w)
        zb = sk.sketch(xb, w)
        zu = sk.sketch(jnp.concatenate([xa, xb]), w)
        np.testing.assert_allclose(
            np.asarray(zu), np.asarray(0.3 * za + 0.7 * zb), atol=1e-5
        )

    def test_atom_norm_constant(self, rng):
        """||A delta_c|| = sqrt(m) for any c (unit-modulus samples)."""
        kc, kw = jax.random.split(rng)
        cs = jax.random.normal(kc, (20, 6)) * 10.0
        w = _freqs(kw, 6, 33)
        a = sk.atoms(cs, w)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(a), axis=1),
            np.full(20, np.sqrt(33.0)),
            rtol=1e-5,
        )

    def test_atom_is_dirac_sketch(self, rng):
        """A delta_c == Sk({c}, [1])."""
        kc, kw = jax.random.split(rng)
        c = jax.random.normal(kc, (5,))
        w = _freqs(kw, 5, 12)
        np.testing.assert_allclose(
            np.asarray(sk.atom(c, w)),
            np.asarray(sk.sketch(c[None, :], w)),
            atol=1e-6,
        )

    def test_complex_roundtrip(self, rng):
        z = jax.random.normal(rng, (2 * 9,))
        np.testing.assert_allclose(
            np.asarray(sk.from_complex(sk.to_complex(z))), np.asarray(z)
        )

    def test_bounds_single_pass(self, rng):
        x = jax.random.normal(rng, (100, 4)) * 3
        lo, hi = sk.data_bounds(x)
        assert bool(jnp.all(lo <= x.min(0))) and bool(jnp.all(hi >= x.max(0)))


class TestSketchProperties:
    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 8),
        m=st.integers(1, 64),
        npts=st.integers(1, 200),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_modulus_bounded_by_one(self, n, m, npts, seed):
        """|z_j| <= 1 for any probability-weighted sketch (char. function)."""
        key = jax.random.PRNGKey(seed)
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (npts, n)) * 5
        w = _freqs(kw, n, m)
        zc = sk.sketch_complex(x, w)
        assert np.all(np.abs(np.asarray(zc)) <= 1.0 + 1e-4)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), shift=st.floats(-5, 5))
    def test_translation_modulates_phase(self, seed, shift):
        """Sk(X + t) = Sk(X) .* exp(-i w^T t) — characteristic-function law."""
        key = jax.random.PRNGKey(seed)
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (40, 3))
        w = _freqs(kw, 3, 10)
        t = jnp.full((3,), shift)
        z0 = np.asarray(sk.sketch_complex(x, w))
        z1 = np.asarray(sk.sketch_complex(x + t, w))
        phase = np.exp(-1j * np.asarray(t @ w))
        np.testing.assert_allclose(z1, z0 * phase, atol=1e-4)


class TestFrequencies:
    def test_shapes_and_dtype(self, rng):
        for dist in ("adapted_radius", "gaussian", "folded_gaussian"):
            w = fq.draw_frequencies(rng, 64, 7, 2.0, dist)
            assert w.shape == (7, 64) and w.dtype == jnp.float32

    def test_adapted_radius_scale_invariance(self, rng):
        """Radii scale as 1/sigma: doubling sigma halves the radius quantiles."""
        w1 = fq.draw_frequencies(rng, 4096, 5, 1.0)
        w2 = fq.draw_frequencies(rng, 4096, 5, 4.0)
        r1 = np.median(np.linalg.norm(np.asarray(w1), axis=0))
        r2 = np.median(np.linalg.norm(np.asarray(w2), axis=0))
        np.testing.assert_allclose(r1 / r2, 2.0, rtol=0.1)

    def test_directions_isotropic(self, rng):
        w = np.asarray(fq.draw_frequencies(rng, 8192, 3, 1.0))
        dirs = w / np.linalg.norm(w, axis=0, keepdims=True)
        np.testing.assert_allclose(dirs.mean(axis=1), np.zeros(3), atol=0.05)

    def test_sigma2_estimation_order_of_magnitude(self):
        """On unit-variance clusters the estimate lands within ~[0.3, 10]."""
        from repro.data import synthetic

        key = jax.random.PRNGKey(1)
        x = synthetic.gaussian_mixture(key, 4000, k=5, n=6, c=3.0)
        s2 = float(fq.estimate_sigma2(jax.random.PRNGKey(2), x))
        assert 0.2 < s2 < 20.0
