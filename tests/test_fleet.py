"""Fleet test battery: the vmapped monoid law and tenant isolation.

Four pillars (ISSUE 7):

1. **Vmapped monoid parity** — stacked ``FleetEngine`` update/merge/finalize
   is bitwise identical to a Python loop of per-tenant ``SketchEngine`` calls,
   for float and quantized states, on the xla and pallas backends.
2. **Isolation fuzz** — hypothesis-generated random interleavings of
   update/merge/evict/restore streams across tenants leave every tenant's
   state bitwise equal to an isolated single-tenant run, and decode-LRU hits
   equal fresh decodes.
3. **Checkpoint round-trip** — evict-then-restore reproduces the exact
   accumulator state and operator spec for float/quantized states and
   dense/structured operators (plus the checkpointer meta/flavour-guard
   regressions the fleet surfaced).
4. **Launch-spec validation** — fleet configs with a tenant count not
   divisible by the shard extent are rejected.

Run alone with:  pytest -m fleet
"""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import fleet as fl
from repro.core.ckm import CKMConfig
from repro.core.engine import QuantizedSketchEngineState, SketchEngineState
from repro.launch.specs import SketchJobSpec
from repro.serve.fleet_service import FleetService

from tests._hypothesis_compat import given, settings, st

pytestmark = pytest.mark.fleet

T, B, N, M = 4, 12, 3, 32

BACKENDS = ["xla", "pallas"]
QUANTS = ["none", "1bit"]


def _make_engine(backend="xla", quant="none", n_tenants=T, name="dense"):
    specs = fl.fleet_specs(jax.random.PRNGKey(0), n_tenants, name, M, N, 1.5)
    quants = fl.fleet_quantizers(jax.random.PRNGKey(7), n_tenants, M, quant)
    kwargs = {}
    if backend == "pallas":
        # Tiny blocks + interpret so the kernel path runs off-TPU in tests.
        kwargs = dict(block_n=32, block_m=32, interpret=True)
    return fl.FleetEngine(specs, backend=backend, quantizers=quants, **kwargs)


def _batches(key, rounds=1, n_tenants=T, batch=B):
    return jax.random.normal(key, (rounds, n_tenants, batch, N))


def _rows_equal(row, ref):
    return all(
        bool(jnp.array_equal(a, b))
        for a, b in zip(
            jax.tree_util.tree_leaves(row), jax.tree_util.tree_leaves(ref)
        )
    )


def _cheap_decode_cfg(**overrides):
    """A decode config that finishes in milliseconds (tests hammer decode)."""
    cfg = CKMConfig(
        k=2,
        decoder="sketch_shift",
        shift_candidates=2,
        shift_steps=3,
        shift_polish_steps=2,
        nnls_iters=4,
    )
    return dataclasses.replace(cfg, **overrides)


# -- 1. the vmapped monoid law -------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("quant", QUANTS)
def test_vmapped_monoid_parity(backend, quant):
    """Stacked update/merge/finalize == Python loop of SketchEngine calls,
    bitwise, for every tenant."""
    eng = _make_engine(backend, quant)
    xs = _batches(jax.random.PRNGKey(1), rounds=2)

    # Stacked path: two update rounds into two states, then a merge.
    sa = eng.update(eng.init_state(), xs[0])
    sb = eng.update(eng.init_state(), xs[1])
    merged = eng.merge(sa, sb)
    z, lo, hi = eng.finalize(merged)

    for t in range(T):
        ref_eng = eng.tenant_engine(t)
        ra = ref_eng.update(ref_eng.init_state(), xs[0, t])
        rb = ref_eng.update(ref_eng.init_state(), xs[1, t])
        rm = ref_eng.merge(ra, rb)
        assert _rows_equal(eng.tenant_state(sa, t), ra)
        assert _rows_equal(eng.tenant_state(merged, t), rm)
        rz, rlo, rhi = ref_eng.finalize(rm)
        assert bool(jnp.array_equal(z[t], rz))
        assert bool(jnp.array_equal(lo[t], rlo))
        assert bool(jnp.array_equal(hi[t], rhi))
        # finalize_tenant is the decode hot path — same numbers, O(m).
        tz, tlo, thi = eng.finalize_tenant(merged, t)
        assert bool(jnp.array_equal(tz, rz))
        assert bool(jnp.array_equal(tlo, rlo))
        assert bool(jnp.array_equal(thi, rhi))


@pytest.mark.parametrize("quant", QUANTS)
def test_ingest_unique_ids_scatter(quant):
    """Unique tenant ids take the one-scatter-per-leaf path and still match
    the per-tenant engines bitwise."""
    eng = _make_engine("xla", quant)
    xs = _batches(jax.random.PRNGKey(2))[0]
    ids = np.array([2, 0, 3, 1])  # permuted on purpose
    state = eng.ingest(eng.init_state(), ids, xs)
    for r, t in enumerate(ids):
        ref_eng = eng.tenant_engine(int(t))
        ref = ref_eng.update(ref_eng.init_state(), xs[r])
        assert _rows_equal(eng.tenant_state(state, int(t)), ref)


@pytest.mark.parametrize("quant", QUANTS)
def test_ingest_duplicate_ids_arrival_order(quant):
    """Duplicate ids in one ingest call fold in arrival order — bitwise the
    association the tenant's isolated engine uses."""
    eng = _make_engine("xla", quant)
    xs = _batches(jax.random.PRNGKey(3), n_tenants=5)[0]
    ids = np.array([1, 0, 1, 2, 1])  # tenant 1 appears three times
    state = eng.ingest(eng.init_state(), ids, xs)
    refs = {}
    for r, t in enumerate(ids):
        t = int(t)
        ref_eng = eng.tenant_engine(t)
        refs[t] = ref_eng.update(
            refs.get(t, ref_eng.init_state()), xs[r]
        )
    for t, ref in refs.items():
        assert _rows_equal(eng.tenant_state(state, t), ref)
    # Untouched tenant stays at the monoid identity.
    assert _rows_equal(
        eng.tenant_state(state, 3),
        eng.tenant_engine(3).init_state(),
    )


def test_structured_operator_fleet():
    """The fleet is operator-family agnostic: structured fast-transform
    tenants batch and match their reference engines bitwise too."""
    eng = _make_engine("xla", "none", name="structured")
    xs = _batches(jax.random.PRNGKey(4))[0]
    state = eng.update(eng.init_state(), xs)
    for t in range(T):
        ref_eng = eng.tenant_engine(t)
        ref = ref_eng.update(ref_eng.init_state(), xs[t])
        assert _rows_equal(eng.tenant_state(state, t), ref)


def test_quantized_fleet_rejects_weights():
    eng = _make_engine("xla", "1bit")
    xs = _batches(jax.random.PRNGKey(5))[0]
    with pytest.raises(ValueError, match="unit-weight"):
        eng.update(eng.init_state(), xs, weights=jnp.ones((T, B)))


def test_stack_operators_rejects_mismatched_tenants():
    a = fl.fleet_specs(jax.random.PRNGKey(0), 1, "dense", M, N, 1.0)
    b = fl.fleet_specs(jax.random.PRNGKey(1), 1, "dense", M // 2, N, 1.0)
    with pytest.raises(ValueError, match="tenant 1"):
        fl.FleetEngine(a + b)


# -- 2. isolation fuzz ---------------------------------------------------------


def _reference_tenant(eng, ops):
    """Replay one tenant's op stream on an isolated SketchEngine."""
    ref_eng = eng.tenant_engine(ops["tenant"])
    state = ref_eng.init_state()
    for kind, payload in ops["stream"]:
        if kind == "update":
            state = ref_eng.update(state, payload)
        elif kind == "merge":
            state = ref_eng.merge(state, payload)
    return state


@settings(max_examples=5, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    quant=st.sampled_from(QUANTS),
)
def test_isolation_fuzz(seed, quant):
    """Random interleavings of update/merge/evict/restore across tenants:
    every tenant ends bitwise equal to an isolated run of its own stream,
    and cached decodes equal fresh decodes."""
    n_tenants = 3
    eng = _make_engine("xla", quant, n_tenants=n_tenants)
    rng = np.random.default_rng(seed)
    per_tenant = [
        {"tenant": t, "stream": []} for t in range(n_tenants)
    ]
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc = FleetService(
            eng,
            _cheap_decode_cfg(),
            decode_cache_entries=8,
            checkpoint_dir=ckpt_dir,
        )
        for step in range(12):
            t = int(rng.integers(n_tenants))
            action = rng.choice(["update", "update", "merge", "evict"])
            if action == "update":
                batch = jnp.asarray(
                    rng.standard_normal((int(rng.integers(2, 7)), N)),
                    jnp.float32,
                )
                svc.submit(t, batch)
                svc.flush(async_ingest=bool(rng.integers(2)))
                per_tenant[t]["stream"].append(("update", batch))
            elif action == "merge":
                ref_eng = eng.tenant_engine(t)
                batch = jnp.asarray(
                    rng.standard_normal((3, N)), jnp.float32
                )
                partial = ref_eng.update(ref_eng.init_state(), batch)
                svc.merge_partial(t, partial)
                per_tenant[t]["stream"].append(("merge", partial))
            else:
                svc.evict(t)
                if rng.integers(2):  # explicit restore half the time;
                    svc.restore(t)  # the other half auto-restores on touch
        for t in range(n_tenants):
            if t in svc.evicted:
                svc.restore(t)
            ref = _reference_tenant(eng, per_tenant[t])
            assert _rows_equal(eng.tenant_state(svc.state, t), ref), (
                f"tenant {t} diverged from its isolated engine "
                f"(seed={seed}, quant={quant})"
            )
        # Decode-LRU: a cache hit is bitwise the fresh decode.
        t = int(rng.integers(n_tenants))
        fresh = svc.decode(t, use_cache=False)
        first = svc.decode(t)
        hit = svc.decode(t)
        assert not first.cached and hit.cached
        assert bool(jnp.array_equal(fresh.centroids, hit.centroids))
        assert bool(jnp.array_equal(fresh.weights, hit.weights))
        assert hit.version == svc.version(t)


def test_decode_cache_invalidated_by_writes():
    """Any write to a tenant bumps its version: the next decode is a miss
    and reflects the new state; other tenants' cached decodes survive."""
    eng = _make_engine("xla", "none", n_tenants=2)
    svc = FleetService(eng, _cheap_decode_cfg(), decode_cache_entries=4)
    xs = _batches(jax.random.PRNGKey(6), n_tenants=2)[0]
    svc.ingest([0, 1], list(xs))
    d0 = svc.decode(0)
    d1 = svc.decode(1)
    svc.submit(0, xs[1])
    svc.flush()
    again0 = svc.decode(0)
    again1 = svc.decode(1)
    assert not again0.cached and again0.version == d0.version + 1
    assert again1.cached and again1.version == d1.version
    assert svc.stats.decode_hits == 1 and svc.stats.decode_misses == 3


def test_decode_lru_capacity_eviction():
    """The LRU holds at most decode_cache_entries models and evicts the
    least-recently-used key."""
    eng = _make_engine("xla", "none", n_tenants=3)
    svc = FleetService(eng, _cheap_decode_cfg(), decode_cache_entries=2)
    xs = _batches(jax.random.PRNGKey(8), n_tenants=3)[0]
    svc.ingest([0, 1, 2], list(xs))
    svc.decode(0)
    svc.decode(1)
    svc.decode(0)  # refresh 0 so tenant 1 is the LRU entry
    svc.decode(2)  # capacity 2: evicts tenant 1
    assert svc.cache_len() == 2
    assert svc.decode(0).cached
    assert svc.decode(2).cached
    assert not svc.decode(1).cached  # was evicted -> fresh decode


def test_decode_cache_disabled():
    eng = _make_engine("xla", "none", n_tenants=1)
    svc = FleetService(eng, _cheap_decode_cfg(), decode_cache_entries=0)
    xs = _batches(jax.random.PRNGKey(9), n_tenants=1)[0]
    svc.ingest([0], list(xs))
    assert not svc.decode(0).cached
    assert not svc.decode(0).cached
    assert svc.cache_len() == 0


# -- 3. checkpoint round-trip --------------------------------------------------


@pytest.mark.parametrize("quant", QUANTS)
@pytest.mark.parametrize("op_name", ["dense", "structured"])
def test_evict_restore_roundtrip(quant, op_name, tmp_path):
    """Evict-then-restore is invisible: exact state row, spec-checked
    identity, version rewound, pre-eviction cached decodes valid again."""
    eng = _make_engine("xla", quant, n_tenants=2, name=op_name)
    svc = FleetService(
        eng, _cheap_decode_cfg(), decode_cache_entries=4,
        checkpoint_dir=tmp_path,
    )
    xs = _batches(jax.random.PRNGKey(10), n_tenants=2)[0]
    svc.ingest([0, 1], list(xs))
    before = eng.tenant_state(svc.state, 0)
    version = svc.version(0)
    cached = svc.decode(0)

    svc.evict(0)
    assert 0 in svc.evicted
    assert _rows_equal(
        eng.tenant_state(svc.state, 0), eng.tenant_engine(0).init_state()
    )
    # The untouched tenant is unaffected by its neighbour's eviction.
    assert _rows_equal(
        eng.tenant_state(svc.state, 1),
        eng.tenant_engine(1).update(eng.tenant_engine(1).init_state(), xs[1]),
    )

    svc.restore(0)
    assert 0 not in svc.evicted
    assert _rows_equal(eng.tenant_state(svc.state, 0), before)
    assert svc.version(0) == version
    hit = svc.decode(0)
    assert hit.cached and hit.version == cached.version
    assert bool(jnp.array_equal(hit.centroids, cached.centroids))


def test_auto_restore_on_touch(tmp_path):
    """Submitting to or decoding an evicted tenant restores it first."""
    eng = _make_engine("xla", "none", n_tenants=2)
    svc = FleetService(
        eng, _cheap_decode_cfg(), checkpoint_dir=tmp_path,
    )
    xs = _batches(jax.random.PRNGKey(11), n_tenants=2, rounds=2)
    svc.ingest([0, 1], list(xs[0]))
    svc.evict(0)
    svc.submit(0, xs[1, 0])
    svc.flush()
    assert 0 not in svc.evicted
    ref_eng = eng.tenant_engine(0)
    ref = ref_eng.update(ref_eng.init_state(), xs[0, 0])
    ref = ref_eng.update(ref, xs[1, 0])
    assert _rows_equal(eng.tenant_state(svc.state, 0), ref)
    assert svc.stats.restores == 1


def test_restore_rejects_wrong_bits(tmp_path):
    """A checkpoint written by a float fleet cannot restore into a quantized
    fleet of the same (n, m) — the flavour guard fails loudly."""
    float_eng = _make_engine("xla", "none", n_tenants=2)
    svc = FleetService(
        float_eng, _cheap_decode_cfg(), checkpoint_dir=tmp_path,
    )
    xs = _batches(jax.random.PRNGKey(12), n_tenants=2)[0]
    svc.ingest([0, 1], list(xs))
    svc.evict(0)

    q_eng = _make_engine("xla", "1bit", n_tenants=2)
    q_svc = FleetService(
        q_eng, _cheap_decode_cfg(), checkpoint_dir=tmp_path,
    )
    q_svc._evicted.add(0)
    with pytest.raises(ValueError):
        q_svc.restore(0)


def test_checkpointer_meta_roundtrip(tmp_path):
    """Checkpointer gap fix: save(meta=...) survives the atomic write and
    read_meta returns it (latest step by default)."""
    ckpt = Checkpointer(tmp_path)
    state = {"a": jnp.arange(4.0)}
    ckpt.save(3, state, meta={"tenant": 7, "freq_op_spec": ["dense", 1]})
    ckpt.save(5, state, meta={"tenant": 7, "version": 5})
    assert ckpt.read_meta(3) == {"tenant": 7, "freq_op_spec": ["dense", 1]}
    assert ckpt.read_meta() == {"tenant": 7, "version": 5}
    ckpt.save(6, state)  # no meta -> {}
    assert ckpt.read_meta(6) == {}


def test_checkpointer_rejects_wrong_flavour(tmp_path):
    """Checkpointer gap fix: restore validates dtype (not just leaf count),
    so a float row cannot silently load into a quantized state twin."""
    ckpt = Checkpointer(tmp_path)
    fstate = SketchEngineState(
        cos_acc=jnp.zeros(M),
        sin_acc=jnp.zeros(M),
        weight_sum=jnp.zeros(()),
        lower=jnp.zeros(N),
        upper=jnp.zeros(N),
        count=jnp.zeros(()),
    )
    ckpt.save(0, fstate)
    qlike = QuantizedSketchEngineState(
        qcos_acc=jnp.zeros(M, jnp.int32),
        qsin_acc=jnp.zeros(M, jnp.int32),
        weight_sum=jnp.zeros(()),
        lower=jnp.zeros(N),
        upper=jnp.zeros(N),
        count=jnp.zeros(()),
    )
    with pytest.raises(ValueError, match="flavour"):
        ckpt.restore(qlike)
    wrong_shape = fstate._replace(cos_acc=jnp.zeros(M * 2))
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(wrong_shape)


# -- 4. launch-spec validation -------------------------------------------------


def test_jobspec_fleet_divisibility():
    """n_tenants must split evenly over the tenant shard extent."""
    good = SketchJobSpec(n_tenants=1024, tenant_shards=8)
    assert good.validate() is good
    with pytest.raises(ValueError, match="tenant shard extent"):
        SketchJobSpec(n_tenants=1000, tenant_shards=7).validate()


def test_jobspec_fleet_field_validation():
    with pytest.raises(ValueError, match="n_tenants"):
        SketchJobSpec(n_tenants=0).validate()
    with pytest.raises(ValueError, match="tenant_shards"):
        SketchJobSpec(tenant_shards=0).validate()
    with pytest.raises(ValueError, match="axis name"):
        SketchJobSpec(tenant_shard_axis="").validate()
    with pytest.raises(ValueError, match="decode_cache_entries"):
        SketchJobSpec(decode_cache_entries=-1).validate()
    with pytest.raises(ValueError, match="fleet jobs"):
        SketchJobSpec(n_tenants=4, backend="sharded").validate()
    assert "fleet=1024x8shards" in SketchJobSpec(
        n_tenants=1024, tenant_shards=8
    ).describe()
    # Single-tenant specs neither mention the fleet nor hit its validation.
    assert "fleet" not in SketchJobSpec().describe()
    SketchJobSpec(backend="sharded").validate()


# -- 5. windowed evict/restore (ISSUE 10 satellite) ----------------------------


def _windowed_service(tmp_path, buckets=3, **kw):
    eng = _make_engine(n_tenants=T)
    svc = FleetService(
        eng, _cheap_decode_cfg(), checkpoint_dir=tmp_path,
        window_buckets=buckets, **kw,
    )
    return eng, svc


def test_windowed_submit_requires_tick(tmp_path):
    _, svc = _windowed_service(tmp_path)
    with pytest.raises(ValueError, match="tick"):
        svc.submit(0, np.zeros((B, N), np.float32))


def test_windowed_evict_restore_roundtrip(tmp_path):
    """Evict checkpoints the lifetime row AND the W bucket columns; restore
    brings both back bitwise while the ring has not moved."""
    eng, svc = _windowed_service(tmp_path)
    xs = _batches(jax.random.PRNGKey(20), rounds=2)
    for r in range(2):
        for t in range(T):
            svc.submit(t, np.asarray(xs[r, t]), t=float(r))
        svc.flush()
    row = eng.tenant_state(svc.state, 1)
    column = svc.window.tenant_column(svc.window_state, 1)
    assert any(float(c.weight_sum) > 0 for c in column)

    svc.evict(1)
    for c in svc.window.tenant_column(svc.window_state, 1):
        assert float(c.weight_sum) == 0.0  # window hole, like the row
    svc.restore(1)
    assert _rows_equal(eng.tenant_state(svc.state, 1), row)
    for got, want in zip(
        svc.window.tenant_column(svc.window_state, 1), column
    ):
        assert _rows_equal(got, want)


def test_windowed_restore_skips_expired_slots(tmp_path):
    """A checkpointed bucket column only re-enters the ring while its slot
    still holds the tick it was saved under; slots reclaimed by newer ticks
    keep their fresh occupants."""
    eng, svc = _windowed_service(tmp_path, buckets=2)
    svc.submit(0, np.asarray(_batches(jax.random.PRNGKey(21))[0, 0]), t=0.0)
    svc.flush()
    svc.evict(0)  # checkpoint holds tenant 0's slot-0 column at tick 0
    # tick 2 reclaims slot 0 (2 % W == 0) for tenant 1's fresh bucket
    svc.submit(1, np.asarray(_batches(jax.random.PRNGKey(22))[0, 1]), t=2.0)
    svc.flush()
    fresh = svc.window.tenant_column(svc.window_state, 1)[0]

    svc.restore(0)
    # tenant 0's expired column stays out of the ring ...
    assert float(
        svc.window.tenant_column(svc.window_state, 0)[0].weight_sum
    ) == 0.0
    # ... tenant 1's fresh bucket is untouched, and the lifetime row is back
    assert _rows_equal(svc.window.tenant_column(svc.window_state, 1)[0], fresh)
    assert float(eng.tenant_state(svc.state, 0).weight_sum) > 0.0


def test_windowed_restore_validates_meta(tmp_path):
    """Bucket count/ticks live in the manifest meta and must match."""
    _, svc = _windowed_service(tmp_path / "a", buckets=2)
    svc.submit(0, np.asarray(_batches(jax.random.PRNGKey(23))[0, 0]), t=0.0)
    svc.flush()
    svc.evict(0)
    # same engine family, windowless service -> window/no-window mismatch
    eng2 = _make_engine(n_tenants=T)
    svc2 = FleetService(
        eng2, _cheap_decode_cfg(), checkpoint_dir=tmp_path / "a"
    )
    svc2._evicted.add(0)
    with pytest.raises(ValueError, match="window"):
        svc2.restore(0)
    # windowed service with a different bucket count
    eng3 = _make_engine(n_tenants=T)
    svc3 = FleetService(
        eng3, _cheap_decode_cfg(), checkpoint_dir=tmp_path / "a",
        window_buckets=4,
    )
    svc3._evicted.add(0)
    with pytest.raises(ValueError, match="window_buckets"):
        svc3.restore(0)
    # windowless checkpoint into a windowed service
    _, svc4 = _windowed_service(tmp_path / "b", buckets=2)
    eng5 = _make_engine(n_tenants=T)
    svc5 = FleetService(
        eng5, _cheap_decode_cfg(), checkpoint_dir=tmp_path / "b"
    )
    svc5.submit(0, np.asarray(_batches(jax.random.PRNGKey(24))[0, 0]))
    svc5.flush()
    svc5.evict(0)
    svc4._evicted.add(0)
    with pytest.raises(ValueError, match="not windowed|window"):
        svc4.restore(0)


# -- 6. per-tenant drift thresholds (ISSUE 10 satellite) -----------------------


def test_drift_threshold_array_validation():
    eng = _make_engine()
    with pytest.raises(ValueError, match="positive"):
        FleetService(eng, _cheap_decode_cfg(), drift_threshold=-1.0)
    with pytest.raises(ValueError, match=r"shape \(4,\)"):
        FleetService(
            eng, _cheap_decode_cfg(), drift_threshold=np.ones(3)
        )
    with pytest.raises(ValueError, match="positive"):
        FleetService(
            eng, _cheap_decode_cfg(),
            drift_threshold=np.array([0.1, -0.1, 0.1, 0.1]),
        )
    svc = FleetService(
        eng, _cheap_decode_cfg(), drift_threshold=np.full(T, 0.5)
    )
    assert svc.threshold(2) == 0.5
    assert FleetService(
        eng, _cheap_decode_cfg(), drift_threshold=0.25
    ).threshold(3) == 0.25
    assert FleetService(eng, _cheap_decode_cfg()).threshold(0) is None


def test_per_tenant_drift_redecode():
    """A hot tenant with a tight bound re-decodes on drifting traffic; a
    cold tenant with a loose bound keeps serving its cached model."""
    eng = _make_engine()
    thresholds = np.full(T, 1e9)
    thresholds[0] = 1e-12  # hot tenant: any movement re-decodes
    svc = FleetService(
        eng, _cheap_decode_cfg(), drift_threshold=thresholds
    )
    xs = _batches(jax.random.PRNGKey(30))[0]
    svc.ingest(range(T), list(np.asarray(xs)))
    svc.decode(0)
    svc.decode(1)

    shifted = np.asarray(xs) + 7.0
    svc.ingest([0, 1], [shifted[0], shifted[1]])  # flush auto-maintains
    assert svc.stats.drift_redecodes == 1  # tenant 0 only
    # tenant 0's fresh model is cached at the current version
    assert svc.decode(0).cached
