"""Multi-device fleet sharding battery (ISSUE 10).

Four pillars:

1. **Mesh parity** — a ``sharding="mesh"`` fleet's update/merge/finalize is
   bitwise identical per tenant to the unsharded stacked fleet AND to
   isolated per-tenant ``SketchEngine`` runs, float and quantized, decayed
   and lifetime.  In-process tests exercise the full mesh code path on a
   1-device mesh; the real 8-shard placement runs in a subprocess with
   forced host devices (same pattern as ``tests/test_topology.py``) and
   additionally asserts the compiled update HLO contains **zero cross-shard
   collectives** — tenant parallelism is pure data parallelism.
2. **Shard routing** — :func:`repro.serve.fleet_service.shard_partition`
   preserves every tenant's arrival order while regrouping requests into
   contiguous per-shard runs (hypothesis fuzz), and a shard-routed
   ``FleetService`` stays bitwise equal to isolated engines under random
   submit/flush/evict/restore interleavings.
3. **Topology substrate** — ``tenant_mesh`` placement validation and the
   ``fleet_wire_cost_model`` checkpoint/broadcast byte/hop accounting.
4. **Launch specs** — ``SketchJobSpec.fleet_kwargs`` / ``service_kwargs``
   drive the engine and service construction end-to-end.

Run alone with:  pytest -m fleet_shard
"""

import dataclasses
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fleet as fl
from repro.core import topology as topo
from repro.core.ckm import CKMConfig
from repro.core.engine import SketchEngine
from repro.launch.specs import SketchJobSpec
from repro.parallel.sharding import tenant_mesh, tenant_shard_specs
from repro.serve.fleet_service import FleetService, shard_partition

from tests._hypothesis_compat import given, settings, st

pytestmark = pytest.mark.fleet_shard

T, B, N, M = 4, 12, 3, 32


def _make_engine(quant="none", n_tenants=T, **kwargs):
    specs = fl.fleet_specs(jax.random.PRNGKey(0), n_tenants, "dense", M, N, 1.5)
    quants = fl.fleet_quantizers(jax.random.PRNGKey(7), n_tenants, M, quant)
    return fl.FleetEngine(specs, quantizers=quants, **kwargs)


def _batches(key, rounds=1, n_tenants=T, batch=B):
    return jax.random.normal(key, (rounds, n_tenants, batch, N))


def _rows_equal(row, ref):
    return all(
        bool(jnp.array_equal(a, b))
        for a, b in zip(
            jax.tree_util.tree_leaves(row), jax.tree_util.tree_leaves(ref)
        )
    )


def _cheap_decode_cfg():
    return CKMConfig(
        k=2, decoder="sketch_shift", shift_candidates=2, shift_steps=3,
        shift_polish_steps=2, nnls_iters=4,
    )


# -- 1. mesh parity (1-device mesh exercises the full shard_map path) ---------


class TestMeshParity:
    @pytest.mark.parametrize("quant", ["none", "1bit"])
    def test_update_merge_finalize_parity(self, quant):
        """mesh(1) fleet == unsharded fleet == isolated engines, bitwise."""
        ref = _make_engine(quant)
        eng = _make_engine(quant, sharding="mesh", tenant_shards=1)
        xs = _batches(jax.random.PRNGKey(1), rounds=2)

        s_ref = ref.merge(
            ref.update(ref.init_state(), xs[0]),
            ref.update(ref.init_state(), xs[1]),
        )
        s = eng.merge(
            eng.update(eng.init_state(), xs[0]),
            eng.update(eng.init_state(), xs[1]),
        )
        for t in range(T):
            assert _rows_equal(
                eng.tenant_state(s, t), ref.tenant_state(s_ref, t)
            )
            e = eng.tenant_engine(t)
            st_iso = e.merge(
                e.update(e.init_state(), xs[0, t]),
                e.update(e.init_state(), xs[1, t]),
            )
            assert _rows_equal(eng.tenant_state(s, t), st_iso)

        z, lo, hi = eng.finalize(s)
        z_r, lo_r, hi_r = ref.finalize(s_ref)
        assert bool(jnp.array_equal(z, z_r))
        assert bool(jnp.array_equal(lo, lo_r))
        assert bool(jnp.array_equal(hi, hi_r))

    def test_decayed_mesh_parity(self):
        """Time-decayed updates agree bitwise through the mesh path."""
        ref = _make_engine(decay=0.9)
        eng = _make_engine(decay=0.9, sharding="mesh", tenant_shards=1)
        xs = _batches(jax.random.PRNGKey(2), rounds=3)
        s_ref, s = ref.init_state(), eng.init_state()
        for r, t_tick in enumerate([0.0, 1.5, 4.0]):
            s_ref = ref.update(s_ref, xs[r], t=t_tick)
            s = eng.update(s, xs[r], t=t_tick)
        for t in range(T):
            assert _rows_equal(eng.tenant_state(s, t), ref.tenant_state(s_ref, t))
        z, _, _ = eng.finalize(s)
        z_r, _, _ = ref.finalize(s_ref)
        assert bool(jnp.array_equal(z, z_r))

    def test_ingest_and_surgery_on_sharded_state(self):
        """Segment-scatter ingest + tenant surgery work on placed state and
        keep it bitwise equal to the unsharded fleet."""
        ref = _make_engine()
        eng = _make_engine(sharding="mesh", tenant_shards=1)
        s_ref, s = ref.init_state(), eng.init_state()
        ids = np.array([2, 0, 2, 1])
        xs = jax.random.normal(jax.random.PRNGKey(3), (4, B, N))
        s_ref = ref.ingest(s_ref, ids, xs)
        s = eng.ingest(s, ids, xs)
        row = eng.tenant_state(s, 2)
        s = eng.reset_tenant(s, 2)
        assert float(eng.tenant_state(s, 2).weight_sum) == 0.0
        s = eng.set_tenant(s, 2, row)
        for t in range(T):
            assert _rows_equal(eng.tenant_state(s, t), ref.tenant_state(s_ref, t))

    def test_hlo_has_no_collectives(self):
        """The compiled sharded update is embarrassingly parallel: no
        all-reduce / all-gather / permute / all-to-all in the hot path."""
        eng = _make_engine(sharding="mesh", tenant_shards=1)
        xs = _batches(jax.random.PRNGKey(4))[0]
        hlo = eng.mesh_update_hlo(eng.init_state(), xs).lower()
        for op in ("all-reduce", "all-gather", "collective-permute", "all-to-all"):
            assert op not in hlo, op

    def test_owner_shard_and_rows(self):
        eng = _make_engine(n_tenants=8, sharding="mesh", tenant_shards=1)
        assert eng.shard_rows == 8
        assert eng.owner_shard(7) == 0
        with pytest.raises(ValueError):
            eng.owner_shard(8)
        with pytest.raises(ValueError):
            eng.owner_shard(-1)
        assert "shards=1x8rows" in repr(eng)


class TestMeshConfigErrors:
    def test_unknown_sharding(self):
        with pytest.raises(ValueError, match="sharding"):
            _make_engine(sharding="grid")

    def test_mesh_requires_mesh_sharding(self):
        with pytest.raises(ValueError, match="mesh"):
            _make_engine(mesh=tenant_mesh(1))
        with pytest.raises(ValueError, match="mesh"):
            _make_engine(tenant_shards=2)

    def test_shard_extent_validated_against_mesh_and_devices(self):
        # tenant_shards must match the mesh axis extent ...
        with pytest.raises(ValueError, match="axis has 1 device"):
            _make_engine(sharding="mesh", mesh=tenant_mesh(1), tenant_shards=2)
        # ... and tenant_mesh refuses extents beyond the device count, with
        # the XLA_FLAGS escape hatch in the message (the n_tenants % shards
        # divisibility check itself runs in the 8-device subprocess test).
        with pytest.raises(ValueError, match="XLA_FLAGS"):
            _make_engine(sharding="mesh", tenant_shards=99)

    def test_axis_must_be_in_mesh(self):
        mesh = tenant_mesh(1, axis="rows")
        with pytest.raises(ValueError, match="axis"):
            _make_engine(sharding="mesh", mesh=mesh, tenant_shard_axis="tenant")
        eng = _make_engine(sharding="mesh", mesh=mesh, tenant_shard_axis="rows")
        assert eng.tenant_shard_axis == "rows"


# -- 2. shard routing ---------------------------------------------------------


class TestShardPartition:
    @settings(max_examples=25, deadline=None)
    @given(
        tenants=st.lists(st.integers(0, 15), min_size=0, max_size=40),
        n_shards=st.integers(1, 4),
    )
    def test_partition_preserves_per_tenant_order(self, tenants, n_shards):
        rows = 16 // n_shards if 16 % n_shards == 0 else None
        owner = (lambda t: t * n_shards // 16)
        pending = [(t, f"req{i}", None) for i, t in enumerate(tenants)]
        ordered, buckets = shard_partition(pending, owner, n_shards)
        # nothing lost, nothing duplicated
        assert sorted(map(id, ordered)) == sorted(map(id, pending))
        # per-tenant subsequences are untouched
        for t in set(tenants):
            assert [r for r in ordered if r[0] == t] == [
                r for r in pending if r[0] == t
            ]
        # bucket membership is by owner, buckets concatenate to the order
        for s, bucket in enumerate(buckets):
            assert all(owner(r[0]) == s for r in bucket)
        assert [r for b in buckets for r in b] == ordered

    @settings(max_examples=10, deadline=None)
    @given(
        ops=st.lists(st.integers(0, 99), min_size=1, max_size=30),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_mesh_service_interleavings_match_isolated(self, ops, seed):
        """Random submit/flush/evict/restore/decode interleavings on a
        mesh(1)-sharded service leave every tenant bitwise equal to an
        isolated SketchEngine fold of its own requests."""
        rng = np.random.default_rng(seed)
        eng = _make_engine(sharding="mesh", tenant_shards=1)
        iso = [SketchEngine(eng.operator(t)) for t in range(T)]
        iso_states = [e.init_state() for e in iso]
        with tempfile.TemporaryDirectory() as d:
            svc = FleetService(eng, _cheap_decode_cfg(), checkpoint_dir=d)
            for op in ops:
                t = op % T
                kind = (op // T) % 4
                if kind == 0 or kind == 1:  # submit (weighted toward folds)
                    x = rng.normal(size=(B, N)).astype(np.float32)
                    svc.submit(t, x)
                    iso_states[t] = iso[t].update(iso_states[t], jnp.asarray(x))
                elif kind == 2:
                    svc.flush()
                else:
                    svc.flush()  # evict folds pending state first
                    svc.evict(t)
            svc.flush()
            for t in range(T):
                if t in svc.evicted:
                    svc.restore(t)
                assert _rows_equal(
                    eng.tenant_state(svc.state, t), iso_states[t]
                ), t


# -- 3. topology substrate ----------------------------------------------------


class TestTopologySubstrate:
    def test_tenant_mesh_validation(self):
        mesh = tenant_mesh(1)
        assert mesh.axis_names == ("tenant",)
        assert mesh.shape["tenant"] == 1
        with pytest.raises(ValueError, match="XLA_FLAGS"):
            tenant_mesh(max(9, len(jax.devices()) + 1))
        with pytest.raises(ValueError):
            tenant_mesh(0)

    def test_tenant_shard_specs(self):
        P = jax.sharding.PartitionSpec
        specs = tenant_shard_specs({"a": 1, "b": (2, 3)})
        assert specs == {"a": P("tenant"), "b": (P("tenant"), P("tenant"))}

    def test_fleet_wire_cost_model(self):
        m = topo.fleet_wire_cost_model(1024, 64, 8, "tree")
        assert m["rows_per_shard"] == 8
        assert m["shard_state_bytes"] == 8 * 1024
        assert m["steady_state_bytes"] == 0  # zero-collective hot path
        assert m["checkpoint_bytes"] == 1024  # one row, owner -> host
        assert m["broadcast_hops"] == 3  # log2(8) rounds tree fan-out
        assert topo.fleet_wire_cost_model(1024, 64, 8, "ring")["broadcast_hops"] == 7
        solo = topo.fleet_wire_cost_model(1024, 64, 1)
        assert solo["broadcast_hops"] == 0
        assert solo["broadcast_bytes_total"] == 0
        with pytest.raises(ValueError, match="multiple"):
            topo.fleet_wire_cost_model(1024, 6, 4)
        with pytest.raises(ValueError):
            topo.fleet_wire_cost_model(1024, 8, 0)


# -- 4. launch specs ----------------------------------------------------------


class TestJobSpecFleetKwargs:
    def test_fleet_kwargs_unsharded(self):
        kw = SketchJobSpec(n_tenants=8).fleet_kwargs()
        assert kw == {"backend": "xla", "decay": None}

    def test_fleet_kwargs_sharded_drive_engine(self):
        job = SketchJobSpec(n_tenants=8, tenant_shards=1, decay=0.8)
        kw = job.fleet_kwargs()
        assert "sharding" not in kw  # shards=1 -> plain placement
        job = dataclasses.replace(job, tenant_shards=8)
        kw = job.fleet_kwargs()
        assert kw["sharding"] == "mesh"
        assert kw["tenant_shards"] == 8
        assert kw["tenant_shard_axis"] == "tenant"

    def test_service_kwargs_drive_service(self):
        job = SketchJobSpec(
            n_tenants=T, decode_cache_entries=7, drift_threshold=0.5,
            window_buckets=3, window_bucket_ticks=2.0,
        )
        svc = FleetService(
            _make_engine(), _cheap_decode_cfg(), **job.service_kwargs()
        )
        assert svc.decode_cache_entries == 7
        assert svc.threshold(0) == 0.5
        assert svc.window.buckets == 3
        assert svc.window.bucket_ticks == 2.0

    def test_indivisible_shards_rejected(self):
        with pytest.raises(ValueError, match="divisible"):
            SketchJobSpec(n_tenants=6, tenant_shards=4).validate()
        with pytest.raises(ValueError):
            SketchJobSpec(tenant_shards=0).validate()


# -- 5. real multi-device placement (subprocess, 8 forced host devices) -------


class TestMultiDevice:
    def test_8_shard_parity_and_zero_collectives(self):
        """8 host devices: the sharded fleet (engine AND shard-routed
        service) is bitwise equal per tenant to the unsharded stacked fleet
        and to isolated engines, float + quantized, and the compiled update
        HLO contains zero cross-shard collectives."""
        prog = textwrap.dedent(
            """
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, numpy as np
            import jax.numpy as jnp
            from repro.core import fleet as fl
            from repro.core.ckm import CKMConfig
            from repro.core.engine import SketchEngine
            from repro.launch.specs import SketchJobSpec
            from repro.serve.fleet_service import FleetService

            T, B, N, M = 16, 8, 3, 32
            assert len(jax.devices()) == 8

            def rows_equal(a, b):
                return all(bool(jnp.array_equal(x, y)) for x, y in zip(
                    jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))

            for quant in ("none", "1bit"):
                specs = fl.fleet_specs(jax.random.PRNGKey(0), T, "dense", M, N, 1.5)
                quants = fl.fleet_quantizers(jax.random.PRNGKey(7), T, M, quant)
                ref = fl.FleetEngine(specs, quantizers=quants)
                kw = SketchJobSpec(n_tenants=T, tenant_shards=8).fleet_kwargs()
                eng = fl.FleetEngine(specs, quantizers=quants, **kw)
                assert eng.tenant_shards == 8 and eng.shard_rows == 2
                assert eng.owner_shard(15) == 7

                xs = jax.random.normal(jax.random.PRNGKey(1), (2, T, B, N))
                s_ref = ref.merge(ref.update(ref.init_state(), xs[0]),
                                  ref.update(ref.init_state(), xs[1]))
                s = eng.merge(eng.update(eng.init_state(), xs[0]),
                              eng.update(eng.init_state(), xs[1]))

                hlo = eng.mesh_update_hlo(eng.init_state(), xs[0]).lower()
                for op in ("all-reduce", "all-gather", "collective-permute",
                           "all-to-all"):
                    assert op not in hlo, (quant, op)

                for t in range(T):
                    assert rows_equal(eng.tenant_state(s, t),
                                      ref.tenant_state(s_ref, t)), (quant, t)
                    e = eng.tenant_engine(t)
                    iso = e.merge(e.update(e.init_state(), xs[0, t]),
                                  e.update(e.init_state(), xs[1, t]))
                    assert rows_equal(eng.tenant_state(s, t), iso), (quant, t)
                zf, lof, hif = eng.finalize(s)
                zr, lor, hir = ref.finalize(s_ref)
                assert bool(jnp.array_equal(zf, zr)), quant
                assert bool(jnp.array_equal(lof, lor)) and bool(
                    jnp.array_equal(hif, hir)), quant

            # the divisibility guard needs real multi-shard meshes to fire
            bad = fl.fleet_specs(jax.random.PRNGKey(0), 15, "dense", M, N, 1.5)
            try:
                fl.FleetEngine(bad, sharding="mesh", tenant_shards=8)
            except ValueError as err:
                assert "divisible" in str(err), err
            else:
                raise AssertionError("indivisible shard extent accepted")

            # service level: shard-routed flush == isolated engines, bitwise
            specs = fl.fleet_specs(jax.random.PRNGKey(0), T, "dense", M, N, 1.5)
            eng = fl.FleetEngine(specs, sharding="mesh", tenant_shards=8)
            cfg = CKMConfig(k=2, decoder="sketch_shift", shift_candidates=2,
                            shift_steps=3, shift_polish_steps=2, nnls_iters=4)
            svc = FleetService(eng, cfg)
            iso = [SketchEngine(eng.operator(t)) for t in range(T)]
            iso_states = [e.init_state() for e in iso]
            rng = np.random.default_rng(3)
            for _ in range(60):
                t = int(rng.integers(T))
                x = rng.normal(size=(B, N)).astype(np.float32)
                svc.submit(t, x)
                iso_states[t] = iso[t].update(iso_states[t], jnp.asarray(x))
                if rng.integers(4) == 0:
                    svc.flush()
            svc.flush()
            for t in range(T):
                assert rows_equal(eng.tenant_state(svc.state, t),
                                  iso_states[t]), t
            print("OK")
            """
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-c", prog], env=env, capture_output=True,
            text=True, timeout=600,
        )
        assert out.returncode == 0, out.stderr[-3000:]
        assert "OK" in out.stdout
