"""Temporal-sketching battery: decay algebra, windows, drift re-decode.

Four pillars (ISSUE 9):

1. **Decay algebra** — the timestamped decayed state is still a commutative
   monoid: identity and commutativity bitwise, same-stamp merges bitwise
   equal to the undecayed merge (associating bitwise on quantized integer
   segments), cross-stamp associativity to
   float tolerance, and a closed-form check that any interleaving of
   update/decay_to/merge equals direct ``gamma**dt`` reweighting of the
   per-batch contributions.  Per backend (xla | pallas | sharded), decay at
   a constant tick is bitwise-transparent over the lifetime engine, and the
   quantized side-channel agrees with the float decay path.
2. **Ring-of-sketches window** — merge-on-read returns exactly the last W
   buckets, slot reuse never leaks an expired bucket into a read, and
   too-late arrivals are dropped rather than corrupting a reclaimed slot.
3. **Fleet-window isolation fuzz** — random timestamped schedules of
   aligned updates / routed ingests / tenant column evict-restore on a
   ``FleetEngine`` window stay bitwise equal to isolated per-tenant
   ``SketchEngine`` windows.
4. **Drift-triggered re-decode acceptance** — on a seeded drifting blobs
   stream, a decayed fleet with ``drift_threshold`` re-decodes itself back
   to within 5% of a fresh fit's SSE while the lifetime sketch degrades.

Run alone with:  pytest -m window
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ckm as ckm_mod
from repro.core import engine as eng_mod
from repro.core import fleet as fl
from repro.core import frequencies as fq
from repro.core import quantize as qz
from repro.core.ckm import CKMConfig
from repro.core.window import SketchWindow, WindowState
from repro.launch.specs import SketchJobSpec
from repro.serve.fleet_service import FleetService

from tests._hypothesis_compat import given, settings, st

pytestmark = pytest.mark.window

GAMMA = 0.5


def _data(seed, npts=200, n=4, m=24):
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (npts, n)) * 2.0
    w = fq.draw_frequencies(kw, m, n, 1.0)
    return x, w


def _states_equal(a, b):
    return all(
        bool(jnp.array_equal(x, y))
        for x, y in zip(
            jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
        )
    )


def _engines(quant="none", decay=GAMMA, m=24):
    """One decay-enabled engine per backend (pallas interpreted off-TPU)."""
    _, w = _data(5, npts=8, m=m)
    q = (
        qz.make_quantizer(jax.random.PRNGKey(3), m, quant)
        if quant != "none"
        else None
    )
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return {
        "xla": eng_mod.SketchEngine(w, "xla", quantizer=q, decay=decay),
        "pallas": eng_mod.SketchEngine(
            w, "pallas", block_n=128, block_m=128, quantizer=q, decay=decay
        ),
        "sharded": eng_mod.SketchEngine(
            w, "sharded", mesh=mesh, quantizer=q, decay=decay
        ),
    }


# -- 1. the decay algebra ------------------------------------------------------


class TestDecayMonoidLaws:
    @pytest.mark.parametrize("quant", ["none", "1bit", "8bit"])
    def test_identity_bitwise(self, quant):
        """merge(identity, s) == s == merge(s, identity), every leaf bitwise,
        for any stamp — the stamp=-inf identity decays to nothing."""
        x, w = _data(0)
        q = (
            qz.make_quantizer(jax.random.PRNGKey(3), 24, quant)
            if quant != "none"
            else None
        )
        e = eng_mod.SketchEngine(w, quantizer=q, decay=GAMMA)
        s = e.update(e.init_state(), x[:120], t=3.0)
        s = e.update(s, x[120:], t=7.0)
        assert _states_equal(e.merge(e.init_state(), s), s)
        assert _states_equal(e.merge(s, e.init_state()), s)
        # identity + identity stays the identity (the (-inf)-(-inf) edge)
        both = e.merge(e.init_state(), e.init_state())
        assert _states_equal(both, e.init_state())

    @pytest.mark.parametrize("quant", ["none", "8bit"])
    def test_commutativity_bitwise(self, quant):
        """merge(a, b) == merge(b, a) bitwise even across different stamps —
        both factor pairs and the symmetric adds are order-free."""
        x, w = _data(1)
        q = (
            qz.make_quantizer(jax.random.PRNGKey(3), 24, quant)
            if quant != "none"
            else None
        )
        e = eng_mod.SketchEngine(w, quantizer=q, decay=GAMMA)
        a = e.update(e.init_state(), x[:80], t=0.0)
        b = e.update(e.init_state(), x[80:], t=5.0)
        assert _states_equal(e.merge(a, b), e.merge(b, a))

    def test_same_stamp_merge_equals_undecayed_bitwise(self):
        """With equal stamps every decay factor is exactly 1.0 and the
        decayed merge reduces to the undecayed merge, bitwise — the decay
        layer perturbs nothing until time actually advances."""
        x, w = _data(2)
        e = eng_mod.SketchEngine(w, decay=GAMMA)
        base = eng_mod.SketchEngine(w)
        a = e.update(e.init_state(), x[:60], t=4.0)
        b = e.update(e.init_state(), x[60:], t=4.0)
        ab = e.merge(a, b)
        ref = base.merge(
            base.update(base.init_state(), x[:60]),
            base.update(base.init_state(), x[60:]),
        )
        for field in ("cos_acc", "sin_acc", "weight_sum", "lower", "upper",
                      "count"):
            assert bool(
                jnp.array_equal(getattr(ab, field), getattr(ref, field))
            ), field

    def test_same_stamp_associativity(self):
        """Same-stamp associativity: bitwise on the quantized int segments
        (integer adds associate exactly); float accumulators associate to
        the same tolerance the undecayed monoid tests pin (float + is not
        associative, decayed or not)."""
        x, w = _data(2)
        q = qz.make_quantizer(jax.random.PRNGKey(3), 24, "1bit")
        eq = eng_mod.SketchEngine(w, quantizer=q, decay=GAMMA)
        a, b, c = (
            eq.update(eq.init_state(), p, t=4.0)
            for p in (x[:60], x[60:130], x[130:])
        )
        left = eq.merge(eq.merge(a, b), c)
        right = eq.merge(a, eq.merge(b, c))
        assert _states_equal(left, right)  # int segments: fully bitwise

        ef = eng_mod.SketchEngine(w, decay=GAMMA)
        a, b, c = (
            ef.update(ef.init_state(), p, t=4.0)
            for p in (x[:60], x[60:130], x[130:])
        )
        left = ef.merge(ef.merge(a, b), c)
        right = ef.merge(a, ef.merge(b, c))
        for zl, zr in zip(ef.finalize(left), ef.finalize(right)):
            np.testing.assert_allclose(
                np.asarray(zl), np.asarray(zr), atol=1e-5
            )

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        ta=st.integers(0, 6),
        tb=st.integers(0, 6),
        tc=st.integers(0, 6),
    )
    def test_cross_stamp_associativity(self, seed, ta, tb, tc):
        """Across stamps the merge is associative to float tolerance (the
        factors distribute mathematically; float * is not associative)."""
        x, w = _data(seed)
        e = eng_mod.SketchEngine(w, decay=GAMMA)
        a = e.update(e.init_state(), x[:60], t=float(ta))
        b = e.update(e.init_state(), x[60:130], t=float(tb))
        c = e.update(e.init_state(), x[130:], t=float(tc))
        left = e.merge(e.merge(a, b), c)
        right = e.merge(a, e.merge(b, c))
        for zl, zr in zip(e.finalize(left), e.finalize(right)):
            np.testing.assert_allclose(
                np.asarray(zl), np.asarray(zr), atol=1e-5
            )
        assert float(left.stamp) == float(right.stamp) == max(ta, tb, tc)

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        ticks=st.lists(
            st.integers(0, 8), min_size=2, max_size=5, unique=True
        ),
    )
    def test_closed_form_exponential_reweighting(self, seed, ticks):
        """Interleaved update/decay_to/merge == direct gamma**dt reweighting
        of the per-batch contributions — the semantic anchor of the whole
        transform."""
        ticks = sorted(ticks)
        x, w = _data(seed, npts=60 * len(ticks))
        e = eng_mod.SketchEngine(w, decay=GAMMA)
        base = eng_mod.SketchEngine(w)  # undecayed partials for the oracle
        batches = [x[i * 60 : (i + 1) * 60] for i in range(len(ticks))]

        s = e.init_state()
        for tk, b in zip(ticks, batches):
            # a gratuitous clock advance between folds must change nothing
            s = e.decay_to(s, float(tk))
            s = e.update(s, b, t=float(tk))
        t_end = float(ticks[-1]) + 2.0
        s = e.decay_to(s, t_end)
        z, lo, hi = e.finalize(s)

        cos = jnp.zeros((24,))
        sin = jnp.zeros((24,))
        wsum = jnp.zeros(())
        for tk, b in zip(ticks, batches):
            p = base._partial_state(b, None)
            f = GAMMA ** (t_end - tk)
            cos = cos + f * p.cos_acc
            sin = sin + f * p.sin_acc
            wsum = wsum + f * p.weight_sum
        z_ref = jnp.concatenate([cos, -sin]) / wsum
        np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(lo), np.asarray(x.min(0)))
        np.testing.assert_allclose(np.asarray(hi), np.asarray(x.max(0)))
        assert float(s.count) == x.shape[0]  # counts never decay

    def test_full_decay_finalizes_to_zero_sketch(self):
        """weight_sum -> 0 under long decay hits the zero-weight finalize
        guard, not accumulator/denom garbage."""
        x, w = _data(4)
        e = eng_mod.SketchEngine(w, decay=GAMMA)
        s = e.update(e.init_state(), x, t=0.0)
        s = e.decay_to(s, 1e4)
        z, _, _ = e.finalize(s)
        assert bool(jnp.all(z == 0.0))

    def test_merge_rejects_mismatched_flavours(self):
        x, w = _data(6)
        e = eng_mod.SketchEngine(w, decay=GAMMA)
        base = eng_mod.SketchEngine(w)
        with pytest.raises(TypeError, match="mismatched state flavours"):
            eng_mod._merge_states(
                e.update(e.init_state(), x, t=0.0),
                base.update(base.init_state(), x),
            )

    def test_t_requires_decay(self):
        x, w = _data(6)
        e = eng_mod.SketchEngine(w)
        with pytest.raises(ValueError, match="decay-enabled"):
            e.update(e.init_state(), x, t=1.0)
        with pytest.raises(ValueError, match="decay-enabled"):
            e.decay_to(e.init_state(), 1.0)
        with pytest.raises(ValueError, match="decay must be in"):
            eng_mod.SketchEngine(w, decay=1.5)


class TestDecayBackendParity:
    @pytest.mark.parametrize("quant", ["none", "1bit"])
    def test_constant_tick_bitwise_transparent(self, quant):
        """Per backend: folding everything at one tick through the decayed
        transform finalizes bitwise equal to the same backend's lifetime
        engine — the decay layer adds no numeric perturbation of its own."""
        x, _ = _data(8)
        for name, e in _engines(quant).items():
            life = eng_mod.SketchEngine(
                e.freq_op,
                e.backend,
                block_n=e.block_n,
                block_m=e.block_m,
                mesh=e.mesh,
                quantizer=e.quantizer,
            )
            sd = e.update(e.init_state(), x[:100], t=2.0)
            sd = e.update(sd, x[100:], t=2.0)
            sl = life.update(life.init_state(), x[:100])
            sl = life.update(sl, x[100:])
            for zd, zl in zip(e.finalize(sd), life.finalize(sl)):
                assert bool(jnp.array_equal(zd, zl)), name

    def test_quantized_decay_bitwise_across_backends(self):
        """Quantized decayed states are bitwise identical across the three
        backends: int codes are bitwise (the existing engine contract) and
        the decay factors are the same scalar float ops everywhere."""
        x, _ = _data(9)
        states, finals = {}, {}
        for name, e in _engines("1bit").items():
            s = e.update(e.init_state(), x[:100], t=0.0)
            s = e.update(s, x[100:], t=3.0)
            states[name], finals[name] = s, e.finalize(s)
        ref = states["xla"]
        for name in ("pallas", "sharded"):
            assert _states_equal(states[name], ref), name
            for za, zb in zip(finals[name], finals["xla"]):
                assert bool(jnp.array_equal(za, zb)), name

    def test_float_decay_parity_across_backends(self):
        """Float decayed sketches agree across backends to the same 1e-4 the
        undecayed parity tests pin."""
        x, _ = _data(10)
        finals = {}
        for name, e in _engines("none").items():
            s = e.update(e.init_state(), x[:100], t=0.0)
            s = e.update(s, x[100:], t=3.0)
            finals[name] = e.finalize(s)
        for name in ("pallas", "sharded"):
            for za, zb in zip(finals[name], finals["xla"]):
                np.testing.assert_allclose(
                    np.asarray(za), np.asarray(zb), atol=1e-4
                )

    def test_quantized_agrees_with_float_decay(self):
        """The int-segment + float-side-channel construction tracks the pure
        float decay path: 8-bit codes keep the decayed sketch within a few
        1e-3, same ballpark as undecayed quantization error."""
        x, w = _data(11, npts=400)
        q = qz.make_quantizer(jax.random.PRNGKey(3), 24, "8bit")
        ef = eng_mod.SketchEngine(w, decay=GAMMA)
        eq = eng_mod.SketchEngine(w, quantizer=q, decay=GAMMA)
        sf, sq = ef.init_state(), eq.init_state()
        for i, tk in enumerate([0.0, 1.0, 4.0]):
            b = x[i * 130 : (i + 1) * 130]
            sf = ef.update(sf, b, t=tk)
            sq = eq.update(sq, b, t=tk)
        zf, _, _ = ef.finalize(sf)
        zq, _, _ = eq.finalize(sq)
        np.testing.assert_allclose(np.asarray(zq), np.asarray(zf), atol=5e-3)

    def test_quantized_same_tick_split_invariance_bitwise(self):
        """Same-tick folds keep the int32 segment exact: any batch split at
        one tick gives bitwise identical decayed quantized states."""
        x, w = _data(12)
        q = qz.make_quantizer(jax.random.PRNGKey(3), 24, "1bit")
        e = eng_mod.SketchEngine(w, quantizer=q, decay=GAMMA)
        one = e.update(e.init_state(), x, t=5.0)
        two = e.update(e.init_state(), x[:77], t=5.0)
        two = e.update(two, x[77:], t=5.0)
        assert _states_equal(one, two)

    def test_ckm_config_threads_decay(self):
        """CKMConfig.decay reaches the engine; the streaming fit runs on the
        decayed transform end to end."""
        _, w = _data(13)
        cfg = CKMConfig(k=2, decay=GAMMA)
        e = ckm_mod.make_engine(w, cfg)
        assert e.decay == GAMMA
        assert isinstance(e.init_state(), eng_mod.DecayedSketchEngineState)
        assert ckm_mod.make_engine(w, CKMConfig(k=2)).decay is None


# -- 2. the ring-of-sketches window --------------------------------------------


class TestSketchWindow:
    def _setup(self, decay=None, buckets=3):
        x, w = _data(20, npts=600)
        e = eng_mod.SketchEngine(w, decay=decay)
        return x, e, SketchWindow(e, buckets)

    def test_merge_on_read_is_exactly_last_w_buckets(self):
        x, e, sw = self._setup()
        ws = sw.init_state()
        chunks = {t: x[t * 100 : (t + 1) * 100] for t in range(6)}
        for t, b in chunks.items():
            ws = sw.update(ws, b, t=float(t))
        # read at t=5 with W=3 -> ticks {3, 4, 5}
        ref = e.init_state()
        for t in (3, 4, 5):
            ref = e.update(ref, chunks[t])
        assert _states_equal(sw.read(ws, 5.0), ref)
        # t=None reads at the newest claimed tick
        assert _states_equal(sw.read(ws), ref)
        for za, zb in zip(sw.finalize(ws), e.finalize(ref)):
            assert bool(jnp.array_equal(za, zb))

    def test_slot_reuse_never_leaks_expired_bucket(self):
        """Tick 0 and tick 3 share slot 0 (W=3): once tick 3 claims it, no
        read at any time can see tick 0's data again."""
        x, e, sw = self._setup()
        ws = sw.init_state()
        poison = x[:100] + 100.0  # unmistakable if it leaks
        ws = sw.update(ws, poison, t=0.0)
        for t in (1, 2, 3):
            ws = sw.update(ws, x[t * 100 : (t + 1) * 100], t=float(t))
        assert int(ws.slot_tick[0]) == 3  # slot 0 recycled
        for read_t in (3.0, 4.0, 5.0, 100.0):
            st_read = sw.read(ws, read_t)
            if float(st_read.count) > 0:
                assert float(st_read.upper.max()) < 50.0
        # a mid-ring read older than head excludes the newer buckets too:
        # at t=2 only ticks {1, 2} are visible (tick 3 is in the future)
        ref = e.init_state()
        for t in (1, 2):
            ref = e.update(ref, x[t * 100 : (t + 1) * 100])
        assert _states_equal(sw.read(ws, 2.0), ref)

    def test_late_arrival_is_dropped_not_folded(self):
        """An update older than the whole ring must not corrupt the slot its
        tick hashes to."""
        x, e, sw = self._setup()
        ws = sw.init_state()
        for t in (1, 2, 3, 4):
            ws = sw.update(ws, x[t * 100 : (t + 1) * 100], t=float(t))
        before = sw.read(ws, 4.0)
        ws2 = sw.update(ws, x[:100] + 999.0, t=0.0)  # tick 0 <= head-W
        assert _states_equal(sw.read(ws2, 4.0), before)

    def test_window_with_decay_reads_at_query_time(self):
        """decay inside the window + hard cutoff at its edge: a read at t
        equals the closed-form reweighting of the surviving buckets."""
        x, e, sw = self._setup(decay=GAMMA)
        base = eng_mod.SketchEngine(e.freq_op)
        ws = sw.init_state()
        chunks = {t: x[t * 100 : (t + 1) * 100] for t in (0, 1, 2, 4)}
        for t, b in chunks.items():
            ws = sw.update(ws, b, t=float(t))
        t_q = 5.0
        got = sw.read(ws, t_q)
        z, _, _ = e.finalize(got)
        cos = jnp.zeros((24,))
        sin = jnp.zeros((24,))
        wsum = jnp.zeros(())
        for t in (4,):  # W=3 at tick 5 -> ticks {3,4,5}; only 4 has data
            p = base._partial_state(chunks[t], None)
            f = GAMMA ** (t_q - t)
            cos, sin = cos + f * p.cos_acc, sin + f * p.sin_acc
            wsum = wsum + f * p.weight_sum
        z_ref = jnp.concatenate([cos, -sin]) / wsum
        np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), atol=1e-5)
        assert float(got.stamp) == t_q

    def test_bucket_ticks_scaling(self):
        """bucket_ticks groups a tick range into one bucket."""
        x, e, _ = self._setup()
        sw = SketchWindow(e, 2, bucket_ticks=10.0)
        ws = sw.init_state()
        ws = sw.update(ws, x[:100], t=3.0)  # tick 0
        ws = sw.update(ws, x[100:200], t=9.9)  # tick 0 (same bucket)
        ws = sw.update(ws, x[200:300], t=10.0)  # tick 1
        ref = e.update(e.init_state(), x[:100])
        ref = e.update(ref, x[100:200])
        ref = e.update(ref, x[200:300])
        assert _states_equal(sw.read(ws, 15.0), ref)
        # tick 2 expires bucket 0
        ws = sw.update(ws, x[300:400], t=25.0)
        ref2 = e.update(e.init_state(), x[200:300])
        ref2 = e.update(ref2, x[300:400])
        assert _states_equal(sw.read(ws, 25.0), ref2)

    def test_constructor_validation(self):
        _, e, _ = self._setup()
        with pytest.raises(ValueError, match="buckets"):
            SketchWindow(e, 0)
        with pytest.raises(ValueError, match="bucket_ticks"):
            SketchWindow(e, 3, bucket_ticks=0.0)

    def test_memory_is_o_w_m(self):
        _, e, _ = self._setup()
        w2, w8 = SketchWindow(e, 2), SketchWindow(e, 8)
        b2 = w2.state_bytes(w2.init_state())
        b8 = w8.state_bytes(w8.init_state())
        assert b8 == 4 * b2


# -- 3. fleet-window isolation fuzz --------------------------------------------


T_FLEET, B_FLEET, N_FLEET, M_FLEET = 3, 8, 3, 32


def _fleet_window(quant="none", decay=GAMMA, buckets=3):
    specs = fl.fleet_specs(
        jax.random.PRNGKey(0), T_FLEET, "dense", M_FLEET, N_FLEET, 1.5
    )
    quants = fl.fleet_quantizers(
        jax.random.PRNGKey(7), T_FLEET, M_FLEET, quant
    )
    fe = fl.FleetEngine(specs, quantizers=quants, decay=decay)
    return fe, SketchWindow(fe, buckets)


class TestFleetWindowIsolation:
    @settings(max_examples=5, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        quant=st.sampled_from(["none", "1bit"]),
    )
    def test_fuzz_bitwise_vs_isolated_tenant_windows(self, seed, quant):
        """Random timestamped schedules of aligned update / routed ingest /
        tenant column reset-restore on a FleetEngine window == isolated
        per-tenant SketchEngine windows, bitwise, read at the same global t.
        """
        rng = np.random.default_rng(seed)
        fe, fw = _fleet_window(quant)
        refs = [fe.tenant_engine(t) for t in range(T_FLEET)]
        rws = [SketchWindow(e, fw.buckets) for e in refs]

        ws = fw.init_state()
        rstates = [w.init_state() for w in rws]
        clock = 0.0
        for _ in range(rng.integers(4, 9)):
            clock += float(rng.integers(0, 3))
            action = rng.choice(["update", "ingest", "evict_restore"])
            if action == "update":
                blk = jnp.asarray(
                    rng.normal(size=(T_FLEET, B_FLEET, N_FLEET)), jnp.float32
                )
                ws = fw.update(ws, blk, t=clock)
                for t in range(T_FLEET):
                    rstates[t] = rws[t].update(rstates[t], blk[t], t=clock)
            elif action == "ingest":
                r = int(rng.integers(1, 5))
                ids = rng.integers(0, T_FLEET, r)  # duplicates welcome
                bt = jnp.asarray(
                    rng.normal(size=(r, B_FLEET, N_FLEET)), jnp.float32
                )
                ws = fw.ingest(ws, ids, bt, t=clock)
                for j, tid in enumerate(ids):
                    rstates[tid] = rws[tid].update(
                        rstates[tid], bt[j], t=clock
                    )
            else:  # evict + immediate restore must be invisible
                tid = int(rng.integers(0, T_FLEET))
                col = fw.tenant_column(ws, tid)
                ws = fw.reset_tenant(ws, tid)
                ws = fw.set_tenant_column(ws, tid, col)

        # Both sides read at the same explicit global time — per-tenant slot
        # bookkeeping may lag the fleet's (a tenant can skip ticks), but the
        # read filter sees the identical tick range either way.
        merged = fw.read(ws, clock)
        for t in range(T_FLEET):
            row = fe.tenant_state(merged, t)
            ref = rws[t].read(rstates[t], clock)
            assert _states_equal(row, ref), f"tenant {t} diverged"
            zf, zl, zh = fe.finalize_tenant(merged, t)
            rf, rl, rh = refs[t].finalize(ref)
            assert bool(jnp.array_equal(zf, rf))

    def test_ring_rotation_no_stale_bucket_fleet(self):
        """Fleet flavour of the leak test: wrap the ring, assert the expired
        block's unmistakable data is gone from merge-on-read."""
        fe, fw = _fleet_window("none", decay=None)
        rng = np.random.default_rng(0)
        ws = fw.init_state()
        poison = jnp.full((T_FLEET, B_FLEET, N_FLEET), 100.0, jnp.float32)
        ws = fw.update(ws, poison, t=0.0)
        for t in (1, 2, 3):
            blk = jnp.asarray(
                rng.normal(size=(T_FLEET, B_FLEET, N_FLEET)), jnp.float32
            )
            ws = fw.update(ws, blk, t=float(t))
        merged = fw.read(ws, 3.0)
        assert float(merged.upper.max()) < 50.0


# -- 4. drift-triggered re-decode acceptance -----------------------------------


def _decode_cfg(**overrides):
    cfg = CKMConfig(
        k=2,
        decoder="sketch_shift",
        shift_candidates=4,
        shift_steps=40,
        shift_polish_steps=10,
        nnls_iters=10,
        replicates=3,  # single-replicate sketch_shift can land on a bad basin
    )
    return dataclasses.replace(cfg, **overrides)


def _blobs(rng, centers, n=160, scale=0.25):
    centers = np.asarray(centers, np.float32)
    lab = rng.integers(0, centers.shape[0], n)
    return (centers[lab] + rng.normal(0, scale, (n, 2))).astype(np.float32)


def _sse(x, centroids):
    x = np.asarray(x)
    c = np.asarray(centroids)
    d = ((x[:, None] - c[None]) ** 2).sum(-1)
    return float(d.min(1).sum())


class TestDriftTriggeredRedecode:
    def test_redecode_recovers_sse_lifetime_degrades(self):
        """Acceptance (ISSUE 9): on a seeded drifting blobs stream the
        decay + drift_threshold fleet's *served model* re-decodes to within
        5% of a fresh same-operator fit's SSE on the live distribution,
        while the lifetime fleet — whose drift gauge can see the shift but
        which has nothing acting on it — keeps serving the stale phase-A
        decode and degrades by orders of magnitude."""
        rng = np.random.default_rng(42)
        m = 64
        old_c = [[-3.0, -3.0], [3.0, 3.0]]
        new_c = [[9.0, 9.0], [15.0, 3.0]]
        specs = fl.fleet_specs(jax.random.PRNGKey(2), 1, "dense", m, 2, 4.0)

        decayed = FleetService(
            fl.FleetEngine(specs, decay=0.5),
            _decode_cfg(),
            drift_threshold=0.15,
        )
        lifetime = FleetService(fl.FleetEngine(specs), _decode_cfg())

        phase_a = [_blobs(rng, old_c) for _ in range(4)]
        phase_b = [_blobs(rng, new_c) for _ in range(10)]
        tick = 0.0
        for batch in phase_a:
            decayed.submit(0, batch, t=tick)
            decayed.flush()
            lifetime.submit(0, batch)
            lifetime.flush()
            tick += 1.0
        decayed.decode(0)  # the served model maintenance will refresh
        lifetime.decode(0)  # the served model nothing will ever refresh
        assert decayed.stats.drift_redecodes == 0
        for batch in phase_b:
            decayed.submit(0, batch, t=tick)
            decayed.flush()  # auto-maintains: scores drift, re-decodes
            lifetime.submit(0, batch)
            lifetime.flush()
            tick += 1.0

        assert decayed.stats.drift_redecodes >= 1
        eval_pts = _blobs(rng, new_c, n=600)

        # Recovery target: a fresh decode of the live distribution through
        # the SAME operator the fleet uses (apples-to-apples — a separately
        # drawn operator with data-adapted sigma^2 would measure operator
        # quality, not staleness), keyed the way FleetService keys tenant 0.
        op = decayed.engine.operator(0)
        z, lo, hi = eng_mod.SketchEngine(op).sketch(
            jnp.asarray(np.concatenate(phase_b))
        )
        fresh_c, _, _ = ckm_mod.decode_sketch(
            jax.random.fold_in(jax.random.PRNGKey(0), 0),
            z,
            op,
            lo,
            hi,
            _decode_cfg(),
        )
        sse_fresh = _sse(eval_pts, fresh_c)
        sse_decayed = _sse(eval_pts, decayed.served_model(0).centroids)
        sse_lifetime = _sse(eval_pts, lifetime.served_model(0).centroids)

        assert sse_decayed <= 1.05 * sse_fresh, (
            f"drift-maintained served SSE {sse_decayed:.1f} not within 5% "
            f"of fresh-fit SSE {sse_fresh:.1f}"
        )
        assert sse_lifetime > 2.0 * sse_fresh, (
            f"stale lifetime served model unexpectedly kept up: "
            f"{sse_lifetime:.1f} vs fresh {sse_fresh:.1f}"
        )

    def test_fresh_tenant_drift_is_defined(self):
        """Regression (ISSUE 9): drift on an all-zero sketch — fresh tenant
        or fully decayed — is 0.0, not NaN, and never decodes."""
        specs = fl.fleet_specs(jax.random.PRNGKey(0), 2, "dense", 32, 2, 1.0)
        svc = FleetService(fl.FleetEngine(specs, decay=0.5), _decode_cfg())
        score = svc.drift(0)
        assert score == 0.0 and not np.isnan(score)
        assert svc.stats.decodes == 0  # the guard short-circuits the decode

        # fully decayed: fold data, then let the mass decay to ~0 exactly
        rng = np.random.default_rng(1)
        svc.submit(1, _blobs(rng, [[0.0, 0.0]]), t=0.0)
        svc.flush()
        svc.state = svc.engine.decay_to(svc.state, 1e4)
        svc._touch([1])
        assert svc.drift(1) == 0.0

    def test_zero_live_sketch_drift_score(self):
        """obs.diagnose.sketch_drift itself defines the 0/0 case as 0.0."""
        from repro.obs.diagnose import sketch_drift

        _, w = _data(30, m=24)
        z0 = jnp.zeros((48,))
        cents = jnp.asarray([[1.0, 0.0, 0.0, 0.0], [0.0, 1.0, 0.0, 0.0]])
        wts = jnp.asarray([0.5, 0.5])
        s = sketch_drift(z0, cents, wts, w)
        assert s == 0.0 and not np.isnan(s)

    def test_submit_t_requires_decay(self):
        specs = fl.fleet_specs(jax.random.PRNGKey(0), 1, "dense", 32, 2, 1.0)
        svc = FleetService(fl.FleetEngine(specs), _decode_cfg())
        with pytest.raises(ValueError, match="decay-enabled"):
            svc.submit(0, np.zeros((4, 2), np.float32), t=1.0)
        with pytest.raises(ValueError, match="drift_threshold"):
            FleetService(
                fl.FleetEngine(specs), _decode_cfg(), drift_threshold=0.0
            )


# -- launch-spec plumbing ------------------------------------------------------


class TestTemporalJobSpec:
    def test_spec_accepts_and_describes_temporal_fields(self):
        spec = SketchJobSpec(
            decay=0.9,
            window_buckets=8,
            window_bucket_ticks=60.0,
            drift_threshold=0.4,
        ).validate()
        assert spec.ckm_overrides()["decay"] == 0.9
        d = spec.describe()
        assert "decay=0.9" in d and "window=8x60.0" in d
        assert "drift_threshold=0.4" in d

    def test_spec_rejects_bad_temporal_fields(self):
        with pytest.raises(ValueError, match="decay"):
            SketchJobSpec(decay=0.0).validate()
        with pytest.raises(ValueError, match="window_buckets"):
            SketchJobSpec(window_buckets=-1).validate()
        with pytest.raises(ValueError, match="window_bucket_ticks"):
            SketchJobSpec(window_buckets=4, window_bucket_ticks=0.0).validate()
        with pytest.raises(ValueError, match="drift_threshold"):
            SketchJobSpec(drift_threshold=-0.1).validate()
