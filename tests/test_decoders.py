"""Decoder subsystem tests: registry contract, bitwise CLOMPR parity,
replicate monotonicity, and sketch-permutation invariance (marker: decoder).

The registry (``repro.core.decoders``) must be a faithful refactor — the
``"clompr"`` entry has to reproduce the pre-registry direct-call path
*bitwise* — and every registered decoder must honour the shared contract:
same ``(centroids, alphas, cost)`` signature, the same sketch-domain cost
objective (so best-of-R replicate selection is monotone for all of them), and
invariance to the arbitrary ordering of the frequency rows of ``(z, w)``.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import CKMConfig, available_decoders, decode_sketch, get_decoder
from repro.core import ckm as ckm_mod
from repro.core.clompr import clompr  # the pre-refactor import path
from repro.core.decoders import DECODERS, register_decoder
from repro.data import synthetic

pytestmark = pytest.mark.decoder

# Shrunk-but-converging decoder budgets: each distinct config compiles once,
# then every test reuses the jit cache (shapes and statics are shared).
FAST = dict(
    atom_steps=60, joint_steps=40, nnls_iters=60, final_steps=120,
    shift_steps=40, shift_polish_steps=150,
    amp_iters=40, amp_polish_steps=150,
)


@functools.lru_cache(maxsize=1)
def _problem():
    """A fixed small sketch problem: (z, w, lo, hi, x) on separated blobs.

    Cached at module level (not a fixture) so the hypothesis-style property
    test can use it too — ``@given``-wrapped tests cannot take pytest
    fixture arguments under the no-dependency fallback shim.
    """
    key = jax.random.PRNGKey(7)
    x, _, _ = synthetic.gaussian_mixture(key, 3000, k=3, n=3, c=6.0, return_labels=True)
    cfg = CKMConfig(k=3, m=120, **FAST)
    z, w, _, (lo, hi) = ckm_mod.compute_sketch(jax.random.PRNGKey(1), x, cfg)
    return z, w, lo, hi, x


@pytest.fixture(scope="module")
def problem():
    return _problem()


class TestRegistry:
    def test_builtins_registered(self):
        assert set(available_decoders()) >= {"clompr", "sketch_shift", "amp"}

    def test_unknown_decoder_raises_with_names(self, problem):
        with pytest.raises(KeyError, match="clompr"):
            get_decoder("gamp_v2")
        z, w, lo, hi, _ = problem
        with pytest.raises(KeyError, match="available"):
            decode_sketch(
                jax.random.PRNGKey(0), z, w, lo, hi,
                CKMConfig(k=3, decoder="nope", **FAST),
            )

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_decoder("clompr")(lambda *a, **k: None)

    def test_custom_decoder_threads_through_decode_sketch(self, problem):
        """A user-registered decoder is selectable via CKMConfig.decoder."""
        z, w, lo, hi, _ = problem
        name = "test_centroid_of_box"

        def box_mid(key, z_, w_, lower, upper, cfg, x_init=None):
            cents = jnp.tile((lower + upper)[None, :] / 2.0, (cfg.k, 1))
            alphas = jnp.full((cfg.k,), 1.0 / cfg.k)
            return cents, alphas, jnp.asarray(0.0)

        DECODERS.pop(name, None)
        register_decoder(name)(box_mid)
        try:
            cents, alphas, cost = decode_sketch(
                jax.random.PRNGKey(0), z, w, lo, hi,
                CKMConfig(k=3, decoder=name, **FAST),
            )
            np.testing.assert_allclose(
                np.asarray(cents), np.tile(np.asarray(lo + hi)[None] / 2, (3, 1))
            )
        finally:
            DECODERS.pop(name)


class TestClomprBitwiseParity:
    def test_registry_matches_pre_refactor_path(self, problem):
        """Registry-"clompr" == the direct clompr() call, bit for bit."""
        z, w, lo, hi, _ = problem
        cfg = CKMConfig(k=3, decoder="clompr", **FAST)
        key = jax.random.PRNGKey(3)
        via_registry = decode_sketch(key, z, w, lo, hi, cfg)
        # What ckm.decode_sketch did before the registry existed (replicate 0
        # uses fold_in(key, 0)):
        direct = clompr(
            jax.random.fold_in(key, 0), z, w, lo, hi, cfg.clompr_config()
        )
        for got, want in zip(via_registry, direct):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_replicated_registry_matches_direct_map(self, problem):
        """Best-of-R via the registry == a hand-rolled lax.map over clompr."""
        z, w, lo, hi, _ = problem
        cfg = CKMConfig(k=3, decoder="clompr", replicates=2, **FAST)
        key = jax.random.PRNGKey(4)
        via_registry = decode_sketch(key, z, w, lo, hi, cfg)
        keys = jnp.stack([jax.random.fold_in(key, r) for r in range(2)])
        cents, alphas, costs = jax.lax.map(
            lambda k_: clompr(k_, z, w, lo, hi, cfg.clompr_config()), keys
        )
        best = jnp.argmin(costs)
        np.testing.assert_array_equal(
            np.asarray(via_registry[0]), np.asarray(cents[best])
        )
        np.testing.assert_array_equal(
            np.asarray(via_registry[2]), np.asarray(costs[best])
        )


@pytest.mark.slow
class TestDecoderContract:
    @pytest.mark.parametrize("decoder", ["clompr", "sketch_shift", "amp"])
    def test_replicate_monotonicity(self, problem, decoder):
        """Best-of-R cost is non-increasing in R for every decoder (the
        replicate-key sequence for R is a prefix of the one for R' > R)."""
        z, w, lo, hi, _ = problem
        key = jax.random.PRNGKey(5)
        costs = {}
        for reps in (1, 3):
            cfg = CKMConfig(k=3, decoder=decoder, replicates=reps, **FAST)
            _, _, cost = decode_sketch(key, z, w, lo, hi, cfg)
            costs[reps] = float(cost)
        assert costs[3] <= costs[1] + 1e-6, costs

    @pytest.mark.parametrize("decoder", ["clompr", "sketch_shift", "amp"])
    def test_output_contract(self, problem, decoder):
        """(K, n) centroids inside the box, normalised weights, finite cost."""
        z, w, lo, hi, _ = problem
        cfg = CKMConfig(k=3, decoder=decoder, **FAST)
        cents, alphas, cost = decode_sketch(
            jax.random.PRNGKey(6), z, w, lo, hi, cfg
        )
        assert cents.shape == (3, 3) and alphas.shape == (3,)
        assert bool(jnp.all(cents >= lo - 1e-5)) and bool(jnp.all(cents <= hi + 1e-5))
        a = np.asarray(alphas)
        assert np.all(a >= 0) and abs(a.sum() - 1.0) < 1e-5
        assert np.isfinite(float(cost))

    @pytest.mark.parametrize("decoder", ["clompr", "sketch_shift", "amp"])
    @pytest.mark.parametrize("init", ["sample", "kpp"])
    def test_x_init_strategies_run(self, problem, decoder, init):
        z, w, lo, hi, x = problem
        cfg = CKMConfig(k=3, decoder=decoder, init=init, **FAST)
        cents, _, _ = decode_sketch(
            jax.random.PRNGKey(8), z, w, lo, hi, cfg, x_init=x[:512]
        )
        assert np.all(np.isfinite(np.asarray(cents)))

    def test_sketch_shift_quantized_end_to_end(self, problem):
        """Tentpole claim: the new decoder is quantized-sketch compatible."""
        _, _, _, _, x = problem
        cfg = CKMConfig(
            k=3, m=120, decoder="sketch_shift", sketch_quantization="1bit",
            **FAST,
        )
        res = ckm_mod.fit(jax.random.PRNGKey(9), x, cfg)
        float_cfg = dataclasses.replace(cfg, sketch_quantization="none")
        ref = ckm_mod.fit(jax.random.PRNGKey(9), x, float_cfg)
        # Quantization noise must not blow up the decoded solution.
        rel = float(ckm_mod.sse(x, res.centroids)) / float(
            ckm_mod.sse(x, ref.centroids)
        )
        assert rel < 1.10, rel

    def test_sketch_shift_streaming(self, problem):
        """fit_streaming works with the new decoder (one-pass contract)."""
        from repro.data import pipeline

        _, _, _, _, x = problem
        cfg = CKMConfig(k=3, m=120, decoder="sketch_shift", **FAST)
        res = ckm_mod.fit_streaming(
            jax.random.PRNGKey(10), pipeline.chunked(x, 640), cfg
        )
        batch = ckm_mod.fit(jax.random.PRNGKey(10), x, cfg)
        # Same key -> same frequencies; the sketches agree up to float
        # accumulation order (the batching differs), so the decodes must land
        # on the same solution — 0.05 is far below the unit cluster std.
        np.testing.assert_allclose(
            np.asarray(res.centroids), np.asarray(batch.centroids), atol=5e-2
        )


@pytest.mark.slow
class TestPermutationInvariance:
    """Property: a decoder may not depend on the arbitrary order of the
    frequency rows of (z, w) — permuting the columns of w together with both
    stacked-real halves of z is a pure relabeling of the same sketch."""

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_cost_invariant_under_frequency_permutation(self, seed):
        z, w, lo, hi, _ = _problem()
        m = w.m
        w_mat = w.materialize()  # permuting needs the dense view; the raw
        # matrix rides the deprecation shim through decode_sketch below
        perm = np.random.default_rng(seed).permutation(m)
        z_p = jnp.concatenate([z[:m][perm], z[m:][perm]])
        w_p = w_mat[:, perm]
        key = jax.random.PRNGKey(11)
        for decoder in ("clompr", "sketch_shift"):
            cfg = CKMConfig(k=3, decoder=decoder, **FAST)
            _, _, cost = decode_sketch(key, z, w, lo, hi, cfg)
            _, _, cost_p = decode_sketch(key, z_p, w_p, lo, hi, cfg)
            # The objective and every decoder step are sums over frequencies,
            # so the decode is permutation-invariant up to float
            # reassociation.
            np.testing.assert_allclose(
                float(cost_p), float(cost), rtol=2e-2, atol=1e-4, err_msg=decoder
            )
