"""Substrate tests: optimizers, checkpointing, data determinism, train loop
fault tolerance (checkpoint/restart), gradient compression, balancer."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizers import OptConfig, make_optimizer


class TestOptimizers:
    def _quadratic_converges(self, name):
        cfg = OptConfig(name=name, lr=0.1, warmup=5, total_steps=300, weight_decay=0.0)
        opt = make_optimizer(cfg)
        params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(5.0)}
        state = opt.init(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2) + p["b"] ** 2

        for step in range(300):
            g = jax.grad(loss)(params)
            params, state, _ = opt.update(g, state, params, jnp.asarray(step))
        assert float(loss(params)) < 1e-2, (name, float(loss(params)))

    @pytest.mark.parametrize("name", ["adamw", "adamw8", "adafactor", "sgd"])
    def test_converges_on_quadratic(self, name):
        self._quadratic_converges(name)

    def test_adamw8_tracks_adamw(self):
        """int8 state quantisation stays close to exact Adam trajectories."""
        key = jax.random.PRNGKey(0)
        w0 = jax.random.normal(key, (64, 32))
        target = jax.random.normal(jax.random.PRNGKey(1), (64, 32))

        def run(name):
            opt = make_optimizer(OptConfig(name=name, lr=0.05, warmup=1,
                                           total_steps=100, weight_decay=0.0))
            p = {"w": w0}
            s = opt.init(p)
            for i in range(60):
                g = jax.grad(lambda pp: jnp.mean((pp["w"] - target) ** 2))(p)
                p, s, _ = opt.update(g, s, p, jnp.asarray(i))
            return p["w"]

        exact = run("adamw")
        quant = run("adamw8")
        rel = float(jnp.linalg.norm(exact - quant) / jnp.linalg.norm(exact))
        assert rel < 0.10, rel

    def test_adafactor_memory_factored(self):
        opt = make_optimizer(OptConfig(name="adafactor"))
        params = {"w": jnp.zeros((128, 64))}
        state = opt.init(params)
        n_state = sum(x.size for x in jax.tree.leaves(state["stats"]))
        assert n_state == 128 + 64  # vr + vc, not 128*64

    def test_grad_clipping(self):
        from repro.optim.optimizers import clip_by_global_norm

        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) > 100
        total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
        np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


class TestCheckpointer:
    def test_roundtrip_and_latest(self, tmp_path):
        from repro.checkpoint.checkpointer import Checkpointer

        ck = Checkpointer(tmp_path, keep=2)
        state = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                 "step": jnp.asarray(7)}
        ck.save(7, state)
        ck.save(14, jax.tree.map(lambda x: x * 2, state))
        assert ck.latest_step() == 14
        restored = ck.restore(state, step=7)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))

    def test_retention_prunes(self, tmp_path):
        from repro.checkpoint.checkpointer import Checkpointer

        ck = Checkpointer(tmp_path, keep=2)
        state = {"x": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            ck.save(s, state)
        assert ck.all_steps() == [3, 4]

    def test_torn_checkpoint_ignored(self, tmp_path):
        from repro.checkpoint.checkpointer import Checkpointer

        ck = Checkpointer(tmp_path, keep=3)
        state = {"x": jnp.ones(4)}
        ck.save(5, state)
        # simulate a crash mid-write: tmp dir + a final dir missing manifest
        (tmp_path / "step_0000000009.tmp").mkdir()
        (tmp_path / "step_0000000008").mkdir()
        assert ck.latest_step() == 5
        restored = ck.restore(state)
        np.testing.assert_array_equal(np.asarray(restored["x"]), np.ones(4))

    def test_async_save(self, tmp_path):
        from repro.checkpoint.checkpointer import Checkpointer

        ck = Checkpointer(tmp_path, keep=3)
        state = {"x": jnp.full((1000,), 3.0)}
        ck.save_async(11, state)
        ck.wait()
        restored = ck.restore(state, step=11)
        np.testing.assert_array_equal(np.asarray(restored["x"]), np.full(1000, 3.0))


class TestDataPipeline:
    def test_deterministic_restart(self):
        from repro.configs.base import ShapeConfig, get_smoke_config
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = get_smoke_config("llama3.2-1b")
        shape = ShapeConfig("t", 32, 4, "train")
        a = SyntheticLM(cfg, shape, DataConfig(seed=3))
        b = SyntheticLM(cfg, shape, DataConfig(seed=3))
        ba, bb = a.batch(17), b.batch(17)
        np.testing.assert_array_equal(np.asarray(ba["tokens"]), np.asarray(bb["tokens"]))

    def test_labels_shifted(self):
        from repro.configs.base import ShapeConfig, get_smoke_config
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = get_smoke_config("llama3.2-1b")
        shape = ShapeConfig("t", 32, 4, "train")
        s = SyntheticLM(cfg, shape, DataConfig(seed=0))
        batch = s.batch(0)
        assert batch["tokens"].shape == (4, 32) and batch["labels"].shape == (4, 32)

    def test_mixture_reweighting_changes_domain_rates(self):
        from repro.configs.base import ShapeConfig, get_smoke_config
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = get_smoke_config("llama3.2-1b")
        shape = ShapeConfig("t", 16, 64, "train")
        s = SyntheticLM(cfg, shape, DataConfig(seed=1, n_domains=4))
        s.set_domain_weights(np.array([1.0, 0.0, 0.0, 0.0]))
        batch = s.batch(0)
        assert np.all(np.asarray(batch["_domains"]) == 0)


class TestBalancer:
    def test_recovers_planted_imbalance(self):
        """CKM-from-sketch finds domain mass; balancer inverts it."""
        from repro.data.clustering import CompressiveBalancer

        key = jax.random.PRNGKey(0)
        cents = jax.random.normal(key, (3, 4)) * 8.0
        # domain mass 0.6 / 0.3 / 0.1
        counts = np.array([1800, 900, 300])
        pts = jnp.concatenate(
            [
                cents[i] + jax.random.normal(jax.random.PRNGKey(i), (int(c), 4))
                for i, c in enumerate(counts)
            ]
        )
        bal = CompressiveBalancer(k=3, dim=4, seed=5)
        for i in range(0, pts.shape[0], 500):
            bal.update(pts[i : i + 500])
        res = bal.cluster()
        alpha = np.sort(np.asarray(res.weights))[::-1]
        np.testing.assert_allclose(alpha, [0.6, 0.3, 0.1], atol=0.08)
        w = bal.balanced_weights(res)
        # heaviest cluster gets the smallest sampling weight
        assert np.argmin(w) == np.argmax(np.asarray(res.weights))


_TRAIN_LOOP = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np, sys
    from repro.configs.base import ShapeConfig, get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.train.train_loop import LoopConfig, run
    from repro.data.pipeline import DataConfig

    ckpt_dir = sys.argv[1]
    steps = int(sys.argv[2])
    cfg = get_smoke_config("llama3.2-1b")
    shape = ShapeConfig("t", 32, 4, "train")
    mesh = make_local_mesh()
    loop = LoopConfig(steps=steps, ckpt_dir=ckpt_dir, ckpt_every=3,
                      monitor_k=2, log_every=2, dtype=jnp.float32)
    out = run(cfg, shape, mesh, loop, DataConfig(seed=0))
    print("FINAL", out["history"][-1]["step"], out["history"][-1]["loss"])
    cents = np.asarray(out["monitor_result"].centroids)
    assert np.all(np.isfinite(cents))
    """
)


@pytest.mark.slow
class TestTrainLoopFaultTolerance:
    def test_checkpoint_restart_matches_uninterrupted(self, tmp_path):
        """Train 6 steps straight vs 3 + restart + 3: identical final loss."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        env.pop("XLA_FLAGS", None)

        def run_loop(d, steps):
            out = subprocess.run(
                [sys.executable, "-c", _TRAIN_LOOP, str(d), str(steps)],
                env=env, capture_output=True, text=True, timeout=600,
            )
            assert out.returncode == 0, out.stderr[-3000:]
            final = [l for l in out.stdout.splitlines() if l.startswith("FINAL")][-1]
            return float(final.split()[2])

        straight = run_loop(tmp_path / "a", 6)
        run_loop(tmp_path / "b", 3)  # writes ckpt at step 3
        resumed = run_loop(tmp_path / "b", 6)  # resumes from step 3
        np.testing.assert_allclose(resumed, straight, rtol=1e-4)


class TestGradCompression:
    def test_compressed_allreduce_with_error_feedback(self):
        prog = textwrap.dedent(
            """
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.optim.grad_compression import (
                compress_allreduce_tree, init_error_state)
            from repro.utils.compat import shard_map

            mesh = jax.make_mesh((2, 2), ("pod", "data"))
            n = 4096
            key = jax.random.PRNGKey(0)
            g_pods = jax.random.normal(key, (2, n))  # one grad per pod
            exact = jnp.sum(g_pods, axis=0)

            def body(g, e):
                return compress_allreduce_tree({"g": g[0]}, {"g": e}, "pod")

            fn = shard_map(body, mesh=mesh,
                               in_specs=(P("pod"), P("pod")),
                               out_specs=({"g": P()}, {"g": P("pod")}),
                               axis_names={"pod"}, check_vma=True)

            err = jnp.zeros((2, n))
            # accumulated compressed sums over repeated steps track the exact
            # sum thanks to error feedback.
            acc_c = jnp.zeros(n); acc_e = jnp.zeros(n)
            for _ in range(20):
                out, err_d = fn(g_pods, err)
                err = err_d["g"]
                acc_c = acc_c + out["g"]
                acc_e = acc_e + exact
            rel = float(jnp.linalg.norm(acc_c - acc_e) / jnp.linalg.norm(acc_e))
            assert rel < 0.01, rel
            # single-shot quantisation error is bounded by the int16 grid
            one, _ = fn(g_pods, jnp.zeros((2, n)))
            amax = float(jnp.max(jnp.abs(g_pods)))
            assert float(jnp.max(jnp.abs(one["g"] - exact))) <= 2 * amax / 8192 + 1e-6
            print("OK")
            """
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-c", prog], env=env, capture_output=True, text=True,
            timeout=300,
        )
        assert out.returncode == 0, out.stderr[-3000:]
        assert "OK" in out.stdout
