"""Reduction topologies: schedule invariance, stragglers, in-mesh parity.

The load-bearing property (ISSUE 4 acceptance): for ANY registered merge
schedule and ANY straggler arrival order, the reduced monoid state is
- **bitwise equal** on the int32 quantized path (integer addition is exactly
  associative and commutative), and
- equal to 1e-6 on the float path (schedules only re-associate sums).

Device-level, the sharded backend's collective merge must produce the same
sketch for every ``reduce_topology`` — checked in a subprocess with 8 forced
host devices, bitwise on the quantized path.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st

from repro.core import engine as eng_mod
from repro.core import frequencies as fq
from repro.core import quantize as qz
from repro.core import topology as topo
from repro.data import pipeline as pipe
from repro.launch.specs import SketchJobSpec

TOPOLOGY_NAMES = ("allreduce", "tree", "ring")


def _partials(seed, n_parts, quantized, npts=600, n=4, m=32):
    key = jax.random.PRNGKey(seed)
    kx, kw, kd = jax.random.split(key, 3)
    x = jax.random.normal(kx, (npts, n)) * 2.0
    w = fq.draw_frequencies(kw, m, n, 1.0)
    q = qz.make_quantizer(kd, m, "1bit") if quantized else None
    e = eng_mod.SketchEngine(w, "xla", chunk=128, quantizer=q)
    size = max(1, npts // n_parts)
    return e, [e.update(e.init_state(), b) for b in pipe.chunked(x, size)]


class TestRegistry:
    def test_names(self):
        assert set(topo.available_topologies()) >= set(TOPOLOGY_NAMES)
        with pytest.raises(ValueError):
            topo.get_topology("hypercube9000")
        with pytest.raises(ValueError):
            eng_mod.SketchEngine(
                jnp.ones((2, 4)), "xla", reduce_topology="hypercube9000"
            )

    def test_register_rejects_collisions(self):
        with pytest.raises(ValueError):
            topo.register_topology(topo.get_topology("tree"))

    def test_plans_cover_every_state_once(self):
        """Every schedule merges each non-root slot exactly once as a source."""
        for name in TOPOLOGY_NAMES:
            for n in (1, 2, 3, 5, 8, 13):
                plan = topo.merge_schedule(n, name)
                srcs = [s for rnd in plan for _, s in rnd]
                root = topo.get_topology(name).root(n)
                assert sorted(srcs + [root]) == list(range(n)), (name, n)

    def test_wire_cost_model(self):
        # log2(8)=3 hops tree; 7 hops ring; psum ring RS+AG moves the least.
        s = 1024
        costs = {t: topo.wire_cost_model(s, 8, t) for t in TOPOLOGY_NAMES}
        assert costs["tree"]["hops"] == 3
        assert costs["ring"]["hops"] == 7
        assert (
            costs["allreduce"]["bytes_per_device"]
            < costs["tree"]["bytes_per_device"]
            < costs["ring"]["bytes_per_device"]
        )
        assert topo.wire_cost_model(s, 1, "ring")["bytes_per_device"] == 0


class TestScheduleInvariance:
    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n_parts=st.integers(1, 9),
        order_seed=st.integers(0, 2**31 - 1),
    )
    def test_quantized_bitwise_any_schedule_any_order(
        self, seed, n_parts, order_seed
    ):
        """Acceptance: any topology x any straggler order -> bitwise-equal
        int32 state on the quantized path."""
        e, parts = _partials(seed, n_parts, quantized=True)
        ref = None
        rng = np.random.default_rng(order_seed)
        for name in TOPOLOGY_NAMES:
            order = list(rng.permutation(len(parts)))
            s = topo.reduce_states(e.merge, parts, name, order=order)
            if ref is None:
                ref = s
                continue
            assert bool(jnp.array_equal(ref.qcos_acc, s.qcos_acc)), name
            assert bool(jnp.array_equal(ref.qsin_acc, s.qsin_acc)), name
            assert bool(jnp.array_equal(ref.lower, s.lower)), name
            assert bool(jnp.array_equal(ref.upper, s.upper)), name
            np.testing.assert_allclose(
                float(ref.weight_sum), float(s.weight_sum)
            )

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_parts=st.integers(1, 9))
    def test_float_schedules_agree_to_1e6(self, seed, n_parts):
        e, parts = _partials(seed, n_parts, quantized=False)
        finals = [
            e.finalize(topo.reduce_states(e.merge, parts, name))
            for name in TOPOLOGY_NAMES
        ]
        for z, lo, hi in finals[1:]:
            np.testing.assert_allclose(
                np.asarray(z), np.asarray(finals[0][0]), atol=1e-6
            )
            np.testing.assert_allclose(np.asarray(lo), np.asarray(finals[0][1]))
            np.testing.assert_allclose(np.asarray(hi), np.asarray(finals[0][2]))

    def test_straggler_merger_matches_schedules(self):
        """Online arrival-order fold == any scheduled reduction (bitwise)."""
        e, parts = _partials(11, 7, quantized=True)
        ref = topo.reduce_states(e.merge, parts, "tree")
        sm = topo.StragglerMerger(e.merge, e.init_state())
        for i in np.random.default_rng(0).permutation(len(parts)):
            sm.add(parts[i])
        late = sm.result()
        assert sm.arrived == len(parts)
        assert bool(jnp.array_equal(ref.qcos_acc, late.qcos_acc))
        assert bool(jnp.array_equal(ref.qsin_acc, late.qsin_acc))

    def test_reduce_partials_method(self):
        e, parts = _partials(3, 5, quantized=False)
        z_a, *_ = e.finalize(e.reduce_partials(parts))
        z_r, *_ = e.finalize(e.reduce_partials(parts, "ring"))
        np.testing.assert_allclose(np.asarray(z_a), np.asarray(z_r), atol=1e-6)

    def test_bad_order_rejected(self):
        e, parts = _partials(5, 4, quantized=False)
        with pytest.raises(ValueError):
            topo.reduce_states(e.merge, parts, "tree", order=[0, 0, 1, 2])
        with pytest.raises(ValueError):
            topo.reduce_states(e.merge, [], "tree")


class TestShardedTopologies:
    def test_in_mesh_parity_all_topologies(self):
        """Subprocess, 8 host devices: every reduce_topology matches the
        reference sketch (float, 1e-4) and is bitwise-identical across
        topologies on the quantized path — the collective IS the monoid
        merge under every schedule."""
        import os
        import subprocess
        import sys
        import textwrap

        prog = textwrap.dedent(
            """
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, numpy as np
            import jax.numpy as jnp
            from repro.core import engine as eng_mod
            from repro.core import frequencies as fq
            from repro.core import quantize as qz
            from repro.core import sketch as sk
            from repro.data.pipeline import chunked

            key = jax.random.PRNGKey(0)
            kx, kw, kd = jax.random.split(key, 3)
            x = jax.random.normal(kx, (4096, 6))
            w = fq.draw_frequencies(kw, 48, 6, 1.0)
            z_ref = np.asarray(sk.sketch(x, w))
            mesh = jax.make_mesh((4, 2), ("data", "model"))

            for name in ("allreduce", "tree", "ring"):
                e = eng_mod.SketchEngine(w, "sharded", mesh=mesh, chunk=512,
                                         reduce_topology=name)
                z, lo, hi = e.sketch(x)
                err = float(np.max(np.abs(np.asarray(z) - z_ref)))
                assert err < 1e-4, (name, err)
                np.testing.assert_allclose(np.asarray(lo), np.asarray(x.min(0)),
                                           atol=1e-6)
                np.testing.assert_allclose(np.asarray(hi), np.asarray(x.max(0)),
                                           atol=1e-6)
                # ragged streaming tail through the same topology
                z2, lo2, _ = e.sketch_stream(chunked(x[:4003], 1000))
                err2 = float(np.max(np.abs(
                    np.asarray(z2) - np.asarray(sk.sketch(x[:4003], w)))))
                assert err2 < 1e-4, (name, "ragged", err2)

            q = qz.make_quantizer(kd, 48, "1bit")
            states = []
            for name in ("allreduce", "tree", "ring"):
                e = eng_mod.SketchEngine(w, "sharded", mesh=mesh, chunk=512,
                                         quantizer=q, reduce_topology=name)
                states.append(e.update(e.init_state(), x))
            for s in states[1:]:
                assert bool(jnp.array_equal(states[0].qcos_acc, s.qcos_acc))
                assert bool(jnp.array_equal(states[0].qsin_acc, s.qsin_acc))
            print("OK")
            """
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-c", prog], env=env, capture_output=True,
            text=True, timeout=300,
        )
        assert out.returncode == 0, out.stderr[-3000:]
        assert "OK" in out.stdout

    def test_tree_requires_power_of_two_axis(self):
        """The butterfly needs 2^k devices; the error must say what to use."""
        import os
        import subprocess
        import sys
        import textwrap

        prog = textwrap.dedent(
            """
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
            import jax
            from repro.core import engine as eng_mod
            from repro.core import frequencies as fq

            w = fq.draw_frequencies(jax.random.PRNGKey(0), 16, 4, 1.0)
            mesh = jax.make_mesh((3, 2), ("data", "model"))
            e = eng_mod.SketchEngine(w, "sharded", mesh=mesh,
                                     reduce_topology="tree")
            try:
                e.sketch(jax.random.normal(jax.random.PRNGKey(1), (96, 4)))
            except ValueError as err:
                assert "power-of-two" in str(err), err
                print("OK")
            """
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-c", prog], env=env, capture_output=True,
            text=True, timeout=300,
        )
        assert out.returncode == 0, out.stderr[-3000:]
        assert "OK" in out.stdout


class TestSketchJobSpec:
    def test_validates_against_registries(self):
        SketchJobSpec(backend="sharded", reduce_topology="ring").validate()
        with pytest.raises(ValueError):
            SketchJobSpec(reduce_topology="star").validate()
        with pytest.raises(ValueError):
            SketchJobSpec(backend="tpu9000").validate()
        with pytest.raises(ValueError):
            SketchJobSpec(ingest="eager").validate()
        with pytest.raises(ValueError):
            SketchJobSpec(ingest_prefetch=0).validate()
        SketchJobSpec(decoder="amp").validate()
        with pytest.raises(KeyError):
            SketchJobSpec(decoder="nope").validate()

    def test_ckm_overrides_round_trip(self):
        import dataclasses

        from repro.core import ckm as ckm_mod

        spec = SketchJobSpec(
            reduce_topology="tree", ingest="async", ingest_prefetch=4,
            sketch_quantization="1bit", decoder="amp",
        )
        cfg = dataclasses.replace(
            ckm_mod.CKMConfig(k=3), **spec.ckm_overrides()
        )
        assert cfg.reduce_topology == "tree"
        assert cfg.ingest == "async" and cfg.ingest_prefetch == 4
        assert cfg.sketch_quantization == "1bit"
        assert cfg.decoder == "amp"
        assert "topology=tree" in spec.describe()
        assert "decoder=amp" in spec.describe()
