"""GPipe pipeline parallelism: schedule correctness vs sequential reference."""

import os
import subprocess
import sys
import textwrap

_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_apply, bubble_fraction

    mesh = jax.make_mesh((4,), ("pipe",))
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    kw, kx = jax.random.split(key)
    ws = jax.random.normal(kw, (n_stages, d, d)) / jnp.sqrt(d)
    x = jax.random.normal(kx, (n_micro, mb, d))

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    out = pipeline_apply(stage_fn, ws, x, mesh, axis="pipe")

    # sequential reference: apply the 4 stages in order to each microbatch
    ref = x
    for s in range(n_stages):
        ref = jax.vmap(lambda h: stage_fn(ws[s], h))(ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    # the compiled schedule must use point-to-point collective-permute
    c = jax.jit(lambda ws, x: pipeline_apply(stage_fn, ws, x, mesh)).lower(ws, x).compile()
    assert "collective-permute" in c.as_text()
    assert abs(bubble_fraction(4, 8) - 3 / 11) < 1e-9
    print("OK")
    """
)


def test_gpipe_schedule_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _PROG], env=env, capture_output=True, text=True,
        timeout=300,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
