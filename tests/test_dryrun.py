"""Integration: one dry-run cell end-to-end (512 fake devices, subprocess)."""

import json
import os
import subprocess
import sys
import textwrap

_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import json
    from repro.launch.dryrun import run_cell

    r = run_cell("llama3.2-1b", "train_4k", multi_pod=False, verbose=False)
    assert r["status"] == "ok", r
    assert r["chips"] == 256
    assert r["dominant"] in ("compute", "memory", "collective")
    # sanity bands: useful compute ratio consistent with full remat, and the
    # three roofline terms all positive.
    assert 0.3 < r["useful_ratio"] < 1.2, r["useful_ratio"]
    assert r["compute_s"] > 0 and r["memory_s"] > 0 and r["collective_s"] > 0
    # memory proof: argument+temp fit in a v5e's 16 GB with headroom factor 2
    mem = r["memory_analysis"]
    assert (mem["argument_size"] + mem["temp_size"]) < 2 * 16 * 2**30, mem
    # serve cell too (sequence-sharded cache)
    r2 = run_cell("llama3.2-1b", "decode_32k", multi_pod=False, verbose=False)
    assert r2["status"] == "ok"
    print(json.dumps({"ok": True}))
    """
)


def test_dryrun_cell_end_to_end():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _PROG], env=env, capture_output=True, text=True,
        timeout=580,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert '"ok": true' in out.stdout
