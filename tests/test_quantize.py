"""Quantized sketch states (QCKM): monoid laws, parity, end-to-end decode.

The quantized state transform (core/quantize.py + core/engine.py) must keep
the engine's monoid contract *exactly* — integer accumulators make identity,
associativity, commutativity, and split invariance bitwise-testable, no
float tolerance.  Dequantization accuracy is statistical: the 1-bit sketch
matches the float sketch within the dither-noise bound (odd-harmonic leakage
+ O(1/sqrt(N)) code noise; measured rel-l2 ~0.15 on the paper's blobs at
m=200, N=8000), and CLOMPR absorbs that distortion — end-to-end SSE within
10% of the float path is the PR's acceptance criterion, asserted here.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import ckm as ckm_mod
from repro.core import engine as eng_mod
from repro.core import frequencies as fq
from repro.core import quantize as qz
from repro.core import sketch as sk
from repro.data import pipeline as pipe


def _data(seed, npts=400, n=4, m=24):
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (npts, n)) * 2.0
    w = fq.draw_frequencies(kw, m, n, 1.0)
    return x, w


def _quantizer(seed, m, spec="1bit"):
    return qz.make_quantizer(jax.random.PRNGKey(1000 + seed), m, spec)


def _int_state_equal(a, b):
    return bool(
        jnp.all(a.qcos_acc == b.qcos_acc) and jnp.all(a.qsin_acc == b.qsin_acc)
    )


class TestParseAndWire:
    def test_parse_bits(self):
        assert qz.parse_bits("none") is None
        assert qz.parse_bits("1bit") == 1
        assert qz.parse_bits("4bit") == 4
        assert qz.parse_bits("16bit") == 16
        for bad in ("2", "0bit", "17bit", "float32", "1 bit no"):
            with pytest.raises(ValueError):
                qz.parse_bits(bad)

    def test_wire_bytes_shrink_with_bits(self):
        float_bytes = qz.state_wire_bytes(1000, 8000, None)
        onebit = qz.state_wire_bytes(1000, 8000, 1)
        eightbit = qz.state_wire_bytes(1000, 8000, 8)
        # 8000 signs fit in int16: 2x smaller than the f32 state; 8-bit code
        # sums over 8000 points genuinely need int32 — same width as float
        # (the model is honest: the win depends on count and depth).
        assert onebit == float_bytes // 2
        assert onebit < eightbit == float_bytes
        # Tiny partials (one batch of 100 points) fit int8: 4x smaller; huge
        # counts fall back to 8-byte lanes instead of crashing.
        assert qz.state_wire_bytes(1000, 100, 1) == float_bytes // 4
        assert qz.state_wire_bytes(1000, 2**40, 16) == float_bytes * 2

    def test_accumulator_capacity_guard(self):
        x, w = _data(9, npts=32)
        e = eng_mod.SketchEngine(w, "xla", quantizer=_quantizer(9, 24, "16bit"))
        s = e.update(e.init_state(), x)
        e.finalize(s)  # under capacity: fine
        over = s._replace(count=jnp.asarray(1e9, jnp.float32))
        with pytest.raises(ValueError, match="overflow"):
            e.finalize(over)
        assert qz.accumulator_capacity(1) == 2**31 - 1

    def test_dither_shape_checked(self):
        _, w = _data(0)
        bad = qz.SketchQuantizer(1, jnp.zeros((7,), jnp.float32))
        with pytest.raises(ValueError):
            eng_mod.SketchEngine(w, "xla", quantizer=bad)


class TestQuantizedMonoidLaws:
    """The laws hold *bitwise* — integer sums have no rounding."""

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        cut_a=st.integers(1, 197),
        cut_b=st.integers(199, 398),
        spec=st.sampled_from(["1bit", "4bit"]),
    )
    def test_merge_associative_and_commutative(self, seed, cut_a, cut_b, spec):
        x, w = _data(seed)
        e = eng_mod.SketchEngine(w, "xla", chunk=64, quantizer=_quantizer(seed, 24, spec))
        parts = [x[:cut_a], x[cut_a:cut_b], x[cut_b:]]
        a, b, c = (e.update(e.init_state(), p) for p in parts)
        left = e.merge(e.merge(a, b), c)
        right = e.merge(a, e.merge(b, c))
        assert _int_state_equal(left, right)
        assert _int_state_equal(e.merge(a, b), e.merge(b, a))
        np.testing.assert_allclose(np.asarray(left.lower), np.asarray(right.lower))
        np.testing.assert_allclose(np.asarray(left.upper), np.asarray(right.upper))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_chunks=st.integers(1, 9))
    def test_split_invariance_is_exact(self, seed, n_chunks):
        """Codes are deterministic per point, so ANY batching of the same
        points yields the bitwise-identical integer state."""
        x, w = _data(seed)
        e = eng_mod.SketchEngine(w, "xla", chunk=128, quantizer=_quantizer(seed, 24))
        one_shot = e.update(e.init_state(), x)
        state = e.init_state()
        for batch in pipe.chunked(x, max(1, x.shape[0] // n_chunks)):
            state = e.update(state, batch)
        assert _int_state_equal(one_shot, state)
        assert float(one_shot.count) == float(state.count) == x.shape[0]

    def test_identity_element(self):
        x, w = _data(3)
        e = eng_mod.SketchEngine(w, "xla", quantizer=_quantizer(3, 24))
        s = e.update(e.init_state(), x)
        for combined in (e.merge(s, e.init_state()), e.merge(e.init_state(), s)):
            assert _int_state_equal(combined, s)
            for za, zb in zip(e.finalize(combined), e.finalize(s)):
                np.testing.assert_allclose(np.asarray(za), np.asarray(zb))

    def test_state_is_integer_and_weights_rejected(self):
        x, w = _data(5)
        e = eng_mod.SketchEngine(w, "xla", quantizer=_quantizer(5, 24))
        s = e.update(e.init_state(), x)
        assert s.qcos_acc.dtype == jnp.int32 and s.qsin_acc.dtype == jnp.int32
        # 1-bit codes: each accumulator entry is bounded by the point count.
        assert int(jnp.max(jnp.abs(s.qcos_acc))) <= x.shape[0]
        with pytest.raises(ValueError):
            e.update(e.init_state(), x, jnp.ones((x.shape[0],)))


class TestDequantization:
    def test_1bit_matches_float_within_dither_noise_bound(self, gaussian_blobs):
        """(pi/4) E[sign] correction on the paper's blobs: rel-l2 within the
        odd-harmonic + code-noise bound (~0.15 measured; 0.25 asserted)."""
        x, _, _ = gaussian_blobs
        k_sig, k_w = jax.random.split(jax.random.PRNGKey(1))
        sigma2 = fq.estimate_sigma2(k_sig, x[:2048])
        w = fq.draw_frequencies(k_w, 200, x.shape[1], sigma2)
        z_ref = np.asarray(sk.sketch(x, w))
        e = eng_mod.SketchEngine(w, "xla", quantizer=_quantizer(0, 200))
        z, lo, hi = e.sketch(x)
        rel = np.linalg.norm(np.asarray(z) - z_ref) / np.linalg.norm(z_ref)
        assert rel < 0.25, rel
        np.testing.assert_allclose(np.asarray(lo), np.asarray(x.min(0)), atol=1e-6)
        np.testing.assert_allclose(np.asarray(hi), np.asarray(x.max(0)), atol=1e-6)

    def test_bbit_error_shrinks_with_depth(self, gaussian_blobs):
        """b-bit rounding error ~ 1/S: 8-bit is near-float, 4bit in between."""
        x, _, _ = gaussian_blobs
        k_sig, k_w = jax.random.split(jax.random.PRNGKey(2))
        sigma2 = fq.estimate_sigma2(k_sig, x[:2048])
        w = fq.draw_frequencies(k_w, 200, x.shape[1], sigma2)
        z_ref = np.asarray(sk.sketch(x, w))
        errs = {}
        for spec in ("4bit", "8bit"):
            e = eng_mod.SketchEngine(w, "xla", quantizer=_quantizer(0, 200, spec))
            z, _, _ = e.sketch(x)
            errs[spec] = float(np.max(np.abs(np.asarray(z) - z_ref)))
        assert errs["8bit"] < 2e-3, errs
        assert errs["4bit"] < 2e-2, errs
        assert errs["8bit"] < errs["4bit"]


class TestQuantizedBackendParity:
    def test_pallas_matches_xla_bitwise(self):
        """Fused int32 kernel == XLA scan, exact — ragged N, unaligned m."""
        x, w = _data(0, npts=777, n=6, m=100)
        for spec in ("1bit", "6bit"):
            q = _quantizer(0, 100, spec)
            e_x = eng_mod.SketchEngine(w, "xla", quantizer=q)
            e_p = eng_mod.SketchEngine(
                w, "pallas", block_n=256, block_m=128, quantizer=q
            )
            s_x = e_x.update(e_x.init_state(), x)
            s_p = e_p.update(e_p.init_state(), x)
            assert _int_state_equal(s_x, s_p), spec
            for za, zb in zip(e_x.finalize(s_x), e_p.finalize(s_p)):
                np.testing.assert_allclose(np.asarray(za), np.asarray(zb))

    def test_sharded_psums_integer_accumulators(self):
        """Acceptance: the sharded backend merges int accumulators (psum over
        the mesh) bitwise-equal to the xla path, ragged streams included."""
        import os
        import subprocess
        import sys
        import textwrap

        prog = textwrap.dedent(
            """
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, numpy as np, jax.numpy as jnp
            from repro.core import engine as eng_mod
            from repro.core import frequencies as fq
            from repro.core import quantize as qz
            from repro.data.pipeline import chunked

            key = jax.random.PRNGKey(0)
            kx, kw, kd = jax.random.split(key, 3)
            x = jax.random.normal(kx, (4096, 6))
            w = fq.draw_frequencies(kw, 48, 6, 1.0)
            q = qz.SketchQuantizer(1, qz.draw_dither(kd, 48))
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            e_x = eng_mod.SketchEngine(w, "xla", chunk=512, quantizer=q)
            e_s = eng_mod.SketchEngine(w, "sharded", mesh=mesh, chunk=512,
                                       quantizer=q)
            s_x = e_x.update(e_x.init_state(), x)
            s_s = e_s.update(e_s.init_state(), x)
            assert s_s.qcos_acc.dtype == jnp.int32
            assert bool(jnp.all(s_x.qcos_acc == s_s.qcos_acc))
            assert bool(jnp.all(s_x.qsin_acc == s_s.qsin_acc))
            assert float(s_s.count) == 4096.0
            # Ragged stream: zero-valid padding must not move the int sums.
            z_s, lo, hi = e_s.sketch_stream(chunked(x[:4003], 1000))
            z_x, lo_x, hi_x = e_x.sketch_stream(chunked(x[:4003], 1000))
            np.testing.assert_allclose(np.asarray(z_s), np.asarray(z_x),
                                       atol=1e-6)
            np.testing.assert_allclose(np.asarray(lo), np.asarray(lo_x))
            np.testing.assert_allclose(np.asarray(hi), np.asarray(hi_x))
            print("OK")
            """
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-c", prog], env=env, capture_output=True,
            text=True, timeout=300,
        )
        assert out.returncode == 0, out.stderr[-3000:]
        assert "OK" in out.stdout


@pytest.mark.slow
class TestQuantizedCKM:
    def test_fit_streaming_1bit_recovers_blobs(self, gaussian_blobs):
        """Acceptance: one-pass 1-bit quantized fit localises every true mean
        (Hungarian-matched error < 1.0), like the float streaming fit."""
        x, _, means = gaussian_blobs
        cfg = ckm_mod.CKMConfig(k=5, sketch_quantization="1bit")
        res = ckm_mod.fit_streaming(
            jax.random.PRNGKey(0), pipe.chunked(x, 1000), cfg
        )
        assert res.sketch.shape == (2 * cfg.sketch_size(x.shape[1]),)
        d = np.linalg.norm(
            np.asarray(means)[:, None] - np.asarray(res.centroids)[None], axis=-1
        ).copy()
        errs = []
        for _ in range(means.shape[0]):
            i, j = np.unravel_index(np.argmin(d), d.shape)
            errs.append(d[i, j])
            d[i, :] = np.inf
            d[:, j] = np.inf
        assert np.all(np.array(errs) < 1.0), errs

    def test_1bit_sse_within_10pct_of_float(self, gaussian_blobs):
        """Acceptance: quantized-vs-float centroid SSE within 10% relative."""
        x, _, _ = gaussian_blobs
        key = jax.random.PRNGKey(0)
        sse = {}
        for quant in ("none", "1bit"):
            cfg = ckm_mod.CKMConfig(k=5, sketch_quantization=quant)
            res = ckm_mod.fit(key, x, cfg)
            sse[quant] = float(ckm_mod.sse(x, res.centroids))
        assert sse["1bit"] <= 1.10 * sse["none"], sse

    def test_1bit_fit_on_pallas_backend(self, gaussian_blobs):
        """Acceptance: sketch_quantization='1bit' end-to-end on the pallas
        backend (fused int32 encoder; sharded is covered bitwise above)."""
        x, _, means = gaussian_blobs
        cfg = ckm_mod.CKMConfig(
            k=5, sketch_quantization="1bit", sketch_backend="pallas"
        )
        res = ckm_mod.fit(jax.random.PRNGKey(0), x, cfg)
        d = np.linalg.norm(
            np.asarray(means)[:, None] - np.asarray(res.centroids)[None], axis=-1
        )
        assert float(np.max(np.min(d, axis=1))) < 1.0
