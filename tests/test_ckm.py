"""Behaviour tests for the CKM decoder + Lloyd baseline (paper §3.2, §4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ckm as ckm_mod
from repro.core import lloyd as lloyd_mod
from repro.core import nnls as nnls_mod
from repro.data import synthetic


def _match_errors(truth, cents):
    d = np.linalg.norm(np.asarray(truth)[:, None] - np.asarray(cents)[None], axis=-1)
    errs = []
    d = d.copy()
    for _ in range(truth.shape[0]):
        i, j = np.unravel_index(np.argmin(d), d.shape)
        errs.append(d[i, j])
        d[i, :] = np.inf
        d[:, j] = np.inf
    return np.array(errs)


@pytest.mark.slow
class TestCKMRecovery:
    def test_recovers_separated_clusters(self, gaussian_blobs):
        """On well-separated blobs CKM must localise every true mean."""
        x, labels, means = gaussian_blobs
        cfg = ckm_mod.CKMConfig(k=5)
        res = ckm_mod.fit(jax.random.PRNGKey(0), x, cfg)
        errs = _match_errors(means, res.centroids)
        assert np.all(errs < 1.0), errs  # within a cluster std of each mean

    def test_weights_are_probabilities(self, gaussian_blobs):
        x, _, _ = gaussian_blobs
        res = ckm_mod.fit(jax.random.PRNGKey(1), x, ckm_mod.CKMConfig(k=5))
        w = np.asarray(res.weights)
        assert np.all(w >= 0) and abs(w.sum() - 1.0) < 1e-5

    def test_sse_close_to_lloyd(self, gaussian_blobs):
        """Paper's headline: CKM SSE comparable to Lloyd-Max (rel < 1.5)."""
        x, _, _ = gaussian_blobs
        res = ckm_mod.fit(jax.random.PRNGKey(2), x, ckm_mod.CKMConfig(k=5))
        km = lloyd_mod.kmeans(
            jax.random.PRNGKey(3), x, lloyd_mod.LloydConfig(k=5, replicates=3)
        )
        rel = float(ckm_mod.sse(x, res.centroids)) / float(km.sse)
        assert rel < 1.5, rel

    def test_replicates_select_lower_cost(self, gaussian_blobs):
        x, _, _ = gaussian_blobs
        r1 = ckm_mod.fit(jax.random.PRNGKey(4), x, ckm_mod.CKMConfig(k=5))
        r3 = ckm_mod.fit(
            jax.random.PRNGKey(4), x, ckm_mod.CKMConfig(k=5, replicates=3)
        )
        assert float(r3.cost) <= float(r1.cost) + 1e-6

    def test_init_strategies_run(self, gaussian_blobs):
        """range / sample / kpp all produce valid centroids (paper §4.2)."""
        x, _, means = gaussian_blobs
        for init in ("range", "sample", "kpp"):
            cfg = ckm_mod.CKMConfig(k=5, init=init, atom_steps=100, joint_steps=80)
            res = ckm_mod.fit(jax.random.PRNGKey(5), x, cfg)
            assert res.centroids.shape == (5, 4)
            assert np.all(np.isfinite(np.asarray(res.centroids)))

    def test_centroids_respect_bounds(self, gaussian_blobs):
        """Box constraint l <= c <= u (paper's 'additional constraints')."""
        x, _, _ = gaussian_blobs
        res = ckm_mod.fit(jax.random.PRNGKey(6), x, ckm_mod.CKMConfig(k=5))
        lo, hi = res.bounds
        c = res.centroids
        assert bool(jnp.all(c >= lo - 1e-5)) and bool(jnp.all(c <= hi + 1e-5))

    def test_decode_from_sketch_only(self, gaussian_blobs):
        """Compressive contract: decoding uses only (z, W, l, u) — no data."""
        x, _, means = gaussian_blobs
        cfg = ckm_mod.CKMConfig(k=5)
        z, w, _, (lo, hi) = ckm_mod.compute_sketch(jax.random.PRNGKey(7), x, cfg)
        cents, alphas, cost = ckm_mod.decode_sketch(
            jax.random.PRNGKey(8), z, w, lo, hi, cfg
        )
        errs = _match_errors(means, cents)
        assert np.all(errs < 1.2), errs


@pytest.mark.slow
class TestLloyd:
    def test_recovers_separated_clusters(self, gaussian_blobs):
        x, _, means = gaussian_blobs
        res = lloyd_mod.kmeans(
            jax.random.PRNGKey(0), x, lloyd_mod.LloydConfig(k=5, replicates=3, init="kpp")
        )
        errs = _match_errors(means, res.centroids)
        assert np.all(errs < 0.5), errs

    def test_sse_decreases_with_replicates(self, gaussian_blobs):
        x, _, _ = gaussian_blobs
        r1 = lloyd_mod.kmeans(jax.random.PRNGKey(1), x, lloyd_mod.LloydConfig(k=5))
        r5 = lloyd_mod.kmeans(
            jax.random.PRNGKey(1), x, lloyd_mod.LloydConfig(k=5, replicates=5)
        )
        assert float(r5.sse) <= float(r1.sse) * (1.0 + 1e-5)

    def test_kpp_beats_range_on_average(self, gaussian_blobs):
        """k-means++ should not be worse than range init (paper Fig. 1)."""
        x, _, _ = gaussian_blobs
        sses = {}
        for init in ("range", "kpp"):
            vals = [
                float(
                    lloyd_mod.lloyd(
                        jax.random.PRNGKey(s), x, lloyd_mod.LloydConfig(k=5, init=init)
                    ).sse
                )
                for s in range(5)
            ]
            sses[init] = np.mean(vals)
        assert sses["kpp"] <= sses["range"] * 1.05


class TestNNLS:
    def test_matches_scipy(self):
        from scipy.optimize import nnls as scipy_nnls

        rng = np.random.default_rng(0)
        a = rng.normal(size=(40, 8)).astype(np.float32)
        beta_true = np.abs(rng.normal(size=8)).astype(np.float32)
        beta_true[2] = 0.0
        z = a @ beta_true
        mask = jnp.ones((8,), bool)
        beta = nnls_mod.nnls(jnp.asarray(a), jnp.asarray(z), mask, iters=500)
        ref, _ = scipy_nnls(a, z)
        np.testing.assert_allclose(np.asarray(beta), ref, atol=2e-3)

    def test_mask_pins_columns(self):
        rng = np.random.default_rng(1)
        a = rng.normal(size=(20, 6)).astype(np.float32)
        z = rng.normal(size=20).astype(np.float32)
        mask = jnp.asarray([True, False, True, True, False, True])
        beta = nnls_mod.nnls(jnp.asarray(a), jnp.asarray(z), mask)
        assert float(beta[1]) == 0.0 and float(beta[4]) == 0.0

    def test_nonnegative(self):
        rng = np.random.default_rng(2)
        a = rng.normal(size=(30, 5)).astype(np.float32)
        z = rng.normal(size=30).astype(np.float32)
        beta = nnls_mod.nnls(jnp.asarray(a), jnp.asarray(z), jnp.ones((5,), bool))
        assert np.all(np.asarray(beta) >= 0)
