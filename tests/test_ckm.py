"""Behaviour tests for the CKM decoder + Lloyd baseline (paper §3.2, §4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ckm as ckm_mod
from repro.core import lloyd as lloyd_mod
from repro.core import nnls as nnls_mod
from repro.data import synthetic


def _match_errors(truth, cents):
    d = np.linalg.norm(np.asarray(truth)[:, None] - np.asarray(cents)[None], axis=-1)
    errs = []
    d = d.copy()
    for _ in range(truth.shape[0]):
        i, j = np.unravel_index(np.argmin(d), d.shape)
        errs.append(d[i, j])
        d[i, :] = np.inf
        d[:, j] = np.inf
    return np.array(errs)


@pytest.mark.slow
class TestCKMRecovery:
    def test_recovers_separated_clusters(self, gaussian_blobs):
        """On well-separated blobs CKM must localise every true mean."""
        x, labels, means = gaussian_blobs
        cfg = ckm_mod.CKMConfig(k=5)
        res = ckm_mod.fit(jax.random.PRNGKey(0), x, cfg)
        errs = _match_errors(means, res.centroids)
        assert np.all(errs < 1.0), errs  # within a cluster std of each mean

    def test_weights_are_probabilities(self, gaussian_blobs):
        x, _, _ = gaussian_blobs
        res = ckm_mod.fit(jax.random.PRNGKey(1), x, ckm_mod.CKMConfig(k=5))
        w = np.asarray(res.weights)
        assert np.all(w >= 0) and abs(w.sum() - 1.0) < 1e-5

    def test_sse_close_to_lloyd(self, gaussian_blobs):
        """Paper's headline: CKM SSE comparable to Lloyd-Max (rel < 1.5).

        Best-of-3 on both sides: single-replicate CKM is at the mercy of the
        frequency draw (~1-in-7 seeds miss a cluster), and the paper's own
        protocol is best-of-replicates — mirror the Lloyd baseline below."""
        x, _, _ = gaussian_blobs
        res = ckm_mod.fit(
            jax.random.PRNGKey(2), x, ckm_mod.CKMConfig(k=5, replicates=3)
        )
        km = lloyd_mod.kmeans(
            jax.random.PRNGKey(3), x, lloyd_mod.LloydConfig(k=5, replicates=3)
        )
        rel = float(ckm_mod.sse(x, res.centroids)) / float(km.sse)
        assert rel < 1.5, rel

    def test_replicates_select_lower_cost(self, gaussian_blobs):
        x, _, _ = gaussian_blobs
        r1 = ckm_mod.fit(jax.random.PRNGKey(4), x, ckm_mod.CKMConfig(k=5))
        r3 = ckm_mod.fit(
            jax.random.PRNGKey(4), x, ckm_mod.CKMConfig(k=5, replicates=3)
        )
        assert float(r3.cost) <= float(r1.cost) + 1e-6

    def test_init_strategies_run(self, gaussian_blobs):
        """range / sample / kpp all produce valid centroids (paper §4.2)."""
        x, _, means = gaussian_blobs
        for init in ("range", "sample", "kpp"):
            cfg = ckm_mod.CKMConfig(k=5, init=init, atom_steps=100, joint_steps=80)
            res = ckm_mod.fit(jax.random.PRNGKey(5), x, cfg)
            assert res.centroids.shape == (5, 4)
            assert np.all(np.isfinite(np.asarray(res.centroids)))

    def test_centroids_respect_bounds(self, gaussian_blobs):
        """Box constraint l <= c <= u (paper's 'additional constraints')."""
        x, _, _ = gaussian_blobs
        res = ckm_mod.fit(jax.random.PRNGKey(6), x, ckm_mod.CKMConfig(k=5))
        lo, hi = res.bounds
        c = res.centroids
        assert bool(jnp.all(c >= lo - 1e-5)) and bool(jnp.all(c <= hi + 1e-5))

    def test_decode_from_sketch_only(self, gaussian_blobs):
        """Compressive contract: decoding uses only (z, W, l, u) — no data."""
        x, _, means = gaussian_blobs
        cfg = ckm_mod.CKMConfig(k=5)
        z, w, _, (lo, hi) = ckm_mod.compute_sketch(jax.random.PRNGKey(7), x, cfg)
        cents, alphas, cost = ckm_mod.decode_sketch(
            jax.random.PRNGKey(8), z, w, lo, hi, cfg
        )
        errs = _match_errors(means, cents)
        assert np.all(errs < 1.2), errs


@pytest.mark.slow
class TestLloyd:
    def test_recovers_separated_clusters(self, gaussian_blobs):
        x, _, means = gaussian_blobs
        res = lloyd_mod.kmeans(
            jax.random.PRNGKey(0), x, lloyd_mod.LloydConfig(k=5, replicates=3, init="kpp")
        )
        errs = _match_errors(means, res.centroids)
        assert np.all(errs < 0.5), errs

    def test_sse_decreases_with_replicates(self, gaussian_blobs):
        x, _, _ = gaussian_blobs
        r1 = lloyd_mod.kmeans(jax.random.PRNGKey(1), x, lloyd_mod.LloydConfig(k=5))
        r5 = lloyd_mod.kmeans(
            jax.random.PRNGKey(1), x, lloyd_mod.LloydConfig(k=5, replicates=5)
        )
        assert float(r5.sse) <= float(r1.sse) * (1.0 + 1e-5)

    def test_kpp_beats_range_on_average(self, gaussian_blobs):
        """k-means++ should not be worse than range init (paper Fig. 1)."""
        x, _, _ = gaussian_blobs
        sses = {}
        for init in ("range", "kpp"):
            vals = [
                float(
                    lloyd_mod.lloyd(
                        jax.random.PRNGKey(s), x, lloyd_mod.LloydConfig(k=5, init=init)
                    ).sse
                )
                for s in range(5)
            ]
            sses[init] = np.mean(vals)
        assert sses["kpp"] <= sses["range"] * 1.05


class TestNNLS:
    def test_matches_scipy(self):
        from scipy.optimize import nnls as scipy_nnls

        rng = np.random.default_rng(0)
        a = rng.normal(size=(40, 8)).astype(np.float32)
        beta_true = np.abs(rng.normal(size=8)).astype(np.float32)
        beta_true[2] = 0.0
        z = a @ beta_true
        mask = jnp.ones((8,), bool)
        beta = nnls_mod.nnls(jnp.asarray(a), jnp.asarray(z), mask, iters=500)
        ref, _ = scipy_nnls(a, z)
        np.testing.assert_allclose(np.asarray(beta), ref, atol=2e-3)

    def test_mask_pins_columns(self):
        rng = np.random.default_rng(1)
        a = rng.normal(size=(20, 6)).astype(np.float32)
        z = rng.normal(size=20).astype(np.float32)
        mask = jnp.asarray([True, False, True, True, False, True])
        beta = nnls_mod.nnls(jnp.asarray(a), jnp.asarray(z), mask)
        assert float(beta[1]) == 0.0 and float(beta[4]) == 0.0

    def test_nonnegative(self):
        rng = np.random.default_rng(2)
        a = rng.normal(size=(30, 5)).astype(np.float32)
        z = rng.normal(size=30).astype(np.float32)
        beta = nnls_mod.nnls(jnp.asarray(a), jnp.asarray(z), jnp.ones((5,), bool))
        assert np.all(np.asarray(beta) >= 0)

    def test_empty_support_returns_zero(self):
        """Regression (PR 6): with every column masked the gram matrix is 0,
        the power-iteration Rayleigh quotient hits its floor, and the old
        1/(2*1e-12) step produced inf/NaN iterates.  The answer is beta = 0."""
        rng = np.random.default_rng(3)
        a = rng.normal(size=(20, 6)).astype(np.float32)
        z = rng.normal(size=20).astype(np.float32)
        beta = nnls_mod.nnls(
            jnp.asarray(a), jnp.asarray(z), jnp.zeros((6,), bool)
        )
        np.testing.assert_array_equal(np.asarray(beta), np.zeros(6, np.float32))

    def test_nan_padding_in_masked_columns_is_ignored(self):
        """Regression (PR 6): decoders keep padded supports — masked columns
        can hold NaN/inf.  The old `a * mask` produced 0 * NaN = NaN grams;
        the select-based masking must give the same answer as clean padding."""
        rng = np.random.default_rng(4)
        a = rng.normal(size=(20, 6)).astype(np.float32)
        z = (a[:, [0, 2, 3, 5]] @ np.abs(rng.normal(size=4))).astype(np.float32)
        mask = jnp.asarray([True, False, True, True, False, True])
        a_nan = a.copy()
        a_nan[:, 1] = np.nan
        a_nan[:, 4] = np.inf
        beta_clean = nnls_mod.nnls(jnp.asarray(a), jnp.asarray(z), mask)
        beta_nan = nnls_mod.nnls(jnp.asarray(a_nan), jnp.asarray(z), mask)
        assert np.all(np.isfinite(np.asarray(beta_nan)))
        np.testing.assert_allclose(
            np.asarray(beta_nan), np.asarray(beta_clean), atol=1e-6
        )


class TestPRNGStreams:
    def test_streams_pairwise_distinct(self):
        """Regression (PR 6): the signature/frequency/dither streams must come
        from one split fan-out — pairwise-distinct keys for any fixed seed.
        (Previously the dither stream was fold_in(key, 0x51) on the *parent*
        key while sig/freq came from split(key) of the same parent, so the
        derivations were not a single coherent fan-out.)"""
        for seed in (0, 1, 42, 2**31 - 1):
            keys = ckm_mod.stream_keys(jax.random.PRNGKey(seed))
            data = [np.asarray(jax.random.key_data(k)) for k in keys]
            assert len(keys) == 3
            for i in range(3):
                for j in range(i + 1, 3):
                    assert not np.array_equal(data[i], data[j]), (seed, i, j)

    def test_quantizer_and_freqs_use_the_fanout(self):
        """make_quantizer's dither key and _draw_freqs' keys are exactly the
        stream_keys fan-out (no ad-hoc fold_in constants left)."""
        key = jax.random.PRNGKey(7)
        k_sig, k_freq, k_dither = ckm_mod.stream_keys(key)
        cfg = ckm_mod.CKMConfig(k=3, m=16, sketch_quantization="1bit")
        q = ckm_mod.make_quantizer(key, cfg, 16)
        expect = jax.random.uniform(
            k_dither, (16,), minval=0.0, maxval=2.0 * np.pi
        )
        np.testing.assert_array_equal(np.asarray(q.dither), np.asarray(expect))
