"""Telemetry + diagnostics suite (``repro.obs``).

Covers the observability PR's acceptance criteria:

- metrics registry / tracer semantics, and the disabled-path no-op contract
  (nothing recorded, results bitwise identical to an untelemetered run);
- instrumentation: engine update/merge/finalize spans + counters, ingest
  overlap accounting, FleetService flush/decode-cache/drift instruments;
- an enabled ``fit_streaming`` run emits update/merge/finalize spans and a
  decoder-convergence series, all parseable back from the JSONL export;
- ``ckm.diagnose`` attributes the three seeded failure modes (m too small,
  sigma mis-scaled, decoder under-iterated) and returns ``ok`` on a
  converged fit;
- the drift gauges distinguish a stationary stream from a mean-shifted one;
- FleetService decode-cache accounting matches a hand-simulated LRU over a
  scripted request sequence, version-bump invalidation included.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.core import ckm as ckm_mod
from repro.core import fleet as fl
from repro.core import freq_ops as fo
from repro.core import ingest as ingest_mod
from repro.core.decoders.clompr import CLOMPRConfig, clompr
from repro.core.decoders.sketch_shift import SketchShiftConfig, sketch_shift
from repro.core.engine import SketchEngine
from repro.obs import metrics as obs_metrics
from repro.obs import runtime as obs_rt
from repro.serve.fleet_service import FleetService
from repro.train.monitor import ActivationMonitor

pytestmark = pytest.mark.obs

FAST = dict(atom_steps=40, joint_steps=30, nnls_iters=40, final_steps=80,
            shift_steps=40, shift_polish_steps=100)


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with telemetry off and empty stores."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


@pytest.fixture(scope="module")
def blobs3():
    """Three well-separated 2-D blobs (N=3000) + a fitted reference config."""
    kc = jax.random.normal(jax.random.PRNGKey(5), (3, 2)) * 6.0
    idx = jax.random.randint(jax.random.PRNGKey(0), (3000,), 0, 3)
    pts = kc[idx] + 0.3 * jax.random.normal(jax.random.PRNGKey(6), (3000, 2))
    return np.asarray(pts)


def _op(m=32, n=3, seed=0):
    return fo.make_operator(
        "dense", jax.random.PRNGKey(seed), m, n, jnp.asarray(1.0)
    )


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_metrics_get_or_create_identity():
    c1 = obs.counter("x.calls", backend="xla")
    c2 = obs.counter("x.calls", backend="xla")
    c3 = obs.counter("x.calls", backend="pallas")
    assert c1 is c2 and c1 is not c3
    c1.inc()
    c1.inc(2.5)
    c3.inc()
    snap = obs.snapshot()
    assert snap["x.calls{backend=xla}"] == 3.5
    assert snap["x.calls{backend=pallas}"] == 1.0


def test_gauge_and_histogram_semantics():
    g = obs.gauge("g")
    g.set(1.0)
    g.set(0.25)
    h = obs.histogram("lat")
    for v in (0.5, 2.0, 0.004):
        h.observe(v)
    snap = obs.snapshot()
    assert snap["g"] == 0.25
    assert snap["lat"]["count"] == 3
    assert snap["lat"]["min"] == 0.004 and snap["lat"]["max"] == 2.0
    assert snap["lat"]["mean"] == pytest.approx((0.5 + 2.0 + 0.004) / 3)


def test_registry_reset_bumps_generation():
    gen0 = obs_metrics.REGISTRY.generation
    obs.counter("a").inc()
    obs_metrics.reset()
    assert obs_metrics.REGISTRY.generation == gen0 + 1
    assert obs.snapshot() == {}


def test_enabled_scope_restores():
    assert not obs_rt.ENABLED
    with obs_rt.enabled_scope():
        assert obs_rt.ENABLED
        with obs_rt.enabled_scope(False):
            assert not obs_rt.ENABLED
        assert obs_rt.ENABLED
    assert not obs_rt.ENABLED


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


def test_span_noop_when_disabled():
    with obs.span("nothing"):
        pass
    obs.series("s", [1.0])
    obs.point("p", 2.0)
    assert obs.TRACER.events == []


def test_span_nesting_depth_and_jsonl(tmp_path):
    obs.enable()
    with obs.span("outer", tag="a"):
        with obs.span("inner"):
            pass
    obs.series("conv", [3.0, 2.0, 1.0], decoder="clompr")
    obs.point("pt", 7.0)
    obs.counter("c").inc(4)
    path = obs.export_jsonl(tmp_path / "t.jsonl")
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    spans = {e["name"]: e for e in lines if e["kind"] == "span"}
    assert spans["outer"]["depth"] == 0 and spans["outer"]["attrs"] == {"tag": "a"}
    assert spans["inner"]["depth"] == 1
    assert spans["outer"]["dur_s"] >= spans["inner"]["dur_s"]
    series = [e for e in lines if e["kind"] == "series"]
    assert series[0]["values"] == [3.0, 2.0, 1.0]
    metric = [e for e in lines if e["kind"] == "metric"]
    assert metric[0]["name"] == "c" and metric[0]["value"] == 4.0


# ---------------------------------------------------------------------------
# Engine instrumentation
# ---------------------------------------------------------------------------


def test_engine_disabled_path_is_silent_and_identical(rng):
    eng = SketchEngine(_op())
    x = jax.random.normal(rng, (64, 3))
    z0, lo0, hi0 = eng.sketch(x)
    assert obs.TRACER.events == [] and obs.snapshot() == {}
    obs.enable()
    z1, lo1, hi1 = eng.sketch(x)
    obs.disable()
    assert jnp.array_equal(z0, z1) and jnp.array_equal(lo0, lo1)


def test_engine_spans_and_counters(rng):
    eng = SketchEngine(_op())
    x = jax.random.normal(rng, (50, 3))
    obs.enable()
    state = eng.update(eng.init_state(), x)
    state = eng.update(state, x[:20])
    eng.finalize(state)
    obs.disable()
    snap = obs.snapshot()
    assert snap["engine.update.calls{backend=xla,bits=none}"] == 2
    assert snap["engine.update.rows{backend=xla,bits=none}"] == 70
    assert snap["engine.finalize.calls{backend=xla,bits=none}"] == 1
    assert snap["engine.state.bytes{backend=xla,bits=none}"] > 0
    names = [e["name"] for e in obs.TRACER.spans()]
    assert names.count("engine.update") == 2
    assert names.count("engine.merge") == 2
    assert names.count("engine.finalize") == 1


def test_engine_quantized_labels(rng):
    from repro.core import quantize as qz

    q = qz.make_quantizer(jax.random.PRNGKey(3), 32, "1bit")
    eng = SketchEngine(_op(), quantizer=q)
    obs.enable()
    eng.sketch(jax.random.normal(rng, (40, 3)))
    obs.disable()
    assert obs.snapshot()["engine.update.rows{backend=xla,bits=1}"] == 40


def test_engine_handles_survive_registry_reset(rng):
    eng = SketchEngine(_op())
    x = jax.random.normal(rng, (8, 3))
    obs.enable()
    eng.update(eng.init_state(), x)
    obs.reset()  # stale handles must be re-resolved, not incremented orphaned
    eng.update(eng.init_state(), x)
    obs.disable()
    assert obs.snapshot()["engine.update.calls{backend=xla,bits=none}"] == 1


# ---------------------------------------------------------------------------
# Ingest instrumentation
# ---------------------------------------------------------------------------


def test_ingest_stats_surface_as_metrics(rng):
    eng = SketchEngine(_op())
    batches = [np.asarray(jax.random.normal(jax.random.fold_in(rng, i), (32, 3)))
               for i in range(5)]
    obs.enable()
    state, stats = ingest_mod.ingest_stream(eng, batches, prefetch=2)
    obs.disable()
    snap = obs.snapshot()
    assert snap["ingest.batches"] == stats.batches == 5
    assert snap["ingest.points"] == stats.points == 160
    assert snap["ingest.compute_s"] == pytest.approx(stats.compute_s)
    assert 0.0 <= snap["ingest.overlap_efficiency"] <= 1.0
    assert snap["ingest.resident_batches"] == 4  # prefetch + 2
    assert obs.TRACER.spans("ingest.stream")


def test_ingest_silent_and_identical_when_disabled(rng):
    eng = SketchEngine(_op())
    batches = [np.asarray(jax.random.normal(jax.random.fold_in(rng, i), (16, 3)))
               for i in range(3)]
    state, _ = ingest_mod.ingest_stream(eng, batches)
    assert obs.snapshot() == {} and obs.TRACER.events == []
    obs.enable()
    state2, _ = ingest_mod.ingest_stream(eng, batches)
    obs.disable()
    z0, _, _ = eng.finalize(state)
    z1, _, _ = eng.finalize(state2)
    assert jnp.array_equal(z0, z1)


# ---------------------------------------------------------------------------
# Decoder convergence traces
# ---------------------------------------------------------------------------


def _sketch_for_decode(blobs3, m=60):
    op = fo.make_operator(
        "dense", jax.random.PRNGKey(1), m, 2, jnp.asarray(0.2)
    )
    eng = SketchEngine(op)
    z, lo, hi = eng.sketch(jnp.asarray(blobs3))
    return z, op, lo, hi


def test_clompr_trace_output_and_parity(blobs3):
    z, op, lo, hi = _sketch_for_decode(blobs3)
    cfg = CLOMPRConfig(k=3, atom_steps=40, joint_steps=30, nnls_iters=40,
                       final_steps=80)
    c0, a0, cost0 = clompr(jax.random.PRNGKey(2), z, op, lo, hi, cfg)
    out = clompr(jax.random.PRNGKey(2), z, op, lo, hi,
                 dataclasses.replace(cfg, trace=True))
    c1, a1, cost1, traces = out
    # Tracing must not perturb the decode (buffers are DCE'd when off).
    assert jnp.array_equal(c0, c1) and jnp.array_equal(cost0, cost1)
    res = np.asarray(traces["residual_norm"])
    assert res.shape == (2 * cfg.k,) and np.all(np.isfinite(res))
    # Greedy pursuit: the final residual is far below the first round's.
    assert res[-1] < res[0]


def test_sketch_shift_trace_output(blobs3):
    z, op, lo, hi = _sketch_for_decode(blobs3)
    cfg = SketchShiftConfig(k=3, candidates=6, shift_steps=30,
                            polish_steps=50, nnls_iters=40, trace=True)
    _, _, _, traces = sketch_shift(jax.random.PRNGKey(2), z, op, lo, hi, cfg)
    res = np.asarray(traces["residual_norm"])
    assert res.shape == (3,) and np.all(np.isfinite(res))
    # Deflation: each harvested mode shrinks the residual.
    assert res[-1] < res[0]


def test_decode_sketch_emits_series_when_enabled(blobs3):
    z, op, lo, hi = _sketch_for_decode(blobs3)
    cfg = ckm_mod.CKMConfig(k=3, m=60, **FAST)
    c0, a0, cost0 = ckm_mod.decode_sketch(
        jax.random.PRNGKey(2), z, op, lo, hi, cfg
    )
    obs.enable()
    c1, a1, cost1 = ckm_mod.decode_sketch(
        jax.random.PRNGKey(2), z, op, lo, hi, cfg
    )
    obs.disable()
    assert jnp.array_equal(c0, c1) and jnp.array_equal(cost0, cost1)
    series = [e for e in obs.TRACER.events if e["kind"] == "series"]
    assert [e["name"] for e in series] == ["decoder.clompr.residual_norm"]
    assert len(series[0]["values"]) == 2 * cfg.k


def test_decode_sketch_traces_best_replicate(blobs3):
    z, op, lo, hi = _sketch_for_decode(blobs3)
    cfg = ckm_mod.CKMConfig(k=3, m=60, replicates=2, decoder="sketch_shift",
                            **FAST)
    obs.enable()
    _, _, cost = ckm_mod.decode_sketch(
        jax.random.PRNGKey(2), z, op, lo, hi, cfg
    )
    obs.disable()
    series = [e for e in obs.TRACER.events if e["kind"] == "series"]
    assert len(series) == 1 and len(series[0]["values"]) == cfg.k
    # The emitted trace belongs to the *selected* replicate: its last
    # residual-norm squared is the reported pre-polish cost scale (loose
    # sanity: finite, positive, same order as sqrt(cost)).
    assert series[0]["values"][-1] > 0.0


# ---------------------------------------------------------------------------
# fit_streaming end-to-end acceptance (spans + series from JSONL)
# ---------------------------------------------------------------------------


def test_fit_streaming_jsonl_acceptance(tmp_path, blobs3):
    cfg = ckm_mod.CKMConfig(k=3, m=60, **FAST)
    batches = [blobs3[i * 500:(i + 1) * 500] for i in range(6)]
    obs.enable()
    res = ckm_mod.fit_streaming(jax.random.PRNGKey(1), iter(batches), cfg)
    path = obs.export_jsonl(tmp_path / "run.jsonl")
    obs.disable()
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    span_names = {e["name"] for e in lines if e["kind"] == "span"}
    assert {"engine.update", "engine.merge", "engine.finalize"} <= span_names
    series = [e for e in lines if e["kind"] == "series"]
    assert any(e["name"] == "decoder.clompr.residual_norm" for e in series)
    vals = next(e for e in series
                if e["name"] == "decoder.clompr.residual_norm")["values"]
    assert len(vals) == 2 * cfg.k and all(np.isfinite(v) for v in vals)
    metrics = {e["name"]: e["value"] for e in lines if e["kind"] == "metric"}
    assert metrics["engine.update.rows{backend=xla,bits=none}"] == 3000
    # The run itself must be unperturbed by telemetry.
    res2 = ckm_mod.fit_streaming(jax.random.PRNGKey(1), iter(batches), cfg)
    assert jnp.array_equal(res.centroids, res2.centroids)


# ---------------------------------------------------------------------------
# FleetService accounting + drift
# ---------------------------------------------------------------------------


def _fleet_service(cache_entries=2, n_tenants=3, m=32, n=2, decode_cfg=None,
                   decay=None, drift_threshold=None):
    specs = fl.fleet_specs(jax.random.PRNGKey(0), n_tenants, "dense", m, n, 1.0)
    eng = fl.FleetEngine(specs, decay=decay)
    cfg = decode_cfg or ckm_mod.CKMConfig(
        k=2, decoder="sketch_shift", shift_candidates=2, shift_steps=3,
        shift_polish_steps=2, nnls_iters=4,
    )
    return FleetService(eng, cfg, decode_cache_entries=cache_entries,
                        drift_threshold=drift_threshold)


def test_fleet_lru_accounting_matches_hand_simulation(rng):
    """Scripted request sequence vs a hand-simulated LRU: hit/miss/evict
    counters must match *exactly*, version bumps invalidating as counted."""
    from collections import OrderedDict

    svc = _fleet_service(cache_entries=2)
    batch = lambda t, i: np.asarray(
        jax.random.normal(jax.random.fold_in(rng, 10 * t + i), (16, 2))
    )
    # (op, tenant): "w" = submit+flush (version bump), "d" = decode.
    script = [("w", 0), ("w", 1), ("w", 2),
              ("d", 0), ("d", 0),            # miss, hit
              ("d", 1),                      # miss (cache: {0, 1})
              ("d", 2),                      # miss, evicts 0 (LRU)
              ("d", 0),                      # miss again (was evicted)
              ("w", 1), ("d", 1),            # version bump -> miss
              ("d", 2), ("d", 2)]            # miss (evicted above), then hit
    sim = OrderedDict()
    versions = {0: 0, 1: 0, 2: 0}
    exp_hits = exp_misses = exp_evicts = 0
    obs.enable()
    for i, (op_, t) in enumerate(script):
        if op_ == "w":
            svc.submit(t, batch(t, i))
            svc.flush()
            versions[t] += 1
        else:
            r = svc.decode(t)
            key = (t, versions[t])
            if key in sim:
                exp_hits += 1
                sim.move_to_end(key)
                assert r.cached
            else:
                exp_misses += 1
                sim[key] = True
                sim.move_to_end(key)
                while len(sim) > 2:
                    sim.popitem(last=False)
                    exp_evicts += 1
                assert not r.cached
            assert r.version == versions[t]
    obs.disable()
    assert svc.stats.decode_hits == exp_hits == 2
    assert svc.stats.decode_misses == exp_misses == 6
    assert svc.stats.decode_cache_evictions == exp_evicts == 4
    assert svc.cache_len() == len(sim) <= 2
    snap = obs.snapshot()
    assert snap["fleet.decode.hits"] == exp_hits
    assert snap["fleet.decode.misses"] == exp_misses
    assert snap.get("fleet.decode.cache_evictions", 0) == exp_evicts
    assert snap["fleet.flush.seconds"]["count"] == svc.stats.flushes > 0


def test_fleet_drift_gauge_stationary_vs_shifted(rng):
    # A converged decode: the stationary drift is then just the (small)
    # decode residual, so the mean-shift signal stands clear of it.
    svc = _fleet_service(
        cache_entries=4, m=48,
        decode_cfg=ckm_mod.CKMConfig(k=2, m=48, shift_steps=40,
                                     shift_polish_steps=100, nnls_iters=50),
    )
    blob = lambda c, s: jnp.asarray(c) + 0.2 * jax.random.normal(
        jax.random.fold_in(rng, s), (300, 2)
    )
    svc.submit(0, blob([3.0, 3.0], 1))
    svc.submit(0, blob([-3.0, -3.0], 2))
    svc.flush()
    svc.decode(0)
    obs.enable()
    stationary = svc.drift(0)
    svc.submit(0, blob([9.0, 9.0], 3))  # mean shift: stream left the model
    svc.flush()
    shifted = svc.drift(0)
    obs.disable()
    assert shifted > 2.0 * stationary
    assert obs.snapshot()["fleet.drift{tenant=0}"] == pytest.approx(shifted)


def test_fleet_drift_redecode_counter(rng):
    """ISSUE 9: unattended maintenance — when a decayed fleet's flush sees a
    tenant breach drift_threshold it invalidates + re-decodes, and the event
    lands both in stats.drift_redecodes and the fleet.redecode.drift
    counter.  Also pins the all-zero-sketch regression: drift on a fresh
    tenant is a defined 0.0 gauge, never NaN."""
    svc = _fleet_service(
        cache_entries=4, m=48, decay=0.5, drift_threshold=0.25,
        decode_cfg=ckm_mod.CKMConfig(k=2, m=48, shift_steps=40,
                                     shift_polish_steps=100, nnls_iters=50),
    )
    blob = lambda c, s: jnp.asarray(c) + 0.2 * jax.random.normal(
        jax.random.fold_in(rng, s), (300, 2)
    )
    svc.submit(0, blob([3.0, 3.0], 1), t=0.0)
    svc.flush()
    svc.decode(0)
    assert svc.stats.drift_redecodes == 0
    obs.enable()
    # Four ticks of decay (old mass -> 6%) plus a mean shift: the served
    # model is now stale, the auto-maintain on flush must catch it.
    svc.submit(0, blob([9.0, -9.0], 2), t=4.0)
    svc.flush()
    obs.disable()
    assert svc.stats.drift_redecodes >= 1
    snap = obs.snapshot()
    assert snap["fleet.redecode.drift"] == svc.stats.drift_redecodes

    # Regression (ISSUE 9): an all-zero live sketch has nothing to drift
    # from — score and gauge are a defined 0.0, with no decode attempted.
    obs.enable()
    score = svc.drift(1)
    obs.disable()
    assert score == 0.0 and not np.isnan(score)
    assert obs.snapshot()["fleet.drift{tenant=1}"] == 0.0


# ---------------------------------------------------------------------------
# ActivationMonitor satellites
# ---------------------------------------------------------------------------


def test_monitor_freq_op_resolution():
    assert ActivationMonitor(dim=512, k=2, m=64).freq_op == "structured"
    assert ActivationMonitor(dim=8, k=2, m=64).freq_op == "dense"
    mon = ActivationMonitor(dim=1024, k=2, m=64, freq_op="dense")
    assert mon.freq_op == "dense"  # explicit override wins
    # The structured default must not materialize an (m, d) matrix in state.
    big = ActivationMonitor(dim=1024, k=2, m=64)
    assert big.freqs.state_bytes() < 64 * 1024 * 4


def test_monitor_sketch_drift_gauge(rng):
    mon = ActivationMonitor(dim=8, k=2, m=64)
    st = mon.init_state()
    x = jax.random.normal(rng, (400, 8))
    st = mon.update(st, x)
    res = mon.decode(st)
    obs.enable()
    stationary = mon.sketch_drift(st, res)
    shifted = mon.sketch_drift(mon.update(st, x + 5.0), res)
    obs.disable()
    assert shifted > 1.5 * stationary
    assert obs.snapshot()["monitor.sketch_drift"] == pytest.approx(shifted)


# ---------------------------------------------------------------------------
# ckm.diagnose — seeded failure-mode attribution (the PR's acceptance test)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_diagnose_attributes_seeded_failure_modes(blobs3):
    pts = blobs3
    # clompr at a mid budget for the seeded-failure fits; the healthy fit is
    # a well-converged sketch_shift decode of the same sketch size.
    base = dict(k=3, m=60, atom_steps=60, joint_steps=40, nnls_iters=60,
                final_steps=120)

    # -- converged fit -> ok ------------------------------------------------
    good = ckm_mod.fit(
        jax.random.PRNGKey(1), pts,
        ckm_mod.CKMConfig(k=3, m=60, decoder="sketch_shift", shift_steps=60,
                          shift_polish_steps=200, nnls_iters=80),
    )
    d = ckm_mod.diagnose(good, probe_budget=0.4)
    assert d.verdict == "ok" and d.ok

    # -- m too small: half-sketch decodes disagree --------------------------
    small = ckm_mod.fit(
        jax.random.PRNGKey(1), pts, ckm_mod.CKMConfig(**{**base, "m": 8})
    )
    d_m = ckm_mod.diagnose(small, probe_budget=0.4)
    assert d_m.verdict == "sketch_size"
    assert d_m.scores["subsketch_disagreement"] > 0.1

    # -- sigma mis-scaled, both directions ----------------------------------
    sig = float(good.sigma2)
    big = ckm_mod.fit(
        jax.random.PRNGKey(1), pts,
        ckm_mod.CKMConfig(**{**base, "sigma2": 1e4 * sig}),
    )
    d_big = ckm_mod.diagnose(big, probe_budget=0.4)
    assert d_big.verdict == "frequency_scale"
    assert d_big.scores["mean_modulus"] > 0.9
    assert "decrease" in d_big.recommendation

    tiny = ckm_mod.fit(
        jax.random.PRNGKey(1), pts,
        ckm_mod.CKMConfig(**{**base, "sigma2": 1e-4 * sig}),
    )
    d_tiny = ckm_mod.diagnose(tiny, probe_budget=0.4)
    assert d_tiny.verdict == "frequency_scale"
    assert d_tiny.scores["mean_modulus"] < 0.05
    assert "increase" in d_tiny.recommendation

    # -- decoder under-iterated: the probe finds a better fit ----------------
    lazy = ckm_mod.fit(
        jax.random.PRNGKey(1), pts,
        ckm_mod.CKMConfig(k=3, m=60, atom_steps=1, joint_steps=1,
                          nnls_iters=2, final_steps=0),
    )
    d_dec = ckm_mod.diagnose(lazy, probe_budget=0.4)
    assert d_dec.verdict == "decoder"
    assert (d_dec.scores["rel_residual"]
            > 1.5 * d_dec.scores["probe_rel_residual"])


@pytest.mark.slow
def test_diagnose_sigma_sweep_with_sample(blobs3):
    cfg = ckm_mod.CKMConfig(k=3, m=60, decoder="sketch_shift", shift_steps=60,
                            shift_polish_steps=200, nnls_iters=80)
    res = ckm_mod.fit(jax.random.PRNGKey(1), blobs3, cfg)
    d = ckm_mod.diagnose(res, probe_budget=0.3, sample=blobs3[:512])
    rows = d.details["sigma_sweep"]
    assert [r["factor"] for r in rows] == [0.1, 1.0, 10.0]
    # The fitted scale is the healthy one; the x10 scale pushes moduli up.
    assert rows[1]["healthy"]
    assert rows[2]["mean_modulus"] > rows[1]["mean_modulus"] > rows[0]["mean_modulus"]


def test_diagnose_emits_instruments(blobs3):
    cfg = ckm_mod.CKMConfig(k=3, m=60, decoder="sketch_shift", **FAST)
    res = ckm_mod.fit(jax.random.PRNGKey(1), blobs3, cfg)
    obs.enable()
    d = ckm_mod.diagnose(res, probe_budget=0.2)
    obs.disable()
    snap = obs.snapshot()
    assert snap[f"diagnose.verdicts{{verdict={d.verdict}}}"] == 1
    assert obs.TRACER.spans("ckm.diagnose")
