"""Distributed sketch: shard_map psum-merge must equal the single-host sketch.

Multi-device tests run in a subprocess with XLA_FLAGS host-device overrides so
the main pytest process keeps exactly one CPU device (see conftest note).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed_sketch as ds
from repro.core import frequencies as fq
from repro.core import sketch as sk


class TestAccumulator:
    def test_update_merge_finalize_equals_batch_sketch(self, rng):
        kx, kw = jax.random.split(rng)
        x = jax.random.normal(kx, (300, 4))
        w = fq.draw_frequencies(kw, 16, 4, 1.0)
        # Stream in 3 uneven chunks through two accumulators, then merge.
        a = ds.init_state(16, 4)
        b = ds.init_state(16, 4)
        a = ds.update(a, x[:50], w)
        a = ds.update(a, x[50:120], w)
        b = ds.update(b, x[120:], w)
        z, lo, hi = ds.finalize(ds.merge(a, b))
        np.testing.assert_allclose(np.asarray(z), np.asarray(sk.sketch(x, w)), atol=1e-5)
        np.testing.assert_allclose(np.asarray(lo), np.asarray(x.min(0)), atol=1e-6)
        np.testing.assert_allclose(np.asarray(hi), np.asarray(x.max(0)), atol=1e-6)

    def test_merge_commutative(self, rng):
        kx, kw = jax.random.split(rng)
        x = jax.random.normal(kx, (100, 3))
        w = fq.draw_frequencies(kw, 8, 3, 1.0)
        a = ds.update(ds.init_state(8, 3), x[:40], w)
        b = ds.update(ds.init_state(8, 3), x[40:], w)
        z1, *_ = ds.finalize(ds.merge(a, b))
        z2, *_ = ds.finalize(ds.merge(b, a))
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-6)


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import distributed_sketch as ds
    from repro.core import frequencies as fq
    from repro.core import sketch as sk

    assert len(jax.devices()) == 8
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (4096, 6))
    w = fq.draw_frequencies(kw, 32, 6, 1.0)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    xs = ds.shard_points(x, mesh, ("data",))
    z, lo, hi = ds.sharded_sketch(xs, w, mesh, ("data",), chunk=512)
    z_ref = sk.sketch(x, w)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(lo), np.asarray(x.min(0)), atol=1e-6)
    np.testing.assert_allclose(np.asarray(hi), np.asarray(x.max(0)), atol=1e-6)

    # pod x data mesh: merge across both axes.
    mesh2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    xs2 = ds.shard_points(x, mesh2, ("pod", "data"))
    z2, lo2, hi2 = ds.sharded_sketch(xs2, w, mesh2, ("pod", "data"), chunk=512)
    np.testing.assert_allclose(np.asarray(z2), np.asarray(z_ref), atol=1e-5)
    print("OK")
    """
)


def test_sharded_sketch_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, capture_output=True, text=True,
        timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
