"""CKM-compressed KV attention: exactness + fidelity properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import layers as L
from repro.models import transformer as tfm
from repro.serve.kv_clustering import (
    attention_decode_compressed,
    build_compressed_cache,
    compress_kv,
)


def _setup():
    cfg = get_smoke_config("llama3.2-1b")
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    p0 = jax.tree.map(lambda l: l[0], params["groups"]["0"])
    dims = tfm.attn_dims(cfg, "attn")
    return cfg, p0, dims


def _full_attention(p0, dims, q_tok, k, v, index):
    kp = jnp.pad(k, ((0, 0), (0, 1), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 1), (0, 0), (0, 0)))
    out, _, _ = L.attention_decode(p0["mixer"], dims, q_tok, kp, vp, index)
    return out


def _manual_cache(k_cent, v_cent, logw, ring_k, ring_v):
    return {"ck": k_cent, "cv": v_cent, "clogw": logw, "k": ring_k, "v": ring_v}


class TestCompressedKVAttention:
    def test_exact_when_every_key_is_its_own_centroid(self):
        """Centroids = prefix keys (unit clusters, log w = 0) + exact ring:
        the compressed step must equal full attention."""
        cfg, p0, dims = _setup()
        s, ring = 48, 16
        key = jax.random.PRNGKey(3)
        kk, kv_, kq = jax.random.split(key, 3)
        k = jax.random.normal(kk, (1, s, cfg.n_kv_heads, cfg.head_dim_)) * 3
        v = jax.random.normal(kv_, (1, s, cfg.n_kv_heads, cfg.head_dim_))
        x = jax.random.normal(kq, (1, 1, cfg.d_model))
        split = s - ring + 1
        ring_k = jnp.zeros((1, ring, cfg.n_kv_heads, cfg.head_dim_))
        ring_v = jnp.zeros_like(ring_k)
        pos = jnp.arange(split, s)
        ring_k = ring_k.at[:, pos % ring].set(k[:, split:])
        ring_v = ring_v.at[:, pos % ring].set(v[:, split:])
        cache = _manual_cache(
            k[:, :split], v[:, :split],
            jnp.zeros((1, split, cfg.n_kv_heads)), ring_k, ring_v,
        )
        out_c, _ = attention_decode_compressed(
            p0["mixer"], dims, x, cache, jnp.asarray(s)
        )
        out_f = _full_attention(p0, dims, x, k, v, jnp.asarray(s))
        np.testing.assert_allclose(
            np.asarray(out_c), np.asarray(out_f), atol=2e-3, rtol=1e-2
        )

    def test_duplicate_keys_collapse_losslessly(self):
        """w identical keys -> one centroid with log w bias: exact again."""
        cfg, p0, dims = _setup()
        uniq, dup, ring = 12, 4, 8
        key = jax.random.PRNGKey(4)
        kk, kv_, kq = jax.random.split(key, 3)
        k_u = jax.random.normal(kk, (1, uniq, cfg.n_kv_heads, cfg.head_dim_)) * 3
        v_u = jax.random.normal(kv_, (1, uniq, cfg.n_kv_heads, cfg.head_dim_))
        # prefix = duplicated keys; ring = a few extra exact keys
        k_pre = jnp.repeat(k_u, dup, axis=1)
        v_pre = jnp.repeat(v_u, dup, axis=1)
        k_ring_src = jax.random.normal(
            jax.random.PRNGKey(8), (1, ring - 1, cfg.n_kv_heads, cfg.head_dim_)
        )
        v_ring_src = jax.random.normal(
            jax.random.PRNGKey(9), (1, ring - 1, cfg.n_kv_heads, cfg.head_dim_)
        )
        k = jnp.concatenate([k_pre, k_ring_src], axis=1)
        v = jnp.concatenate([v_pre, v_ring_src], axis=1)
        s = k.shape[1]
        x = jax.random.normal(kq, (1, 1, cfg.d_model))
        split = s - ring + 1  # == uniq*dup
        assert split == uniq * dup
        ring_k = jnp.zeros((1, ring, cfg.n_kv_heads, cfg.head_dim_))
        ring_v = jnp.zeros_like(ring_k)
        pos = jnp.arange(split, s)
        ring_k = ring_k.at[:, pos % ring].set(k[:, split:])
        ring_v = ring_v.at[:, pos % ring].set(v[:, split:])
        cache = _manual_cache(
            k_u, v_u, jnp.full((1, uniq, cfg.n_kv_heads), jnp.log(float(dup))),
            ring_k, ring_v,
        )
        out_c, _ = attention_decode_compressed(
            p0["mixer"], dims, x, cache, jnp.asarray(s)
        )
        out_f = _full_attention(p0, dims, x, k, v, jnp.asarray(s))
        np.testing.assert_allclose(
            np.asarray(out_c), np.asarray(out_f), atol=2e-3, rtol=1e-2
        )

    @pytest.mark.parametrize("method", ["lloyd", "ckm"])
    def test_clustered_kv_high_fidelity(self, method):
        """Keys WITH cluster structure (the real-cache regime): small error."""
        cfg, p0, dims = _setup()
        s, n_clusters, ring = 512, 16, 32
        key = jax.random.PRNGKey(5)
        kc_, ka, kv_, kq = jax.random.split(key, 4)
        centers = jax.random.normal(kc_, (n_clusters, cfg.n_kv_heads, cfg.head_dim_)) * 4
        assign = jax.random.randint(ka, (s,), 0, n_clusters)
        k = centers[assign][None] + 0.1 * jax.random.normal(
            kv_, (1, s, cfg.n_kv_heads, cfg.head_dim_)
        )
        v = centers[assign][None] * 0.5 + 0.05 * jax.random.normal(
            kq, (1, s, cfg.n_kv_heads, cfg.head_dim_)
        )
        x = jax.random.normal(jax.random.PRNGKey(6), (1, 1, cfg.d_model))
        cache = build_compressed_cache(
            jax.random.PRNGKey(7), k, v, n_clusters, ring, method=method
        )
        out_c, _ = attention_decode_compressed(
            p0["mixer"], dims, x, cache, jnp.asarray(s)
        )
        out_f = _full_attention(p0, dims, x, k, v, jnp.asarray(s))
        rel = float(
            jnp.linalg.norm(out_c - out_f) / jnp.maximum(jnp.linalg.norm(out_f), 1e-9)
        )
        assert rel < 0.15, f"{method}: rel err {rel}"

    def test_ring_receives_new_token(self):
        cfg, p0, dims = _setup()
        s = 32
        k = jnp.zeros((1, s, cfg.n_kv_heads, cfg.head_dim_))
        cache = _manual_cache(
            k, k, jnp.zeros((1, s, cfg.n_kv_heads)),
            jnp.zeros((1, 8, cfg.n_kv_heads, cfg.head_dim_)),
            jnp.zeros((1, 8, cfg.n_kv_heads, cfg.head_dim_)),
        )
        x = jnp.ones((1, 1, cfg.d_model))
        _, new = attention_decode_compressed(
            p0["mixer"], dims, x, cache, jnp.asarray(s)
        )
        slot = s % 8
        assert float(jnp.abs(new["k"][0, slot]).sum()) > 0.0
