"""Docs-links check: cross-references resolve, named symbols import.

The docs site promises three kinds of integrity, enforced here in tier-1:

1. every relative markdown link in ``docs/*.md`` + ``README.md`` points at a
   file that exists, and every ``#anchor`` on such a link (and every
   ``[[...]]``-style anchor, should one appear) matches a real heading slug
   in the target file;
2. every dotted ``repro.*`` name mentioned in backticks imports — module
   path plus attribute chain — so the docs cannot name a symbol that was
   renamed away;
3. every backticked ``CKMConfig.<field>`` or ``SketchJobSpec.<field>`` is a
   real config field (the kind of drift PR-sized refactors create).
"""

import dataclasses
import importlib
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_WIKILINK = re.compile(r"\[\[([^\]]+)\]\]")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_SPAN = re.compile(r"`([^`\n]+)`")
_REPRO_NAME = re.compile(r"^repro(\.[A-Za-z_]\w*)+$")
_CFG_FIELD = re.compile(r"^CKMConfig\.(\w+)$")
_JOBSPEC_FIELD = re.compile(r"^SketchJobSpec\.(\w+)$")
_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, dash spaces."""
    text = re.sub(r"[`*_]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _anchors(path: Path) -> set[str]:
    return {_slugify(h) for h in _HEADING.findall(path.read_text())}


def _prose(path: Path) -> str:
    """File text with fenced code blocks removed (snippets are executed by
    test_docs.py; here we only vet prose-level references)."""
    return _FENCE.sub("", path.read_text())


@pytest.mark.docs
@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_relative_links_resolve(path):
    text = path.read_text()
    problems = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, anchor = target.partition("#")
        dest = (path.parent / ref).resolve() if ref else path
        if ref and not dest.exists():
            problems.append(f"{target}: file {ref} missing")
            continue
        if anchor and dest.suffix == ".md" and anchor not in _anchors(dest):
            problems.append(f"{target}: no heading for #{anchor} in {dest.name}")
    for name in _WIKILINK.findall(_prose(path)):
        slug = _slugify(name)
        if not any(slug in _anchors(p) for p in DOC_FILES):
            problems.append(f"[[{name}]]: no heading slug {slug!r} in any doc")
    assert not problems, f"{path.name}:\n" + "\n".join(problems)


def _resolve_dotted(name: str):
    """Import the longest module prefix, then getattr the rest."""
    parts = name.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        for attr in parts[cut:]:
            obj = getattr(obj, attr)  # AttributeError = broken doc reference
        return obj
    raise ImportError(f"no importable prefix of {name!r}")


@pytest.mark.docs
@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_named_public_symbols_exist(path):
    problems = []
    spans = set(_CODE_SPAN.findall(_prose(path)))
    for span in sorted(spans):
        token = span.strip().rstrip("()")
        if _REPRO_NAME.match(token):
            try:
                _resolve_dotted(token)
            except (ImportError, AttributeError) as e:
                problems.append(f"`{span}`: {e}")
        m = _CFG_FIELD.match(token)
        if m:
            from repro.core.ckm import CKMConfig

            fields = {f.name for f in dataclasses.fields(CKMConfig)}
            if m.group(1) not in fields:
                problems.append(f"`{span}`: CKMConfig has no field {m.group(1)!r}")
        m = _JOBSPEC_FIELD.match(token)
        if m:
            from repro.launch.specs import SketchJobSpec

            fields = {f.name for f in dataclasses.fields(SketchJobSpec)}
            # methods (fleet_kwargs(), service_kwargs(), validate(), ...)
            # are legitimate references too — anything on the class counts
            if m.group(1) not in fields and not hasattr(
                SketchJobSpec, m.group(1)
            ):
                problems.append(
                    f"`{span}`: SketchJobSpec has no field or attribute "
                    f"{m.group(1)!r}"
                )
    assert not problems, f"{path.name}:\n" + "\n".join(problems)


def test_docs_corpus_nonempty():
    assert len(DOC_FILES) >= 4  # architecture, api, scaling, README


@pytest.mark.docs
def test_obs_public_api_resolves_and_is_documented():
    """Every ``repro.obs.__all__`` symbol exists on the package AND appears
    in ``docs/observability.md`` — the metrics/tracing/diagnostics API
    cannot grow an undocumented (or documented-but-renamed) surface."""
    import repro.obs as obs

    doc = (ROOT / "docs" / "observability.md").read_text()
    problems = []
    for name in obs.__all__:
        if not hasattr(obs, name):
            problems.append(f"repro.obs.__all__ names missing attr {name!r}")
        if name not in doc:
            problems.append(f"repro.obs.{name} not mentioned in observability.md")
    assert not problems, "\n".join(problems)
