"""Fallback for ``hypothesis`` so property tests run without the dependency.

When the real library is installed (see requirements-test.txt) it is used
unchanged.  When it is missing, a tiny vendored substitute provides the same
``@settings/@given`` surface with *deterministic* pseudo-random sampling
(``random.Random(0)``): each property still gets exercised on ``max_examples``
drawn inputs, it just loses shrinking and the adaptive search.  That keeps a
missing dev dependency from erroring test collection while preserving the
property coverage.
"""

try:  # pragma: no cover - exercised implicitly by which branch imports
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda r: options[r.randrange(len(options))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10, unique=False):
            def draw(r):
                size = r.randint(min_size, max_size)
                out, seen, attempts = [], set(), 0
                # bounded retry loop so unique=True over a small element
                # domain cannot spin forever
                while len(out) < size and attempts < 100 * max(size, 1):
                    v = elements.draw(r)
                    attempts += 1
                    if unique:
                        if v in seen:
                            continue
                        seen.add(v)
                    out.append(v)
                return out

            return _Strategy(draw)

    st = _Strategies()

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **_kw):
                # args is () for functions, (self,) for methods.
                n = getattr(wrapper, "_max_examples", 10)
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn)

            # pytest must not see the strategy parameters (it would resolve
            # them as fixtures): expose only the remaining ones (e.g. self).
            keep = [
                p
                for name, p in inspect.signature(fn).parameters.items()
                if name not in strategies
            ]
            wrapper.__signature__ = inspect.Signature(keep)
            del wrapper.__wrapped__
            return wrapper

        return deco
