"""The trip-count-aware HLO cost analyzer vs known-flop programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils import hlo


def _compiled(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


class TestHloAnalyzer:
    def test_single_matmul_flops(self):
        m, k, n = 128, 256, 512
        c = _compiled(
            lambda a, b: a @ b,
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32),
        )
        costs = hlo.analyze_compiled(c)
        assert costs.flops == pytest.approx(2 * m * k * n, rel=0.01)

    def test_scan_multiplies_by_trip_count(self):
        n_steps = 8
        d = 128

        def f(x, w):
            def body(c, _):
                return c @ w, None

            y, _ = jax.lax.scan(body, x, None, length=n_steps)
            return y

        c = _compiled(
            f,
            jax.ShapeDtypeStruct((d, d), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
        )
        costs = hlo.analyze_compiled(c)
        assert costs.flops == pytest.approx(n_steps * 2 * d**3, rel=0.01)
        # XLA's own cost_analysis undercounts — that's why this module exists.
        ca = c.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        assert float(ca["flops"]) < costs.flops

    def test_nested_scan(self):
        d, outer, inner = 64, 3, 5

        def f(x, w):
            def inner_body(c, _):
                return c @ w, None

            def outer_body(c, _):
                c, _ = jax.lax.scan(inner_body, c, None, length=inner)
                return c, None

            y, _ = jax.lax.scan(outer_body, x, None, length=outer)
            return y

        c = _compiled(
            f,
            jax.ShapeDtypeStruct((d, d), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
        )
        costs = hlo.analyze_compiled(c)
        assert costs.flops == pytest.approx(outer * inner * 2 * d**3, rel=0.01)

    def test_batched_dot_flops(self):
        b, m, k, n = 4, 32, 64, 16
        c = _compiled(
            lambda a, w: jnp.einsum("bmk,bkn->bmn", a, w),
            jax.ShapeDtypeStruct((b, m, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k, n), jnp.float32),
        )
        costs = hlo.analyze_compiled(c)
        assert costs.flops == pytest.approx(2 * b * m * k * n, rel=0.01)

    def test_bytes_at_least_io(self):
        n = 1 << 16
        c = _compiled(lambda a: a * 2.0 + 1.0, jax.ShapeDtypeStruct((n,), jnp.float32))
        costs = hlo.analyze_compiled(c)
        assert costs.bytes >= 2 * 4 * n  # read + write once
        assert costs.bytes <= 6 * 4 * n  # and not wildly more

    def test_collectives_counted_with_trip_count(self):
        """psum inside a scanned body over a 4-device mesh."""
        import subprocess, sys, os, textwrap

        prog = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.utils import hlo
            from repro.utils.compat import shard_map

            mesh = jax.make_mesh((4,), ("d",))
            steps, n = 6, 1024

            def f(x):
                def body(c, _):
                    return jax.lax.psum(c, "d"), None
                y, _ = jax.lax.scan(body, x, None, length=steps)
                return y

            fn = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())
            c = jax.jit(fn).lower(jax.ShapeDtypeStruct((n,), jnp.float32)).compile()
            costs = hlo.analyze_compiled(c)
            expect = steps * n * 4
            assert abs(costs.coll_by_op.get("all-reduce", 0) - expect) / expect < 0.05, costs.coll_by_op
            assert costs.coll_count["all-reduce"] == steps
            print("OK")
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-c", prog], env=env, capture_output=True, text=True,
            timeout=180,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout


class TestSliceCostSemantics:
    def test_scan_xs_not_billed_full_per_iteration(self):
        """A scan body dynamic-slices its stacked xs: per-iteration bytes must
        be slice-sized, not the whole stacked tensor (the xlstm 369 TiB
        phantom of EXPERIMENTS §Perf P5)."""
        import jax, jax.numpy as jnp

        steps, d = 64, 128

        def f(xs):
            def body(c, x):
                return c + jnp.sum(x * 2.0), None

            out, _ = jax.lax.scan(body, jnp.zeros(()), xs)
            return out

        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((steps, d, d), jnp.float32)
        ).compile()
        costs = hlo.analyze_compiled(c)
        full_every_iter = steps * steps * d * d * 4
        one_pass = steps * d * d * 4
        assert costs.bytes < 0.2 * full_every_iter, costs.bytes
        assert costs.bytes >= one_pass, costs.bytes
