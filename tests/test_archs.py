"""Per-architecture smoke tests on reduced configs (assignment requirement).

For every one of the 10 assigned archs:
- one forward + train-loss step on CPU, asserting shapes + finiteness;
- prefill -> decode_step consistency: decoding token t against the cache must
  reproduce the full-sequence forward logits at position t (catches cache,
  ring-buffer, rope and state-carry bugs in one go).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, get_smoke_config
from repro.models import transformer as tfm

B, S = 2, 32


def _batch(cfg, key, s=S):
    kt, kp = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(kt, (B, s), 0, cfg.vocab_size),
    }
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            kp, (B, cfg.frontend_len, cfg.d_model), jnp.float32
        )
    elif cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            kp, (B, cfg.frontend_len, cfg.d_model), jnp.float32
        )
    return batch


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = get_smoke_config(request.param)
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    return request.param, cfg, params


class TestSmokeForward:
    def test_forward_shapes_and_finite(self, arch_setup):
        arch, cfg, params = arch_setup
        batch = _batch(cfg, jax.random.PRNGKey(1))
        x, aux = tfm.forward(params, cfg, batch, dtype=jnp.float32)
        s_total = S + (cfg.frontend_len if cfg.frontend == "vision" else 0)
        assert x.shape == (B, s_total, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(x))), f"{arch}: non-finite hidden states"
        assert bool(jnp.isfinite(aux))

    def test_train_loss_and_grads_finite(self, arch_setup):
        arch, cfg, params = arch_setup
        batch = _batch(cfg, jax.random.PRNGKey(2))

        loss, grads = jax.value_and_grad(
            lambda p: tfm.lm_loss(p, cfg, batch, dtype=jnp.float32)
        )(params)
        assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
        leaves = jax.tree.leaves(grads)
        assert leaves and all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
        # Sanity: loss near log(vocab) for random init.
        assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3 * np.log(cfg.vocab_size)

    def test_remat_matches_no_remat(self, arch_setup):
        arch, cfg, params = arch_setup
        batch = _batch(cfg, jax.random.PRNGKey(3))
        l0 = tfm.lm_loss(params, cfg, batch, dtype=jnp.float32, remat="none")
        l1 = tfm.lm_loss(params, cfg, batch, dtype=jnp.float32, remat="full")
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


class TestPrefillDecodeConsistency:
    def test_decode_matches_forward(self, arch_setup):
        arch, cfg, params = arch_setup
        if cfg.frontend == "vision":
            pytest.skip("decode consistency covered by text archs; vlm prefix static")
        s_prompt, n_steps = 16, 4
        batch = _batch(cfg, jax.random.PRNGKey(4), s=s_prompt + n_steps)
        tokens = batch["tokens"]

        # Reference: full forward logits at each position.
        full_batch = dict(batch)
        full_batch["tokens"] = tokens
        x, _ = tfm.forward(params, cfg, full_batch, dtype=jnp.float32)
        ref_logits = tfm.logits_fn(params, cfg, x)  # (B, S, V)

        # Prefill on the prompt, then decode the next n_steps tokens.
        pre_batch = dict(batch)
        pre_batch["tokens"] = tokens[:, :s_prompt]
        logits, cache, index = tfm.prefill(
            params, cfg, pre_batch, cache_len=s_prompt + n_steps, dtype=jnp.float32
        )
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]),
            np.asarray(ref_logits[:, s_prompt - 1]),
            atol=2e-2, rtol=1e-2,
        )
        for t in range(n_steps):
            tok = tokens[:, s_prompt + t][:, None]
            logits, cache = tfm.decode_step(
                params, cfg, tok, cache, index, dtype=jnp.float32
            )
            index = index + 1
            np.testing.assert_allclose(
                np.asarray(logits[:, 0]),
                np.asarray(ref_logits[:, s_prompt + t]),
                atol=2e-2, rtol=1e-2,
                err_msg=f"{arch}: decode step {t} diverges from forward",
            )


class TestConfigs:
    def test_full_configs_match_assignment(self):
        """The exact full configs: layer/width/vocab per the assignment table."""
        from repro.configs.base import get_config

        expect = {
            "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
            "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
            "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
            "smollm-360m": (32, 960, 15, 5, 2560, 49152),
            "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
            "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
            "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
            "xlstm-125m": (12, 768, 4, 4, 0, 50304),
            "whisper-small": (12, 768, 12, 12, 3072, 51865),
            "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        }
        for arch, (nl, d, h, kv, ff, v) in expect.items():
            cfg = get_config(arch)
            got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                   cfg.d_ff, cfg.vocab_size)
            assert got == (nl, d, h, kv, ff, v), f"{arch}: {got}"

    def test_param_counts_in_band(self):
        """Analytic param counts land near the advertised model sizes."""
        from repro.configs.base import get_config

        bands = {
            "mistral-large-123b": (100e9, 140e9),
            "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
            "jamba-v0.1-52b": (40e9, 60e9),
            "llama3.2-1b": (0.9e9, 1.6e9),
            "smollm-360m": (0.3e9, 0.45e9),
            "granite-moe-1b-a400m": (0.8e9, 1.6e9),
            "gemma3-1b": (0.7e9, 1.3e9),
            "xlstm-125m": (0.1e9, 0.2e9),
        }
        for arch, (lo, hi) in bands.items():
            n = get_config(arch).param_count()
            assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"

    def test_moe_active_params(self):
        from repro.configs.base import get_config

        kimi = get_config("kimi-k2-1t-a32b")
        active = kimi.active_param_count()
        assert 25e9 < active < 40e9, f"kimi active {active/1e9:.1f}B"
