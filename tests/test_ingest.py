"""Async ingest: prefetch parity, error propagation, async==sync fits.

The ingest pipeline must be a pure plumbing change: same batches, same order,
same ops — so the async path's results are *identical* to the sync path's,
not merely close.  That equality is the acceptance test here (ISSUE 4's
"async-vs-sync fit_streaming equality").
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ckm as ckm_mod
from repro.core import engine as eng_mod
from repro.core import frequencies as fq
from repro.core import ingest as ing
from repro.core import quantize as qz
from repro.data import pipeline as pipe


def _blobs(npts=2000, n=3, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (npts, n)) * 2.0
    w = fq.draw_frequencies(kw, 40, n, 1.0)
    return x, w


class TestBatchSource:
    def test_protocol_conformance(self):
        x, _ = _blobs()
        assert isinstance(pipe.chunked(x, 128), ing.BatchSource)
        assert isinstance([x[:10], x[10:]], ing.BatchSource)

        from repro.configs.base import ShapeConfig, get_smoke_config
        from repro.data.pipeline import DataConfig, SyntheticLM

        src = SyntheticLM(
            get_smoke_config("llama3.2-1b"),
            ShapeConfig("t", 16, 8, "train"),
            DataConfig(seed=0, embed_dim=8),
        )
        assert isinstance(src.embedding_stream(0, 2), ing.BatchSource)

    def test_with_latency_passthrough(self):
        x, _ = _blobs(npts=64)
        batches = list(pipe.with_latency(pipe.chunked(x, 32), 0.0))
        assert len(batches) == 2
        np.testing.assert_array_equal(np.asarray(batches[0]), np.asarray(x[:32]))
        with pytest.raises(ValueError):
            next(pipe.with_latency(pipe.chunked(x, 32), -1.0))


class TestPrefetched:
    @pytest.mark.parametrize("prefetch", [1, 2, 5])
    def test_order_and_content_preserved(self, prefetch):
        x, _ = _blobs(npts=997)  # ragged tail
        got = list(ing.prefetched(pipe.chunked(x, 100), prefetch))
        ref = list(pipe.chunked(x, 100))
        assert len(got) == len(ref)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))

    def test_bad_depth_rejected(self):
        with pytest.raises(ValueError):
            list(ing.prefetched([jnp.zeros((2, 2))], prefetch=0))

    def test_source_error_propagates(self):
        def bad():
            yield jnp.zeros((4, 2))
            raise RuntimeError("disk on fire")

        with pytest.raises(RuntimeError, match="disk on fire"):
            list(ing.prefetched(bad(), 2))

    def test_early_consumer_exit_shuts_producer_down(self):
        x, _ = _blobs(npts=4000)
        it = ing.prefetched(pipe.chunked(x, 100), 2)
        next(it)
        it.close()  # generator finalizer must stop the worker thread


class TestIngestStream:
    def test_bitwise_equal_to_sync_fold(self):
        x, w = _blobs(npts=1503)
        e = eng_mod.SketchEngine(w, "xla", chunk=128)
        state = e.init_state()
        for b in pipe.chunked(x, 200):
            state = e.update(state, b)
        z_sync = e.finalize(state)
        a_state, stats = ing.ingest_stream(e, pipe.chunked(x, 200), prefetch=3)
        z_async = e.finalize(a_state)
        for zs, za in zip(z_sync, z_async):
            assert bool(jnp.array_equal(zs, za))
        assert stats.batches == 8 and stats.points == 1503
        assert 0.0 <= stats.overlap_efficiency <= 1.0

    def test_quantized_path_bitwise(self):
        x, w = _blobs(npts=900)
        q = qz.make_quantizer(jax.random.PRNGKey(4), 40, "1bit")
        e = eng_mod.SketchEngine(w, "xla", quantizer=q)
        s_sync = e.init_state()
        for b in pipe.chunked(x, 128):
            s_sync = e.update(s_sync, b)
        s_async, _ = ing.ingest_stream(e, pipe.chunked(x, 128))
        assert bool(jnp.array_equal(s_sync.qcos_acc, s_async.qcos_acc))
        assert bool(jnp.array_equal(s_sync.qsin_acc, s_async.qsin_acc))

    def test_resumes_from_existing_state(self):
        """ingest_stream folds INTO a prior state (fit_streaming's shape:
        first batch consumed for sigma2, the rest streamed async)."""
        x, w = _blobs(npts=1000)
        e = eng_mod.SketchEngine(w, "xla")
        head = e.update(e.init_state(), x[:300])
        tail, _ = ing.ingest_stream(e, pipe.chunked(x[300:], 250), state=head)
        z_split = e.finalize(tail)
        z_once = e.sketch(x)
        for zs, zo in zip(z_split, z_once):
            np.testing.assert_allclose(
                np.asarray(zs), np.asarray(zo), atol=1e-5
            )

    def test_donate_preserves_caller_state_and_tolerance(self):
        """donate=True carries a private copy (the caller's state survives)
        and stays within float tolerance of the non-donated fold (it fuses
        update into one jit, which may reassociate — hence opt-in)."""
        x, w = _blobs(npts=1200)
        e = eng_mod.SketchEngine(w, "xla")
        head = e.update(e.init_state(), x[:300])
        nd, _ = ing.ingest_stream(e, pipe.chunked(x[300:], 300), state=head)
        d, _ = ing.ingest_stream(
            e, pipe.chunked(x[300:], 300), state=head, donate=True
        )
        # caller's state must still be alive and correct after donation
        z_head, *_ = e.finalize(head)
        z_ref, *_ = e.finalize(e.update(e.init_state(), x[:300]))
        assert bool(jnp.array_equal(z_head, z_ref))
        for a, b in zip(e.finalize(nd), e.finalize(d)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-5
            )

    def test_donate_quantized_bitwise(self):
        """Integer code accumulators are fusion-proof: donate path bitwise."""
        x, w = _blobs(npts=1000)
        q = qz.make_quantizer(jax.random.PRNGKey(9), 40, "1bit")
        e = eng_mod.SketchEngine(w, "xla", quantizer=q)
        nd, _ = ing.ingest_stream(e, pipe.chunked(x, 250))
        d, _ = ing.ingest_stream(e, pipe.chunked(x, 250), donate=True)
        assert bool(jnp.array_equal(nd.qcos_acc, d.qcos_acc))
        assert bool(jnp.array_equal(nd.qsin_acc, d.qsin_acc))

    def test_engine_sketch_stream_async_flag(self):
        x, w = _blobs(npts=800)
        e = eng_mod.SketchEngine(w, "xla")
        z_s = e.sketch_stream(pipe.chunked(x, 150))
        z_a = e.sketch_stream(pipe.chunked(x, 150), async_ingest=True)
        for zs, za in zip(z_s, z_a):
            assert bool(jnp.array_equal(zs, za))


class TestAsyncFitStreaming:
    def test_async_equals_sync_fit_streaming(self):
        """Acceptance: same key, same stream -> identical CKMResult arrays."""
        x, _ = _blobs(npts=3000, n=2, seed=7)
        cfg = ckm_mod.CKMConfig(
            k=3, m=60, sigma2=1.0,
            atom_steps=25, joint_steps=15, nnls_iters=25, final_steps=30,
        )
        key = jax.random.PRNGKey(2)
        res_sync = ckm_mod.fit_streaming(key, pipe.chunked(x, 500), cfg)
        import dataclasses

        acfg = dataclasses.replace(cfg, ingest="async", ingest_prefetch=3)
        res_async = ckm_mod.fit_streaming(key, pipe.chunked(x, 500), acfg)
        assert bool(jnp.array_equal(res_sync.sketch, res_async.sketch))
        assert bool(
            jnp.array_equal(res_sync.centroids, res_async.centroids)
        )
        assert bool(jnp.array_equal(res_sync.weights, res_async.weights))
        for a, b in zip(res_sync.bounds, res_async.bounds):
            assert bool(jnp.array_equal(a, b))

    def test_bad_ingest_mode_rejected(self):
        x, _ = _blobs(npts=100)
        cfg = ckm_mod.CKMConfig(k=2, ingest="psychic")
        with pytest.raises(ValueError, match="ingest"):
            ckm_mod.fit_streaming(
                jax.random.PRNGKey(0), pipe.chunked(x, 50), cfg
            )
