"""Docs-check: execute every fenced python block in the docs site.

``docs/api.md`` promises its snippets are runnable; this test makes that a
CI invariant so the docs can't rot.  Blocks within one file run top-to-bottom
in a single shared namespace (later snippets may use names defined earlier),
mirroring a reader following the page.  Registered via the ``docs`` marker in
pytest.ini — run just this check with::

    PYTHONPATH=src python -m pytest -q -m docs
"""

import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = [
    ROOT / "docs" / "api.md",
    ROOT / "docs" / "scaling.md",
    ROOT / "docs" / "observability.md",
    ROOT / "README.md",
]
_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _blocks(path: Path) -> list[str]:
    return [m.group(1) for m in _FENCE.finditer(path.read_text())]


@pytest.mark.docs
@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_snippets_execute(path):
    blocks = _blocks(path)
    assert blocks, f"no ```python blocks found in {path}"
    ns: dict = {"__name__": f"docscheck_{path.stem}"}
    for i, src in enumerate(blocks):
        code = compile(src, f"{path.name}[block {i}]", "exec")
        exec(code, ns)  # noqa: S102 — executing our own documentation
