"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 real CPU
device; multi-device tests spawn subprocesses with their own flags."""

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def gaussian_blobs():
    """Well-separated mixture for recovery tests: K=5 unit blobs in R^4."""
    from repro.data import synthetic

    key = jax.random.PRNGKey(42)
    x, labels, means = synthetic.gaussian_mixture(
        key, 8000, k=5, n=4, c=6.0, return_labels=True
    )
    return x, labels, means
