"""Frequency-operator subsystem tests (marker: freq_ops).

The contract (``core/freq_ops``): operators are registered by name, expose
``apply``/``adjoint``/``materialize``/``col_norms``/``spec``, and thread
end-to-end (sketch -> engine backends -> quantization -> decoders).  The
acceptance pins:

- ``freq_op="dense"`` through the registry is **bitwise identical** to the
  pre-refactor dense-matrix path on all three backends (the xla replica here
  is a verbatim copy of the pre-refactor chunked-scan math);
- the structured fast transform agrees with its dense materialisation, its
  adjoint is the true transpose, and its column norms follow the drawn
  adapted radii exactly (the radial-rescaling property);
- ``spec()`` rebuilds operators exactly and is O(1) bytes;
- the raw ``(n, m)`` convenience wrap still works on the sketch/engine entry
  points, while the decoder helpers and kernel wrappers raise ``TypeError``
  (their deprecation window closed in PR 6);
- ``draw_frequencies`` takes a ``dtype`` and the radius inverse-CDF sampler
  agrees between f32 and f64 on identical uniforms;
- ``estimate_sigma2`` recovers the within-cluster scale within 2x on
  synthetic Gaussian blobs across seeds.
"""

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ckm as ckm_mod
from repro.core import engine as eng_mod
from repro.core import freq_ops as fo
from repro.core import frequencies as fq
from repro.core import quantize as qz
from repro.core import sketch as sk
from repro.core.decoders import common as dec_common
from repro.kernels import ref

pytestmark = pytest.mark.freq_ops


@functools.partial(jax.jit, static_argnames=("chunk",))
def _pre_refactor_sketch(x, w, chunk=8192):
    """Verbatim copy of the pre-refactor ``core.sketch.sketch`` math
    (uniform weights): the bitwise oracle for the dense registry path."""
    x = jnp.asarray(x, jnp.float32)
    n_pts = x.shape[0]
    m = w.shape[1]
    weights = jnp.full((n_pts,), 1.0 / n_pts, jnp.float32)
    pad = (-n_pts) % chunk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
        weights = jnp.concatenate([weights, jnp.zeros((pad,), weights.dtype)], axis=0)
    n_chunks = x.shape[0] // chunk
    xs = x.reshape(n_chunks, chunk, -1)
    ws_ = weights.reshape(n_chunks, chunk)

    def body(acc, inp):
        xc, bc = inp
        proj = xc @ w
        return (acc[0] + bc @ jnp.cos(proj), acc[1] + bc @ jnp.sin(proj)), None

    acc0 = jnp.zeros((m,), jnp.float32)
    (cos_acc, sin_acc), _ = jax.lax.scan(body, (acc0, acc0), (xs, ws_))
    return jnp.concatenate([cos_acc, -sin_acc])


def _ops(n=6, m=80, sigma2=1.3, seed=5):
    key = jax.random.PRNGKey(seed)
    return {
        name: fo.make_operator(name, key, m, n, sigma2)
        for name in fo.available_freq_ops()
    }


class TestRegistry:
    def test_builtins_registered(self):
        assert set(fo.available_freq_ops()) >= {"dense", "structured"}

    def test_unknown_name_raises_with_names(self):
        with pytest.raises(KeyError, match="dense"):
            fo.get_freq_op("fourier9000")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            fo.register_freq_op("dense")(lambda *a, **k: None)

    def test_custom_operator_threads_through_config(self):
        """A user-registered family is selectable via CKMConfig.freq_op."""
        name = "test_scaled_dense"
        fo.FREQ_OPS.pop(name, None)

        @fo.register_freq_op(name)
        def build(key, m, n, sigma2, *, dist="adapted_radius", dtype=jnp.float32):
            base = fo.make_operator("dense", key, m, n, sigma2, dist=dist,
                                    dtype=dtype)
            return fo.DenseOperator(0.5 * base.w)

        try:
            x = jax.random.normal(jax.random.PRNGKey(0), (256, 3))
            cfg = ckm_mod.CKMConfig(
                k=2, m=24, sigma2=1.0, freq_op=name,
                atom_steps=5, joint_steps=5, nnls_iters=5, final_steps=5,
            )
            res = ckm_mod.fit(jax.random.PRNGKey(1), x, cfg)
            assert res.centroids.shape == (2, 3)
            assert isinstance(res.freq_op, fo.DenseOperator)
        finally:
            fo.FREQ_OPS.pop(name)


class TestDenseBitwiseIdentity:
    """Acceptance: the registry dense path == the pre-refactor dense path,
    bit for bit, on every backend."""

    def test_xla_sketch_bitwise(self):
        key = jax.random.PRNGKey(3)
        kx, kf = jax.random.split(key)
        x = jax.random.normal(kx, (1003, 6)) * 2.0
        sigma2 = jnp.asarray(1.7, jnp.float32)
        w = fq.draw_frequencies(kf, 48, 6, sigma2)
        op = fo.make_operator("dense", kf, 48, 6, sigma2)
        # Same key -> the drawn matrix itself is bitwise identical...
        assert bool(jnp.array_equal(op.w, w))
        # ...and the chunked-scan sketch through the operator matches the
        # pre-refactor math exactly (same jaxpr: op.apply IS `x @ w`).
        z_op = sk.sketch(x, op, chunk=256)
        z_old = _pre_refactor_sketch(x, w, chunk=256)
        assert bool(jnp.array_equal(z_op, z_old))

    def test_engine_backends_bitwise_raw_vs_operator(self):
        """Raw-matrix engines (shim) and operator engines agree bitwise on
        xla and pallas; the sharded backend is covered in a subprocess."""
        key = jax.random.PRNGKey(4)
        kx, kf = jax.random.split(key)
        x = jax.random.normal(kx, (777, 5))
        op = fo.make_operator("dense", kf, 40, 5, 1.0)
        for backend, kw in (("xla", {}), ("pallas", dict(block_n=256, block_m=128))):
            z_raw, lo_r, hi_r = eng_mod.SketchEngine(op.w, backend, **kw).sketch(x)
            z_op, lo_o, hi_o = eng_mod.SketchEngine(op, backend, **kw).sketch(x)
            assert bool(jnp.array_equal(z_raw, z_op)), backend
            assert bool(jnp.array_equal(lo_r, lo_o) and jnp.array_equal(hi_r, hi_o))

    def test_sharded_backend_bitwise(self):
        """Sharded backend: operator-carried engine == raw-matrix engine,
        bitwise, in a forced-8-device subprocess."""
        import os
        import subprocess
        import sys
        import textwrap

        prog = textwrap.dedent(
            """
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, numpy as np, jax.numpy as jnp
            from repro.core import engine as eng_mod
            from repro.core import freq_ops as fo

            key = jax.random.PRNGKey(0)
            kx, kf = jax.random.split(key)
            x = jax.random.normal(kx, (4096, 6))
            op = fo.make_operator("dense", kf, 48, 6, 1.0)
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            z_raw, lo_r, hi_r = eng_mod.SketchEngine(
                op.w, "sharded", mesh=mesh, chunk=512).sketch(x)
            z_op, lo_o, hi_o = eng_mod.SketchEngine(
                op, "sharded", mesh=mesh, chunk=512).sketch(x)
            assert bool(jnp.array_equal(z_raw, z_op))
            assert bool(jnp.array_equal(lo_r, lo_o))
            # The structured family runs through the same sharded machinery
            # (the operator pytree rides shard_map replicated).
            s_op = fo.make_operator("structured", kf, 48, 6, 1.0)
            z_sh, _, _ = eng_mod.SketchEngine(
                s_op, "sharded", mesh=mesh, chunk=512).sketch(x)
            z_x, _, _ = eng_mod.SketchEngine(s_op, "xla", chunk=512).sketch(x)
            err = float(np.max(np.abs(np.asarray(z_sh) - np.asarray(z_x))))
            assert err < 1e-4, err
            print("OK")
            """
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-c", prog], env=env, capture_output=True,
            text=True, timeout=300,
        )
        assert out.returncode == 0, out.stderr[-3000:]
        assert "OK" in out.stdout


class TestStructuredAlgebra:
    @pytest.mark.parametrize("n,m", [(6, 80), (16, 16), (5, 7), (33, 100)])
    def test_apply_matches_materialize(self, n, m):
        op = _ops(n=n, m=m)["structured"]
        x = jax.random.normal(jax.random.PRNGKey(1), (17, n))
        W = op.materialize()
        assert W.shape == (n, m)
        np.testing.assert_allclose(
            np.asarray(op.apply(x)), np.asarray(x @ W), atol=1e-4
        )

    def test_apply_matches_explicit_hadamard_oracle(self):
        """Independent oracle: explicit Sylvester-Hadamard matmuls (ref.py)."""
        op = _ops(n=24, m=100)["structured"]
        x = jax.random.normal(jax.random.PRNGKey(2), (31, 24))
        want = ref.structured_project_ref(x, op.diags, op.radii)[:, : op.m]
        np.testing.assert_allclose(
            np.asarray(op.apply(x)), np.asarray(want), rtol=1e-4, atol=1e-4
        )

    def test_adjoint_is_transpose(self):
        op = _ops()["structured"]
        x = jax.random.normal(jax.random.PRNGKey(3), (9, op.n))
        v = jax.random.normal(jax.random.PRNGKey(4), (9, op.m))
        W = np.asarray(op.materialize())
        np.testing.assert_allclose(
            np.asarray(op.adjoint(v)), np.asarray(v) @ W.T, atol=1e-4
        )
        # <apply(x), v> == <x, adjoint(v)> — the defining identity.
        lhs = float(jnp.sum(op.apply(x) * v))
        rhs = float(jnp.sum(x * op.adjoint(v)))
        assert abs(lhs - rhs) < 1e-2 * max(1.0, abs(lhs))

    def test_radial_rescaling_exact(self):
        """||omega_j|| equals the drawn adapted radius exactly — the
        "adapted-radius radial rescaling" of the tentpole."""
        op = _ops(n=10, m=64)["structured"]
        W = np.asarray(op.materialize())
        np.testing.assert_allclose(
            np.linalg.norm(W, axis=0), np.asarray(op.col_norms()), rtol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(op.col_norms()), np.asarray(op.rho.reshape(-1)[: op.m])
        )

    def test_atom_norm_preserved(self):
        """|A delta_c| has unit modulus per frequency for ANY operator —
        CLOMPR's sqrt(m) normalisation stays valid."""
        for name, op in _ops(n=7, m=33).items():
            cs = jax.random.normal(jax.random.PRNGKey(5), (4, 7)) * 3.0
            a = sk.atoms(cs, op)
            np.testing.assert_allclose(
                np.linalg.norm(np.asarray(a), axis=1),
                np.full(4, np.sqrt(33.0)),
                rtol=1e-5,
                err_msg=name,
            )

    def test_grad_flows_through_apply(self):
        """Decoders autodiff through the fast transform."""
        op = _ops()["structured"]

        def f(c):
            return jnp.sum(jnp.cos(op.apply(c)))

        g = jax.grad(f)(jnp.ones((op.n,)))
        assert g.shape == (op.n,) and bool(jnp.all(jnp.isfinite(g)))


class TestSpec:
    @pytest.mark.parametrize("name", ["dense", "structured"])
    def test_roundtrip_exact(self, name):
        op = _ops()[name]
        spec = op.spec()
        op2 = fo.from_spec(spec)
        for a, b in zip(jax.tree.leaves(op), jax.tree.leaves(op2)):
            assert bool(jnp.array_equal(a, b))
        assert op2.spec() == spec

    @pytest.mark.parametrize("name", ["dense", "structured"])
    def test_spec_is_o1_bytes(self, name):
        op = _ops(n=64, m=512)[name]
        spec_bytes = fo.spec_wire_bytes(op.spec())
        matrix_bytes = 4 * 64 * 512
        assert spec_bytes < 128
        assert spec_bytes < 0.01 * matrix_bytes

    def test_structured_state_is_o_m(self):
        """The operator's leaves are O(m) — what a by-value carry would ship
        — vs the O(n·m) dense matrix."""
        n, m = 256, 2048
        ops = _ops(n=n, m=m)
        assert ops["structured"].state_bytes() < 0.1 * ops["dense"].state_bytes()

    def test_raw_matrix_has_no_spec(self):
        w = jnp.ones((3, 8))
        with pytest.raises(ValueError, match="no spec"):
            fo.as_operator(w).spec()

    def test_engine_exposes_spec(self):
        op = _ops()["structured"]
        eng = eng_mod.SketchEngine(op, "xla")
        assert eng.spec() == op.spec()
        assert eng.w.shape == (op.n, op.m)  # back-compat materialisation


class TestDeprecationShim:
    def test_decoder_helpers_reject_raw_matrix(self):
        """Satellite (PR 6): the one-release raw-array window is closed —
        the decoder helpers now raise TypeError instead of warning."""
        op = _ops()["dense"]
        z = jnp.ones((2 * op.m,))
        cents = jnp.zeros((3, op.n))
        alpha = jnp.ones((3,)) / 3.0
        with pytest.raises(TypeError, match="as_operator"):
            dec_common.residual_cost(z, cents, alpha, op.w)
        with pytest.raises(TypeError, match="as_operator"):
            dec_common.resolution_radius(op.w, 2.5)
        # The explicit wrap is the supported path and matches the operator.
        raw = dec_common.residual_cost(z, cents, alpha, fo.as_operator(op.w))
        via_op = dec_common.residual_cost(z, cents, alpha, op)
        assert bool(jnp.array_equal(raw, via_op))

    def test_kernel_wrappers_reject_raw_matrix(self):
        """kernels.ops closed the same window: raw w -> TypeError."""
        from repro.kernels import ops

        op = _ops()["dense"]
        x = jax.random.normal(jax.random.PRNGKey(1), (32, op.n))
        with pytest.raises(TypeError, match="as_operator"):
            ops.fourier_sketch(x, op.w, jnp.full((32,), 1.0 / 32))

    def test_sketch_and_engine_accept_raw_silently(self):
        """The convenience wrap: raw w keeps working here without noise."""
        op = _ops()["dense"]
        x = jax.random.normal(jax.random.PRNGKey(0), (64, op.n))
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            z = sk.sketch(x, op.w)
            eng_mod.SketchEngine(op.w, "xla").sketch(x)
        assert z.shape == (2 * op.m,)


class TestBackendParityStructured:
    def test_pallas_matches_xla(self):
        op = _ops(n=11, m=70)["structured"]
        x = jax.random.normal(jax.random.PRNGKey(6), (513, 11))
        z_x, lo_x, hi_x = eng_mod.SketchEngine(op, "xla").sketch(x)
        z_p, lo_p, hi_p = eng_mod.SketchEngine(op, "pallas", block_n=128).sketch(x)
        np.testing.assert_allclose(np.asarray(z_p), np.asarray(z_x), atol=1e-4)
        np.testing.assert_allclose(np.asarray(lo_p), np.asarray(lo_x), atol=1e-6)

    def test_quantized_pallas_bitwise_matches_xla(self):
        """Integer code sums are exact: the fused structured QCKM kernel must
        agree with the XLA chunked path bit for bit."""
        op = _ops(n=9, m=50)["structured"]
        x = jax.random.normal(jax.random.PRNGKey(7), (300, 9))
        for bits in (1, 4):
            q = qz.make_quantizer(jax.random.PRNGKey(8), op.m, f"{bits}bit")
            e_x = eng_mod.SketchEngine(op, "xla", quantizer=q)
            e_p = eng_mod.SketchEngine(op, "pallas", block_n=64, quantizer=q)
            s_x = e_x.update(e_x.init_state(), x)
            s_p = e_p.update(e_p.init_state(), x)
            assert bool(jnp.array_equal(s_x.qcos_acc, s_p.qcos_acc)), bits
            assert bool(jnp.array_equal(s_x.qsin_acc, s_p.qsin_acc)), bits


class TestDtypeSatellite:
    def test_draw_frequencies_dtype(self):
        w32 = fq.draw_frequencies(jax.random.PRNGKey(0), 16, 4, 1.0)
        assert w32.dtype == jnp.float32
        with jax.experimental.enable_x64():
            w64 = fq.draw_frequencies(
                jax.random.PRNGKey(0), 16, 4, 1.0, dtype=jnp.float64
            )
            assert w64.dtype == jnp.float64

    def test_radius_inverse_cdf_f32_f64_agree(self):
        """On identical uniforms, the f32 and f64 grid samplers agree to f32
        resolution — the CDF accumulation is not precision-fragile."""
        u = np.linspace(0.005, 0.995, 199)
        for sigma2 in (0.25, 1.0, 9.0):
            r32 = np.asarray(fq.radius_from_uniform(u, sigma2, jnp.float32))
            with jax.experimental.enable_x64():
                r64 = np.asarray(
                    fq.radius_from_uniform(u, sigma2, jnp.float64)
                )
            np.testing.assert_allclose(r32, r64, rtol=2e-4, atol=1e-6)

    def test_ckm_config_propagates_dtype(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (256, 3))
        cfg = ckm_mod.CKMConfig(k=2, m=24, sigma2=1.0, freq_dtype="float32")
        z, op, _, _ = ckm_mod.compute_sketch(jax.random.PRNGKey(1), x, cfg)
        assert op.materialize().dtype == jnp.float32
        assert op.spec().dtype == "float32"

    @pytest.mark.parametrize("freq_op", ["dense", "structured"])
    def test_f64_operator_fits_end_to_end(self, freq_op):
        """An f64 operator projects in f64 but the sketch/decoder pipeline
        keeps its f32 accumulator contract — the advertised
        ``freq_dtype="float64"`` path must actually fit."""
        with jax.experimental.enable_x64():
            x = jax.random.normal(jax.random.PRNGKey(0), (256, 3), jnp.float32)
            cfg = ckm_mod.CKMConfig(
                k=2, m=24, sigma2=1.0, freq_op=freq_op, freq_dtype="float64",
                atom_steps=5, joint_steps=5, nnls_iters=5, final_steps=5,
            )
            res = ckm_mod.fit(jax.random.PRNGKey(1), x, cfg)
            assert res.freq_op.materialize().dtype == jnp.float64
            assert res.sketch.dtype == jnp.float32
            assert np.all(np.isfinite(np.asarray(res.centroids)))


class TestSigma2Estimation:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_recovers_scale_within_2x(self, seed):
        """Satellite: the small-sketch regression heuristic lands within 2x
        of the true within-cluster sigma^2 on Gaussian blobs (k=3, n=4,
        separation c=6), across seeds and cluster scales."""
        from repro.data import synthetic

        x, _, _ = synthetic.gaussian_mixture(
            jax.random.PRNGKey(seed), 4000, k=3, n=4, c=6.0, return_labels=True
        )
        for scale in (0.5, 2.0):
            true_s2 = scale * scale  # unit clusters scaled by `scale`
            est = float(
                fq.estimate_sigma2(jax.random.PRNGKey(seed + 100), x * scale)
            )
            assert 0.5 * true_s2 <= est <= 2.0 * true_s2, (seed, scale, est)


@pytest.mark.slow
class TestStructuredEndToEnd:
    def test_structured_fit_recovers_blobs(self, gaussian_blobs):
        """The structured family localises every true mean like dense fit."""
        x, _, means = gaussian_blobs
        cfg = ckm_mod.CKMConfig(k=5, freq_op="structured")
        res = ckm_mod.fit(jax.random.PRNGKey(0), x, cfg)
        assert isinstance(res.freq_op, fo.StructuredOperator)
        d = np.linalg.norm(
            np.asarray(means)[:, None] - np.asarray(res.centroids)[None], axis=-1
        ).copy()
        errs = []
        for _ in range(means.shape[0]):
            i, j = np.unravel_index(np.argmin(d), d.shape)
            errs.append(d[i, j])
            d[i, :] = np.inf
            d[:, j] = np.inf
        assert np.all(np.array(errs) < 1.0), errs

    def test_structured_quantized_streaming(self, gaussian_blobs):
        """Composes with QCKM + fit_streaming (one-pass, both decoders)."""
        from repro.data import pipeline as pipe

        x, _, _ = gaussian_blobs
        cfg = ckm_mod.CKMConfig(
            k=5, freq_op="structured", sketch_quantization="1bit",
            decoder="sketch_shift", shift_steps=40, shift_polish_steps=150,
            nnls_iters=60,
        )
        res = ckm_mod.fit_streaming(
            jax.random.PRNGKey(2), pipe.chunked(x, 1000), cfg
        )
        sse_rel = float(ckm_mod.sse(x, res.centroids)) / x.shape[0]
        assert np.isfinite(sse_rel)
        # Well below the dataset variance — the decode genuinely worked.
        assert sse_rel < 2.0 * 4.0  # n=4 unit-variance clusters
