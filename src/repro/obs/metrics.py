"""Metrics registry: counters, gauges, and histograms for the sketch fleet.

Design constraints (the hot-path contract of ``docs/observability.md``):

- **Disabled = free.**  Call sites guard on ``runtime.ENABLED`` before
  touching the registry, so a disabled process never pays a dict lookup —
  only one module-attribute read per instrumented call.
- **Enabled = no churn.**  ``counter()/gauge()/histogram()`` are
  get-or-create: the first call for a ``(name, labels)`` pair allocates the
  instrument, every later call is a dict hit returning the *same* object.
  Hot paths that fire per batch (``SketchEngine.update``) resolve their
  handles once and cache them on the owning object, so the steady state is
  a plain ``float +=``.
- **Labels are identity.**  ``counter("engine.update.rows", backend="xla")``
  and ``backend="pallas"`` are two instruments; ``snapshot()`` keys them as
  ``name{k=v,...}``.

Instruments are plain Python accumulators (no JAX arrays): telemetry must
never put anything on a device or into a trace.  Increments from the ingest
producer thread interleave with the consumer's under the GIL; get-or-create
is lock-protected so two threads cannot race a first-touch registration.
"""

from __future__ import annotations

import math
import threading
from typing import Iterator

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
    "snapshot",
    "reset",
]


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _format_key(name: str, label_key: tuple) -> str:
    if not label_key:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in label_key) + "}"


class Counter:
    """Monotone accumulator (rows folded, cache hits, seconds stalled)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    """Last-write-wins instantaneous value (overlap fraction, drift score)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Streaming distribution summary: count/sum/min/max + log2 buckets.

    ``observe(v)`` is O(1) and allocation-free after the first touch of a
    bucket: values land in power-of-two buckets (index ``ceil(log2 v)``),
    enough resolution for latency work without reservoir bookkeeping.
    """

    __slots__ = ("name", "labels", "count", "total", "min", "max", "buckets")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.buckets: dict[int, int] = {}

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        idx = math.frexp(value)[1] if value > 0.0 else -1074
        self.buckets[idx] = self.buckets.get(idx, 0) + 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Process-wide instrument store; one lives at ``metrics.REGISTRY``."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[tuple, object] = {}
        # Bumped by reset(): hot-path callers that cache instrument handles
        # (e.g. SketchEngine) compare generations to drop stale handles.
        self.generation = 0

    def _get(self, cls, name: str, labels: dict):
        lk = _label_key(labels)
        key = (cls, name, lk)
        inst = self._instruments.get(key)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(key)
                if inst is None:
                    inst = cls(name, lk)
                    self._instruments[key] = inst
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def __iter__(self) -> Iterator:
        return iter(list(self._instruments.values()))

    def __len__(self) -> int:
        return len(self._instruments)

    def snapshot(self) -> dict:
        """``{"name{labels}": value-or-summary}`` for export/assertions."""
        out: dict = {}
        for inst in self:
            key = _format_key(inst.name, inst.labels)
            if isinstance(inst, Histogram):
                out[key] = {
                    "count": inst.count,
                    "sum": inst.total,
                    "min": inst.min if inst.count else None,
                    "max": inst.max if inst.count else None,
                    "mean": inst.mean,
                }
            else:
                out[key] = inst.value
        return out

    def reset(self) -> None:
        """Drop every instrument (tests; between benchmark trials)."""
        with self._lock:
            self._instruments.clear()
            self.generation += 1


REGISTRY = MetricsRegistry()


def counter(name: str, **labels) -> Counter:
    """Get-or-create a counter on the default registry."""
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    """Get-or-create a gauge on the default registry."""
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    """Get-or-create a histogram on the default registry."""
    return REGISTRY.histogram(name, **labels)


def snapshot() -> dict:
    """Snapshot of the default registry."""
    return REGISTRY.snapshot()


def reset() -> None:
    """Reset the default registry."""
    REGISTRY.reset()
