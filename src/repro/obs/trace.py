"""Span tracer with JSONL export and ``jax.profiler`` pass-through.

Three event kinds, all host-side Python (never traced inside ``jit``):

- **spans** — ``with trace.span("engine.update", backend="xla"):`` records a
  ``(name, t0, duration, depth, attrs)`` event around a region of dispatch
  code, and enters a ``jax.profiler.TraceAnnotation`` of the same name so the
  region shows up in TensorBoard/perfetto profiles when a profiler trace is
  active (a TraceAnnotation is a cheap no-op otherwise);
- **series** — a named list of floats, e.g. a decoder's per-round residual
  norms.  The values are computed *inside* the jitted decoder as ordinary
  array outputs (O(iterations) scalars, dead-code-eliminated when tracing is
  off) and handed to the tracer after the call — nothing is ever traced into
  the XLA graph;
- **points** — one-off ``(name, value, attrs)`` observations.

Like the metrics registry, the tracer is only touched behind a
``runtime.ENABLED`` guard; ``span()`` double-checks so un-guarded callers
stay correct, just not free.  Export is JSON Lines: one self-describing
object per event (``kind``/``name``/``attrs`` plus kind-specific fields),
parseable with nothing but ``json.loads`` per line.
"""

from __future__ import annotations

import contextlib
import json
import time
from pathlib import Path

from repro.obs import runtime

__all__ = ["Tracer", "TRACER", "span", "series", "point", "export_jsonl"]


class Tracer:
    """Append-only event log; one process-wide instance at ``trace.TRACER``."""

    def __init__(self):
        self.events: list[dict] = []
        self._depth = 0

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Record a wall-clock span around a block of dispatch-layer code.

        JAX dispatch is asynchronous, so a span around an un-synchronised
        call measures dispatch, not device compute; paths that block per
        batch (``fit_streaming``, ``ingest_stream``) give true durations.
        """
        if not runtime.ENABLED:
            yield
            return
        import jax

        depth = self._depth
        self._depth += 1
        t0 = time.perf_counter()
        try:
            with jax.profiler.TraceAnnotation(name):
                yield
        finally:
            self._depth = depth
            self.events.append(
                {
                    "kind": "span",
                    "name": name,
                    "t0": t0,
                    "dur_s": time.perf_counter() - t0,
                    "depth": depth,
                    "attrs": attrs,
                }
            )

    def series(self, name: str, values, **attrs) -> None:
        """Record a convergence/trajectory series (list of floats)."""
        if not runtime.ENABLED:
            return
        self.events.append(
            {
                "kind": "series",
                "name": name,
                "values": [float(v) for v in values],
                "attrs": attrs,
            }
        )

    def point(self, name: str, value: float, **attrs) -> None:
        """Record a single observation."""
        if not runtime.ENABLED:
            return
        self.events.append(
            {
                "kind": "point",
                "name": name,
                "value": float(value),
                "attrs": attrs,
            }
        )

    def spans(self, name: str | None = None) -> list[dict]:
        """Completed span events, optionally filtered by name."""
        return [
            e
            for e in self.events
            if e["kind"] == "span" and (name is None or e["name"] == name)
        ]

    def jsonl_lines(self, metrics_snapshot: dict | None = None) -> list[str]:
        """Every event (plus an optional metrics snapshot) as JSONL lines."""
        lines = [json.dumps(e) for e in self.events]
        if metrics_snapshot is not None:
            for key, value in sorted(metrics_snapshot.items()):
                lines.append(
                    json.dumps({"kind": "metric", "name": key, "value": value})
                )
        return lines

    def export_jsonl(
        self, path, *, metrics_snapshot: dict | None = None
    ) -> Path:
        """Write the event log (and optional metrics) to a ``.jsonl`` file."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(
            "\n".join(self.jsonl_lines(metrics_snapshot)) + "\n"
        )
        return path

    def reset(self) -> None:
        self.events.clear()
        self._depth = 0


TRACER = Tracer()


@contextlib.contextmanager
def span(name: str, **attrs):
    """``with obs.span("name", k=v):`` on the default tracer."""
    with TRACER.span(name, **attrs):
        yield


def series(name: str, values, **attrs) -> None:
    """Record a series on the default tracer."""
    TRACER.series(name, values, **attrs)


def point(name: str, value: float, **attrs) -> None:
    """Record a point observation on the default tracer."""
    TRACER.point(name, value, **attrs)


def export_jsonl(path, *, with_metrics: bool = True) -> Path:
    """Export the default tracer (and, by default, the metrics snapshot)."""
    snap = None
    if with_metrics:
        from repro.obs import metrics as _metrics

        snap = _metrics.snapshot()
    return TRACER.export_jsonl(path, metrics_snapshot=snap)
