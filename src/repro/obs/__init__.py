"""``repro.obs`` — telemetry + sketch-health diagnostics for the CKM stack.

Three layers, documented (with runnable snippets) in ``docs/observability.md``:

- :mod:`repro.obs.runtime` — the master switch.  Everything below is inert
  until :func:`enable` flips the module-level ``runtime.ENABLED`` bool; the
  disabled hot path costs one attribute read + branch (pinned <= 2% on the
  engine-update microbenchmark by the ``obs_overhead`` kernels row).
- :mod:`repro.obs.metrics` / :mod:`repro.obs.trace` — a get-or-create
  instrument registry (counters / gauges / histograms) and a span tracer
  with JSONL export + ``jax.profiler.TraceAnnotation`` pass-through.  The
  instrumented call sites live in ``core/engine.py`` (update/merge/finalize),
  ``core/ingest.py`` (overlap accounting), ``serve/fleet_service.py``
  (flush latency, decode-cache traffic) and the decoders (convergence
  series).
- :mod:`repro.obs.diagnose` — ``ckm.diagnose(result)``: attribute a bad fit
  to sketch size m, frequency scale sigma, or the decoder; plus the O(m)
  :func:`sketch_drift` score emitted as a gauge by ``FleetService.drift``
  and ``ActivationMonitor.sketch_drift``.
"""

from __future__ import annotations

from repro.obs import metrics, runtime, trace
from repro.obs.diagnose import (
    Diagnosis,
    diagnose,
    matched_distance,
    model_sketch,
    sigma_sweep,
    sketch_drift,
)
from repro.obs.metrics import (
    REGISTRY,
    MetricsRegistry,
    counter,
    gauge,
    histogram,
    snapshot,
)
from repro.obs.runtime import disable, enable, enabled, enabled_scope
from repro.obs.trace import TRACER, Tracer, export_jsonl, point, series, span

__all__ = [
    # switch
    "enable",
    "disable",
    "enabled",
    "enabled_scope",
    # metrics
    "MetricsRegistry",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
    "snapshot",
    # tracing
    "Tracer",
    "TRACER",
    "span",
    "series",
    "point",
    "export_jsonl",
    # diagnostics
    "Diagnosis",
    "diagnose",
    "sketch_drift",
    "model_sketch",
    "matched_distance",
    "sigma_sweep",
    # submodules
    "metrics",
    "runtime",
    "trace",
    "reset",
]


def reset() -> None:
    """Reset the default metrics registry *and* the default tracer.

    One call returns the process to a clean-slate telemetry state (the
    switch position is left alone) — tests and benchmark trials use this
    between runs.
    """
    metrics.reset()
    trace.TRACER.reset()
