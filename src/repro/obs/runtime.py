"""The telemetry master switch — one module-level bool, read on every hot path.

Every instrumented call site guards with ``if runtime.ENABLED:`` *before*
touching any telemetry object, so the disabled path costs exactly one module
attribute read and a branch (the ``obs_overhead`` row in
``experiments/paper/kernels.json`` pins the disabled-path regression at
<= 2% on the engine-update microbenchmark).  Nothing here is ever traced
inside ``jit`` — instrumentation happens at the Python dispatch layer, and
convergence traces are computed *as array outputs* of the jitted decoders
and emitted host-side (see ``docs/observability.md``).

Call sites must read the flag as an attribute (``runtime.ENABLED``), never
``from ... import ENABLED`` — a from-import snapshots the value at import
time and would never see :func:`enable`.
"""

from __future__ import annotations

import contextlib

__all__ = ["ENABLED", "enable", "disable", "enabled", "enabled_scope"]

ENABLED: bool = False


def enable() -> None:
    """Turn telemetry on process-wide (metrics + tracer + profiler spans)."""
    global ENABLED
    ENABLED = True


def disable() -> None:
    """Turn telemetry off; instrumented paths fall back to the bare hot path."""
    global ENABLED
    ENABLED = False


def enabled() -> bool:
    """The current switch state (prefer attribute reads on hot paths)."""
    return ENABLED


@contextlib.contextmanager
def enabled_scope(on: bool = True):
    """Scoped enable/disable — restores the previous state on exit."""
    global ENABLED
    prev = ENABLED
    ENABLED = on
    try:
        yield
    finally:
        ENABLED = prev
