"""Sketch-health diagnostics: blame the sketch, the scale, or the decoder.

"When compressive learning fails" (Schellekens & Jacques, 2020) observes
that a bad compressive fit has exactly three root causes, and that they are
distinguishable *from the sketch alone*:

- **sketch size m too small** — the inverse problem is under-determined:
  the decoder reaches a *small* sketch residual yet the solution is not
  identifiable.  Signature: probe decodes from disjoint frequency subsets
  of the same sketch land on wildly different centroid sets.
- **frequency scale mis-set** — the sketch samples the characteristic
  function where it carries no information.  Signature: the CF moduli
  ``|psi(w_j)|`` are ~1 across frequencies (sigma^2 over-estimated: all
  frequencies inside the central lobe) or at the empirical noise floor
  (sigma^2 under-estimated: all frequencies past the decay).  O(m) to test.
- **decoder failure** — the sketch is informative but the decode did not
  converge.  Signature: a cheap, well-converged probe decode
  (``sketch_shift`` — the fast decoder the fleet's hot path already uses)
  reaches a materially lower sketch residual than the result's.

:func:`diagnose` runs those three probes on a ``ckm.CKMResult`` (data-free;
pass ``sample=`` to add a true re-sketching sigma sweep) and returns a
:class:`Diagnosis` with a single ``verdict`` plus the scores behind it.

The same CF-fingerprint view gives the **drift score**: the distance between
a live window's sketch and the decoded model's re-sketched centroids
(:func:`sketch_drift`) is an O(m) health number every service tier can emit
as a gauge — ``FleetService.drift`` and ``ActivationMonitor.sketch_drift``
wire it in.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Diagnosis",
    "diagnose",
    "model_sketch",
    "sketch_drift",
    "matched_distance",
    "sigma_sweep",
]

VERDICTS = ("ok", "sketch_size", "frequency_scale", "decoder")


@dataclasses.dataclass
class Diagnosis:
    """Outcome of :func:`diagnose` — one verdict, with its evidence.

    ``verdict`` is one of ``VERDICTS``; ``scores`` holds the scalar evidence
    (residuals, CF moduli, subset disagreement); ``details`` the per-probe
    sweep tables; ``recommendation`` a one-line operator hint.
    """

    verdict: str
    scores: dict
    details: dict
    recommendation: str

    @property
    def ok(self) -> bool:
        return self.verdict == "ok"


def model_sketch(centroids, weights, w) -> jax.Array:
    """Re-sketch a decoded model: ``sum_k alpha_k A delta_{c_k}`` (2m,)."""
    from repro.core import freq_ops as fo
    from repro.core import sketch as sk

    op = fo.as_operator(w)
    return jnp.asarray(weights, jnp.float32) @ sk.atoms(
        jnp.asarray(centroids, jnp.float32), op
    )


def sketch_drift(z_live, centroids, weights, w) -> float:
    """O(m) drift score: relative CF distance between a live window's sketch
    and the decoded model's re-sketched centroids.

    Both the live sketch and the model sketch are normalised characteristic
    functions, so ``||z_live - z_model|| / ||z_live||`` is scale-free: ~0 on
    a stationary stream (up to decode residual + O(1/sqrt N) sampling
    noise), O(1) once the stream moves away from the decoded model.

    An all-zero live sketch — what an empty or fully-decayed state finalizes
    to (the engine's ``weight_sum -> 0`` guard) — scores a defined 0.0, not
    the 0/0 the raw ratio would produce: with no live evidence there is
    nothing to drift from.
    """
    z_live = jnp.asarray(z_live, jnp.float32)
    z_model = model_sketch(centroids, weights, w)
    num = jnp.linalg.norm(z_live - z_model)
    den = jnp.linalg.norm(z_live)
    return float(jnp.where(den > 0, num / jnp.maximum(den, 1e-12), 0.0))


def matched_distance(a, b, weights_a=None) -> float:
    """Greedy-matched mean displacement between two centroid sets.

    Same matching rule as ``ActivationMonitor.drift``: repeatedly pair the
    globally closest remaining (a_i, b_j), optionally weighting each pair by
    ``weights_a[i]`` (uniform when omitted).
    """
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    wa = (
        np.full((a.shape[0],), 1.0 / a.shape[0])
        if weights_a is None
        else np.asarray(weights_a, np.float64)
    )
    d = np.linalg.norm(a[:, None] - b[None], axis=-1)
    moved, used = 0.0, d.copy()
    for _ in range(a.shape[0]):
        i, j = np.unravel_index(np.argmin(used), used.shape)
        moved += wa[i] * d[i, j]
        used[i, :] = np.inf
        used[:, j] = np.inf
    return float(moved / max(wa.sum(), 1e-9))


def _rel_residual(z, centroids, weights, w) -> float:
    r = jnp.asarray(z, jnp.float32) - model_sketch(centroids, weights, w)
    denom = jnp.maximum(jnp.linalg.norm(jnp.asarray(z)), 1e-12)
    return float(jnp.linalg.norm(r) / denom)


def _default_probe_config(k: int, probe_budget: float):
    from repro.core import ckm as ckm_mod

    s = max(probe_budget, 0.05)
    return ckm_mod.CKMConfig(
        k=k,
        decoder="sketch_shift",
        shift_steps=max(int(150 * s), 10),
        shift_polish_steps=max(int(400 * s), 20),
        nnls_iters=max(int(150 * s), 10),
    )


def _subsketch(z, w_mat, idx):
    """Restrict a stacked-real sketch + dense frequency matrix to a subset
    of frequencies — a *valid smaller sketch of the same data* (each entry
    samples the CF independently)."""
    m = w_mat.shape[1]
    z_sub = jnp.concatenate([z[:m][idx], z[m:][idx]])
    return z_sub, w_mat[:, idx]


def sigma_sweep(
    sample,
    result,
    *,
    key=None,
    factors=(0.1, 1.0, 10.0),
    m_probe: int | None = None,
) -> list[dict]:
    """Re-sketch ``sample`` at ``sigma2 = factor * result.sigma2`` and report
    each scale's CF-modulus health — the data-backed half of the m/sigma
    sweep harness (the data-free half runs inside :func:`diagnose`).

    Returns one row per factor: ``{factor, sigma2, mean_modulus, healthy}``,
    where healthy means the moduli land in the informative mid-band.
    """
    from repro.core import freq_ops as fo
    from repro.core import sketch as sk
    from repro.core.engine import SketchEngine

    key = key if key is not None else jax.random.PRNGKey(0)
    x = jnp.asarray(sample, jnp.float32)
    n = x.shape[1]
    m = int(m_probe) if m_probe is not None else int(result.freq_op.m)
    rows = []
    for i, factor in enumerate(factors):
        sigma2 = float(result.sigma2) * float(factor)
        op = fo.make_operator(
            "dense", jax.random.fold_in(key, i), m, n, jnp.asarray(sigma2)
        )
        z, _, _ = SketchEngine(op, "xla").sketch(x)
        mod = float(jnp.mean(jnp.abs(sk.to_complex(z))))
        rows.append(
            {
                "factor": float(factor),
                "sigma2": sigma2,
                "mean_modulus": mod,
                "healthy": bool(0.05 <= mod <= 0.9),
            }
        )
    return rows


def diagnose(
    result,
    *,
    key=None,
    probe=None,
    sample=None,
    probe_budget: float = 1.0,
    modulus_high: float = 0.9,
    modulus_low: float = 0.05,
    decoder_blame_ratio: float = 1.5,
    decoder_blame_margin: float = 0.05,
    disagreement_threshold: float = 0.1,
) -> Diagnosis:
    """Attribute a (possibly bad) compressive fit to m, sigma, or the decoder.

    Parameters
    ----------
    result : a ``ckm.CKMResult`` (``ckm.fit`` / ``fit_streaming`` output; the
        sketch, operator, bounds and decoded model it carries are all the
        evidence needed — no data access).
    key : PRNG key for the probe decodes (default ``PRNGKey(0)``).
    probe : optional ``CKMConfig`` for the probe decoder (default: a
        ``sketch_shift`` config scaled by ``probe_budget`` — the cheap
        decoder, run well-converged).
    sample : optional ``(N, n)`` data sample; adds the re-sketching
        :func:`sigma_sweep` rows to ``details``.
    probe_budget : scale on the default probe's iteration budgets.
    modulus_high / modulus_low : CF-modulus band outside which the frequency
        scale is declared mis-set (low is meaningful only while above the
        empirical noise floor ~``1/sqrt(2N)``; at the default 0.05 that
        means N >= ~1000).
    decoder_blame_ratio / decoder_blame_margin : the probe must beat the
        result's relative residual by both this factor and this absolute
        margin to blame the decoder.
    disagreement_threshold : box-normalised matched-centroid disagreement
        between disjoint half-sketch decodes above which m is blamed.

    Returns a :class:`Diagnosis`.  Verdict precedence: ``frequency_scale``
    (the sketch itself is uninformative — nothing downstream is meaningful),
    then ``decoder`` (the sketch supports a better fit than the one
    reported), then ``sketch_size`` (no decode from this few frequencies is
    identifiable), else ``ok``.
    """
    from repro.core import ckm as ckm_mod
    from repro.core import sketch as sk
    from repro.obs import metrics as obs_metrics
    from repro.obs import runtime as obs_rt
    from repro.obs import trace as obs_trace

    key = key if key is not None else jax.random.PRNGKey(0)
    z = jnp.asarray(result.sketch, jnp.float32)
    op = result.freq_op
    lo, hi = result.bounds
    k = int(result.centroids.shape[0])
    m = int(op.m)
    box_diag = float(
        jnp.maximum(jnp.linalg.norm(jnp.asarray(hi) - jnp.asarray(lo)), 1e-12)
    )
    if probe is None:
        probe = _default_probe_config(k, probe_budget)

    with obs_trace.span("ckm.diagnose", m=m, k=k):
        # -- 1. CF-modulus health: O(m), no decode needed. ------------------
        moduli = jnp.abs(sk.to_complex(z))
        mean_mod = float(jnp.mean(moduli))
        norms = op.col_norms()
        med = jnp.median(norms)
        low_band = float(jnp.mean(jnp.where(norms <= med, moduli, 0.0))) * 2.0
        high_band = float(jnp.mean(jnp.where(norms > med, moduli, 0.0))) * 2.0
        sigma_verdict = None
        if mean_mod > modulus_high:
            sigma_verdict = "sigma2_too_large"
        elif mean_mod < modulus_low:
            sigma_verdict = "sigma2_too_small"

        # -- 2. Decoder probe: can a converged cheap decode beat the result?
        rel_res = _rel_residual(z, result.centroids, result.weights, op)
        k_probe, k_sub = jax.random.split(key)
        p_cents, p_alpha, _ = ckm_mod.decode_sketch(k_probe, z, op, lo, hi, probe)
        rel_res_probe = _rel_residual(z, p_cents, p_alpha, op)
        decoder_blamed = (
            rel_res > rel_res_probe * decoder_blame_ratio
            and rel_res > rel_res_probe + decoder_blame_margin
        )

        # -- 3. m sweep: probe decodes from disjoint half-sketches. ---------
        # Each half is a valid m/2-sketch of the same data; if the two
        # halves' decodes disagree, no decode at this m is identifiable.
        w_mat = op.materialize()
        perm = jax.random.permutation(k_sub, m)
        half = max(m // 2, 1)
        halves = []
        for s in range(2):
            idx = perm[s * half : (s + 1) * half]
            z_s, w_s = _subsketch(z, w_mat, idx)
            c_s, a_s, _ = ckm_mod.decode_sketch(
                jax.random.fold_in(k_sub, s), z_s, w_s, lo, hi, probe
            )
            halves.append(
                {
                    "m": int(idx.shape[0]),
                    "centroids": np.asarray(c_s),
                    "rel_residual": _rel_residual(z_s, c_s, a_s, w_s),
                }
            )
        disagreement = matched_distance(
            halves[0]["centroids"], halves[1]["centroids"]
        ) / box_diag
        m_blamed = disagreement > disagreement_threshold

        details: dict = {
            "sigma_profile": {
                "mean_modulus": mean_mod,
                "low_band_modulus": low_band,
                "high_band_modulus": high_band,
                "direction": sigma_verdict,
            },
            "m_sweep": [
                {"m": h["m"], "rel_residual": h["rel_residual"]} for h in halves
            ],
        }
        if sample is not None:
            details["sigma_sweep"] = sigma_sweep(sample, result, key=key)

        scores = {
            "rel_residual": rel_res,
            "probe_rel_residual": rel_res_probe,
            "mean_modulus": mean_mod,
            "subsketch_disagreement": disagreement,
            "m_per_kn": m / max(k * int(op.n), 1),
        }

        if sigma_verdict is not None:
            verdict = "frequency_scale"
            recommendation = (
                "decrease sigma2 (frequencies sample the flat top of the "
                "characteristic function)"
                if sigma_verdict == "sigma2_too_large"
                else "increase sigma2 (frequencies sample past the CF decay "
                "— the sketch is at the noise floor)"
            )
        elif decoder_blamed:
            verdict = "decoder"
            recommendation = (
                "re-decode with a larger iteration budget or another "
                f"registered decoder (probe reached {rel_res_probe:.3f} "
                f"relative residual vs the result's {rel_res:.3f})"
            )
        elif m_blamed:
            verdict = "sketch_size"
            recommendation = (
                "increase m (disjoint half-sketch decodes disagree by "
                f"{disagreement:.2f} of the box diagonal — the inverse "
                "problem is not identifiable at this sketch size)"
            )
        else:
            verdict = "ok"
            recommendation = "no failure signature detected"

    if obs_rt.ENABLED:
        obs_metrics.gauge("diagnose.rel_residual").set(rel_res)
        obs_metrics.gauge("diagnose.subsketch_disagreement").set(disagreement)
        obs_metrics.gauge("diagnose.mean_modulus").set(mean_mod)
        obs_metrics.counter("diagnose.verdicts", verdict=verdict).inc()
        obs_trace.point("diagnose.verdict", VERDICTS.index(verdict), verdict=verdict)

    return Diagnosis(
        verdict=verdict,
        scores=scores,
        details=details,
        recommendation=recommendation,
    )
