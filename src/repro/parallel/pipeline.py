"""GPipe-style pipeline parallelism via shard_map + ppermute.

Available as a parallelism feature (assignment: "DP/TP/PP/EP/SP as
appropriate").  The assigned dry-runs use DP x TP (x EP/SP), which covers all
10 archs at 512 chips; PP becomes necessary beyond ~16-way model parallelism
where TP collectives saturate ICI — stage boundaries then replace per-layer
all-reduces with point-to-point ppermutes.

Schedule: classic GPipe fill-drain over ``n_micro`` microbatches and
``n_stages`` pipeline stages (= size of the "pipe" mesh axis):

    tick t in [0, n_micro + n_stages):  every stage applies its layer block
    to its current activation, then ppermutes it one stage forward.  Stage s
    computes microbatch m at tick t = m + s; bubble fraction is the usual
    (n_stages - 1) / (n_micro + n_stages - 1).

``stage_fn(stage_params, x)`` is the per-stage computation (e.g. a slice of
layer groups); stage params live sharded P("pipe") on their leading axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.utils import compat


def pipeline_apply(
    stage_fn,
    stage_params,
    x_micro: jax.Array,  # (n_micro, mb, ...) microbatched input
    mesh: Mesh,
    axis: str = "pipe",
):
    """Run the GPipe schedule.  Returns (n_micro, mb, ...) outputs.

    stage_params: pytree with leading dim n_stages (sharded over ``axis``).
    Inputs/outputs are replicated across ``axis`` (stage 0 reads, the last
    stage's results are broadcast back) — a production variant would keep
    them sharded on the data axis; this keeps the schedule itself auditable.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    total_ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(params, xs):
        params = jax.tree.map(lambda p: p[0], params)  # this stage's slice
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        # carry/out differ per stage -> mark them varying over the pipe axis
        carry = compat.pvary(jnp.zeros(mb_shape, xs.dtype), axis)
        out = compat.pvary(jnp.zeros_like(xs), axis)

        def tick(t, state):
            carry, out = state
            # stage 0 ingests microbatch t (when in range); others use carry.
            x_in = jnp.where(
                stage == 0,
                xs[jnp.clip(t, 0, n_micro - 1)],
                carry,
            )
            y = stage_fn(params, x_in)
            # last stage records microbatch m = t - (n_stages - 1)
            m = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (m >= 0)
            m_c = jnp.clip(m, 0, n_micro - 1)
            cur = jax.lax.dynamic_index_in_dim(out, m_c, 0, keepdims=False)
            out = jax.lax.dynamic_update_index_in_dim(
                out, jnp.where(write, y, cur), m_c, 0
            )
            carry = jax.lax.ppermute(y, axis, perm)
            return carry, out

        _, out = jax.lax.fori_loop(0, total_ticks, tick, (carry, out))
        # broadcast the last stage's buffer to every stage (replicated out).
        out = jax.lax.psum(
            jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)), axis
        )
        return out

    param_specs = jax.tree.map(lambda _: P(axis), stage_params)
    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(param_specs, P()),
        out_specs=P(),
        axis_names={axis},
        check_vma=True,
    )
    return fn(stage_params, x_micro)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
