"""Sharding rules: FSDP + TP (+ EP + SP) over the production mesh.

Axes (launch/mesh.py): ``("pod", "data", "model")`` multi-pod or
``("data", "model")`` single-pod.

- Parameters: tensor-parallel dim over "model" (attention heads / FFN hidden /
  vocab / experts), FSDP dim over "data" (MaxText-style: XLA inserts per-layer
  all-gathers forward and reduce-scatters backward => ZeRO-3 memory without
  manual collectives).  Optimizer state mirrors parameter shardings.
- Batch: global batch over ("pod", "data").
- Decode caches: the KV-cache *sequence* dimension shards over "model"
  (sequence-parallel decode attention: scores/softmax reductions over the
  sharded axis become psums — the cache never gathers).  Recurrent states
  shard over their channel dim where divisible.
- Any dim not divisible by its axis size falls back to replication (guarded
  here, so odd vocab sizes like 92553 compile; see §Perf for the padded-vocab
  optimisation).

Param rules match by path suffix; recurrent-family (xlstm) params stay
replicated except embeddings (125M model — TP would only add latency).
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# (path-regex, spec builder) — first match wins.  "F" = fsdp axis, "M" = model.
_PARAM_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    (r"embed/table$", ("M", "F")),  # (vocab, d)
    (r"lm_head/w$", ("F", "M")),  # (d, vocab)
    (r"(mixer|cross)/wq$", ("F", "M")),
    (r"(mixer|cross)/wk$", ("F", "M")),
    (r"(mixer|cross)/wv$", ("F", "M")),
    (r"(mixer|cross)/wo$", ("M", "F")),
    (r"mlp/w_gate$", ("F", "M")),
    (r"mlp/w_up$", ("F", "M")),
    (r"mlp/w_down$", ("M", "F")),
    (r"mlp/router$", (None, None)),  # replicated: shard_map body computes it
    # MoE experts (E, d, f)/(E, f, d): EP over model, FSDP over d/f.
    (r"mlp/w_(gate|up)$", ("M", "F", None)),
    (r"mlp/w_down$", ("M", None, "F")),
    # Mamba: channel (d_inner) dim over model.
    (r"mixer/in_proj$", ("F", "M")),
    (r"mixer/conv_w$", (None, "M")),
    (r"mixer/conv_b$", ("M",)),
    (r"mixer/x_proj$", ("M", None)),
    (r"mixer/dt_proj$", (None, "M")),
    (r"mixer/dt_bias$", ("M",)),
    (r"mixer/a_log$", ("M", None)),
    (r"mixer/d_skip$", ("M",)),
    (r"mixer/out_proj$", ("M", "F")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def axis_extent(mesh: Mesh, axes) -> int:
    """Product of the named mesh axes' sizes — the device count a leading
    data axis is split over.  Shared by the batch/cache spec builders here
    and the SketchEngine's sharded backend (padding + merge fan-in p, the
    ``p`` of ``core.topology.wire_cost_model``)."""
    sizes = _axis_sizes(mesh)
    ext = 1
    for a in axes:
        ext *= sizes[a]
    return ext


def tenant_mesh(shards: int, axis: str = "tenant", devices=None) -> Mesh:
    """1-D mesh for fleet tenant sharding: ``shards`` devices on one axis.

    The fleet's stacked state (``core.fleet.FleetEngine(sharding="mesh")``)
    splits its leading tenant axis over this mesh — each device owns one
    contiguous block of ``n_tenants / shards`` tenant rows.  Tenant sharding
    is pure data parallelism, so a single axis is always enough; the axis
    name defaults to ``SketchJobSpec.tenant_shard_axis``'s default.
    """
    import numpy as np

    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    devices = list(jax.devices()) if devices is None else list(devices)
    if shards > len(devices):
        raise ValueError(
            f"tenant_mesh needs {shards} devices, only {len(devices)} "
            "available (force host devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N before "
            "jax initialises)"
        )
    return Mesh(np.asarray(devices[:shards]), (axis,))


def tenant_shard_specs(tree: Any, axis: str = "tenant") -> Any:
    """``P(axis)`` for every leaf of a stacked fleet pytree.

    Every fleet leaf — state accumulators ``(T, m)``, bounds ``(T, n)``,
    scalars-per-tenant ``(T,)``, stacked operator leaves, dither rows —
    carries the tenant axis leading, so one spec rule covers the whole
    tree: shard dim 0 over ``axis``, replicate the rest.  Feed the result
    to :func:`to_shardings` for placement or to ``compat.shard_map``
    in/out specs.
    """
    return jax.tree_util.tree_map(lambda _: P(axis), tree)


def _resolve(spec_tags, shape, mesh, fsdp_axis, stacked: bool):
    """Tags -> PartitionSpec with divisibility guards.  ``stacked``: the leaf
    has a leading layer-group axis (from scan stacking) that stays unsharded."""
    sizes = _axis_sizes(mesh)
    model = sizes.get("model", 1)
    fsdp = sizes.get(fsdp_axis, 1) if fsdp_axis else 1
    dims = list(shape[1:]) if stacked else list(shape)
    if len(spec_tags) != len(dims):
        return P()  # rank mismatch — replicate
    out: list[Any] = []
    for tag, d in zip(spec_tags, dims):
        if tag == "M" and model > 1 and d % model == 0:
            out.append("model")
        elif tag == "F" and fsdp > 1 and d % fsdp == 0:
            out.append(fsdp_axis)
        else:
            out.append(None)
    if stacked:
        out = [None] + out
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_specs(
    params_shape: Any, cfg: ModelConfig, mesh: Mesh, fsdp_axis: str | None = "data"
) -> Any:
    """PartitionSpec pytree matching a params (shape) pytree."""
    moe_3d = {"w_gate", "w_up", "w_down"}
    replicate_families = cfg.family == "ssm"

    def spec(path, leaf):
        ps = _path_str(path)
        stacked = ps.startswith("groups/") or ps.startswith("encoder/groups")
        shape = leaf.shape
        if replicate_families and "embed" not in ps and "lm_head" not in ps:
            return P()
        # Distinguish dense-mlp 2D vs moe 3D weights sharing the name.
        name = ps.rsplit("/", 1)[-1]
        rank = len(shape) - (1 if stacked else 0)
        if name in moe_3d and rank == 3:
            tags = ("M", "F", None) if name in ("w_gate", "w_up") else ("M", None, "F")
            return _resolve(tags, shape, mesh, fsdp_axis, stacked)
        for pat, tags in _PARAM_RULES:
            if re.search(pat, ps) and len(tags) == rank:
                return _resolve(tags, shape, mesh, fsdp_axis, stacked)
        return P()  # norms, biases, gates: replicated

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def opt_state_specs(opt_shape: Any, pspecs: Any) -> Any:
    """Optimizer state mirrors param shardings (ZeRO via GSPMD).

    Adam m/v share the parameter spec; Adafactor's factored stats inherit the
    spec with the reduced dim removed; int8-quantised payloads replicate
    (their blocked layout decouples from the logical dims).
    """
    flat_p = jax.tree_util.tree_flatten_with_path(
        pspecs, is_leaf=lambda x: isinstance(x, P)
    )[0]
    by_path = {_path_str(k): v for k, v in flat_p}

    def pad(base: P, rank: int) -> tuple:
        t = tuple(base)
        return t + (None,) * (rank - len(t))

    def spec(path, leaf):
        ps = _path_str(path)
        for prefix in ("m/", "v/", "stats/"):
            if not ps.startswith(prefix):
                continue
            rest = ps[len(prefix) :]
            if rest in by_path:  # plain adam m/v — same shape, same spec
                return by_path[rest]
            if "/" in rest:
                cand, suffix = rest.rsplit("/", 1)
                if cand in by_path:
                    base = pad(by_path[cand], len(leaf.shape) + 1)
                    if suffix == "vr":  # param shape minus last dim
                        return P(*base[:-1])
                    if suffix == "vc":  # param shape minus 2nd-to-last dim
                        return P(*(base[:-2] + base[-1:]))
                    if suffix == "v":
                        return P(*base[: len(leaf.shape)])
                    return P()  # q/scale payloads
        return P()

    return jax.tree_util.tree_map_with_path(spec, opt_shape)


# ---------------------------------------------------------------------------
# Batch / cache specs per shape cell
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Any:
    """Specs for the training/prefill input batch dict."""
    ba = batch_axes(mesh)
    dp = axis_extent(mesh, ba)
    bspec = ba if shape.global_batch % dp == 0 and shape.global_batch >= dp else None
    specs = {"tokens": P(bspec, None)}
    if shape.kind == "train":
        specs["labels"] = P(bspec, None)
    if cfg.frontend == "vision":
        specs["patches"] = P(bspec, None, None)
    elif cfg.frontend == "audio":
        specs["frames"] = P(bspec, None, None)
    return specs


def cache_specs(cache_shape: Any, cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Decode-cache specs: batch over (pod,data) when divisible; KV-cache
    sequence dim over "model" (SP decode); recurrent channels over "model"."""
    ba = batch_axes(mesh)
    dp = axis_extent(mesh, ba)
    model = _axis_sizes(mesh).get("model", 1)
    b = shape.global_batch
    bspec = ba if b % dp == 0 and b >= dp else None

    def spec(path, leaf):
        ps = _path_str(path)
        shp = leaf.shape
        stacked = ps.startswith("groups/")
        dims = shp[1:] if stacked else shp
        name = ps.rsplit("/", 1)[-1]
        out: list[Any] = [bspec]  # dim0 after optional stack = batch
        if name in ("k", "v", "ck", "cv", "cross_k", "cross_v"):
            # (B, S, KV, hd): shard S over model if divisible.
            s = dims[1]
            out += ["model" if s % model == 0 and not cfg.family == "ssm" else None,
                    None, None]
        elif name == "clogw":
            s = dims[1]
            out += ["model" if s % model == 0 else None, None]
        elif ps.endswith("state/conv"):
            out += [None, "model" if dims[2] % model == 0 else None]
        elif ps.endswith("state/ssm"):
            out += ["model" if dims[1] % model == 0 else None, None]
        elif "state/" in ps:  # mlstm C/n, slstm h/c/n/m — small: replicate
            out += [None] * (len(dims) - 1)
        else:
            out += [None] * (len(dims) - 1)
        if stacked:
            out = [None] + out
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def activation_sharder(mesh: Mesh | None, seq_shard: bool = False):
    """Constraint hook threaded through the model (MaxText-style).

    GSPMD sharding propagation alone loses the batch sharding deep inside
    scanned layers (observed: attention scores materialising with the GLOBAL
    batch per device).  Explicit constraints on the residual stream and the
    attention/FFN intermediates pin every activation's sharding.

    ``seq_shard`` (Megatron-style sequence parallelism) additionally shards
    the residual stream's sequence dim over "model": the per-layer remat save
    shrinks by the TP degree (61 x 940 MB -> 61 x 59 MB for kimi); XLA
    inserts the all-gather at attention/MLP entry and the reduce-scatter at
    exit.  Enabled for d_model >= 4096 archs (configs/base.py).

    kinds: resid (B,S,d) | heads (B,S,H,hd) | kv (B,S,KV,hd) | ffn (B,S,ff)
    """
    if mesh is None:
        return lambda x, kind: x
    sizes = _axis_sizes(mesh)
    ba = batch_axes(mesh)
    dp = axis_extent(mesh, ba)
    model = sizes.get("model", 1)

    def shard(x, kind: str):
        bspec = ba if (x.shape[0] % dp == 0 and x.shape[0] >= dp) else None
        if kind == "resid":
            s = x.shape[1]
            sspec = (
                "model" if seq_shard and s % model == 0 and s > model else None
            )
            spec = P(bspec, sspec, None)
        elif kind in ("heads", "kv"):
            h = x.shape[2]
            spec = P(bspec, None, "model" if h % model == 0 else None, None)
        elif kind == "ffn":
            f = x.shape[2]
            spec = P(bspec, None, "model" if f % model == 0 else None)
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard


def to_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
