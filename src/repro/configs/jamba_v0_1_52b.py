"""jamba-v0.1-52b [hybrid] Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts top-2.
Period-8 block: 1 attention layer per 7 Mamba layers (attn at index 3), MoE
MLP on every second layer.  ``long_context="ckm"``: the 4 attention layers use
CKM-compressed KV for long_500k; Mamba layers carry O(1) state.
"""

from repro.configs.base import ModelConfig

_MIXER = ("mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba")
_MLP = ("dense", "moe", "dense", "moe", "dense", "moe", "dense", "moe")


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        mixer_pattern=_MIXER,
        mlp_pattern=_MLP,
        moe_experts=16,
        moe_top_k=2,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        long_context="ckm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        family="hybrid",
        n_layers=8,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        mixer_pattern=_MIXER,
        mlp_pattern=_MLP,
        moe_experts=4,
        moe_top_k=2,
        moe_capacity_factor=8.0,
        ssm_state=4,
        ssm_conv=4,
        ssm_expand=2,
        q_block=32,
        scan_chunk=16,
        long_context="ckm",
    )
