"""gemma3-1b [dense] [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, 5:1 local:global
sliding-window pattern, 128k-class context.  head_dim=256 (gemma3 heads are
wider than d_model / n_heads).  ``long_context="ckm"``: the 1-in-6 global
layers use the CKM-compressed KV path for long_500k (DESIGN.md §4); local
layers are sub-quadratic by construction (ring window).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab_size=262144,
        mixer_pattern=("local", "local", "local", "local", "local", "attn"),
        mlp_pattern=("dense",) * 6,
        window=512,
        tie_embeddings=True,
        rope_theta=1e6,
        long_context="ckm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b-smoke",
        family="dense",
        n_layers=8,  # 1 full period + 2 remainder layers (exercises "rest")
        d_model=48,
        n_heads=2,
        n_kv_heads=1,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        mixer_pattern=("local", "local", "local", "local", "local", "attn"),
        mlp_pattern=("dense",) * 6,
        window=16,
        tie_embeddings=True,
        q_block=32,
        scan_chunk=16,
        long_context="ckm",
    )
