"""smollm-360m [dense] llama-arch small [hf:HuggingFaceTB/SmolLM-360M].

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49152,
        tie_embeddings=True,
        long_context="skip",  # pure full attention
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-smoke",
        family="dense",
        n_layers=2,
        d_model=60,
        n_heads=3,
        n_kv_heads=1,
        head_dim=20,
        d_ff=128,
        vocab_size=256,
        tie_embeddings=True,
        q_block=32,
        scan_chunk=16,
    )
