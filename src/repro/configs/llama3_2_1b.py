"""llama3.2-1b [dense] small llama3 [hf:meta-llama/Llama-3.2-1B].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128256,
        tie_embeddings=True,
        rope_theta=5e5,
        long_context="skip",  # pure full attention
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        tie_embeddings=True,
        q_block=32,
        scan_chunk=16,
    )
