"""internvl2-26b [vlm]: InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  The vision frontend
is a STUB per the assignment: input_specs() provides precomputed patch
embeddings (B, 256, d_model) prepended to the token stream.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,
        frontend="vision",
        frontend_len=256,
        q_block=256,
        long_context="skip",  # pure full attention (DESIGN.md §4)
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        frontend="vision",
        frontend_len=8,
        q_block=32,
        scan_chunk=16,
    )
