"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384 experts top-8.
Per the assignment the attention is GQA (the real K2 uses MLA); every layer is
MoE (the real K2's first dense layer / shared expert are elided) — noted in
DESIGN.md §8.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=2048,
        vocab_size=163840,
        mlp_pattern=("moe",),
        moe_experts=384,
        moe_top_k=8,
        q_block=128,  # bounds the f32 score-block transient at 64 heads
        long_context="skip",  # pure full attention
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab_size=256,
        mlp_pattern=("moe",),
        moe_experts=8,
        moe_top_k=2,
        moe_capacity_factor=8.0,
        q_block=32,
        scan_chunk=16,
    )
