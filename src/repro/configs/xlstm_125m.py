"""xlstm-125m [ssm] sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H d_ff=0 vocab=50304.  d_ff=0 -> no separate MLP: the
recurrent blocks carry their own up/down projections.  Alternating
mLSTM / sLSTM (1:1).  Sub-quadratic by construction -> runs long_500k.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        mixer_pattern=("mlstm", "slstm"),
        mlp_pattern=("none", "none"),
        mlstm_heads=4,
        ssm_expand=2,
        tie_embeddings=True,
        long_context="run",  # O(1) recurrent state
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke",
        family="ssm",
        n_layers=4,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab_size=256,
        mixer_pattern=("mlstm", "slstm"),
        mlp_pattern=("none", "none"),
        mlstm_heads=2,
        ssm_expand=2,
        tie_embeddings=True,
        q_block=32,
        scan_chunk=16,
        long_context="run",
    )
