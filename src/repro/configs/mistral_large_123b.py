"""mistral-large-123b [dense] [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        family="dense",
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=32768,
        q_block=128,  # bounds the f32 score-block transient at 96 heads
        long_context="skip",  # pure full attention
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=128,
        vocab_size=256,
        q_block=32,
        scan_chunk=16,
    )
