"""Model / run configuration dataclasses + the --arch registry."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # Layer pattern: one period, repeated n_layers // len(pattern) times via
    # lax.scan, remainder unrolled.  mixer kinds: attn|local|mamba|mlstm|slstm;
    # mlp kinds: dense|moe|none.
    mixer_pattern: tuple[str, ...] = ("attn",)
    mlp_pattern: tuple[str, ...] = ("dense",)
    window: int = 1024  # sliding window for "local" mixers
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    # SSM / recurrent
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    mlstm_heads: int = 4
    # Encoder-decoder (whisper): encoder is an attn-only non-causal stack.
    encoder_layers: int = 0
    # Modality frontend STUB: input_specs() provides precomputed embeddings.
    frontend: Literal["vision", "audio", None] = None
    frontend_len: int = 0
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    q_block: int = 512  # query chunk in lazy-mask attention
    # Attention score storage dtype between the QK^T dot and the softmax
    # fusion.  "f32" is the conservative default; "bf16" halves the dominant
    # HBM term of every train cell (softmax statistics stay f32 inside the
    # fusion).  A Pallas flash kernel (kernels/flash_attention.py) removes
    # the traffic entirely on TPU.
    score_dtype: str = "f32"
    scan_chunk: int = 256  # chunk for recurrent mixers
    # long_500k policy (DESIGN.md §4): subquadratic archs run it; pure
    # full-attention archs skip.  "ckm" = CKM-compressed KV on global layers.
    long_context: Literal["run", "skip", "ckm"] = "skip"

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def period(self) -> int:
        assert len(self.mixer_pattern) == len(self.mlp_pattern)
        return len(self.mixer_pattern)

    def layer_kinds(self) -> list[tuple[str, str]]:
        """Per-layer (mixer, mlp) for all n_layers."""
        p = self.period
        return [
            (self.mixer_pattern[i % p], self.mlp_pattern[i % p])
            for i in range(self.n_layers)
        ]

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, hd = self.d_model, self.head_dim_
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for mixer, mlp in self.layer_kinds():
            total += d  # norm1
            if mixer in ("attn", "local"):
                total += d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
            elif mixer == "mamba":
                di = self.ssm_expand * d
                dr = max(d // 16, 1)
                total += (
                    d * 2 * di + self.ssm_conv * di + di
                    + di * (dr + 2 * self.ssm_state) + dr * di + di
                    + di * self.ssm_state + di + di * d
                )
            elif mixer == "mlstm":
                di = self.ssm_expand * d
                total += d * di + 3 * di * di + d * 2 * self.mlstm_heads + d * di + di * d
            elif mixer == "slstm":
                # W (d,4d) + block-diagonal R (H, d/H, 4d/H) + bias
                total += d * 4 * d + d * 4 * d // self.n_heads + 4 * d
            if mlp == "dense":
                total += d + 3 * d * self.d_ff
            elif mlp == "moe":
                total += d + d * self.moe_experts + 3 * d * self.d_ff * self.moe_experts
        if self.encoder_layers:
            total += self.encoder_layers * (
                2 * d + d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
                + 3 * d * self.d_ff
            )
            # decoder cross-attention blocks
            total += self.n_layers * (d + d * hd * (self.n_heads * 2 + self.n_kv_heads * 2))
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.moe_experts == 0:
            return self.param_count()
        full_moe = self.param_count()
        n_moe_layers = sum(1 for _, m in self.layer_kinds() if m == "moe")
        expert_params = 3 * self.d_model * self.d_ff
        inactive = n_moe_layers * (self.moe_experts - self.moe_top_k) * expert_params
        return full_moe - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode", "long_decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "long_decode"),
}

ARCHS = [
    "internvl2-26b",
    "mistral-large-123b",
    "gemma3-1b",
    "smollm-360m",
    "llama3.2-1b",
    "kimi-k2-1t-a32b",
    "granite-moe-1b-a400m",
    "xlstm-125m",
    "whisper-small",
    "jamba-v0.1-52b",
]


def get_config(arch: str) -> ModelConfig:
    """Load ``src/repro/configs/<arch>.py`` (dashes/dots -> underscores)."""
    mod_name = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.config()


def get_smoke_config(arch: str) -> ModelConfig:
    mod_name = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config()
