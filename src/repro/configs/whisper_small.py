"""whisper-small [audio] enc-dec, conv frontend (stub) [arXiv:2212.04356].

12L (per stack) d_model=768 12H d_ff=3072 vocab=51865.  The conv/mel frontend
is a STUB: input_specs() provides precomputed frame embeddings (B, 1500, d)
fed to the encoder.  RoPE replaces whisper's absolute embeddings (DESIGN.md
§8).  Enc-dec decodes against cross-attention; long_500k skipped (full
attention decoder).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51865,
        encoder_layers=12,
        frontend="audio",
        frontend_len=1500,
        tie_embeddings=True,
        long_context="skip",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        encoder_layers=2,
        frontend="audio",
        frontend_len=12,
        tie_embeddings=True,
        q_block=32,
        scan_chunk=16,
    )
