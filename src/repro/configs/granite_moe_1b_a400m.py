"""granite-moe-1b-a400m [moe] [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32 experts top-8.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab_size=49155,
        mlp_pattern=("moe",),
        moe_experts=32,
        moe_top_k=8,
        tie_embeddings=True,
        long_context="skip",  # pure full attention
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab_size=256,
        mlp_pattern=("moe",),
        moe_experts=4,
        moe_top_k=2,
        moe_capacity_factor=8.0,
        tie_embeddings=True,
        q_block=32,
        scan_chunk=16,
    )
