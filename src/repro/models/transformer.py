"""The model stack: one flexible decoder (+ optional encoder) that realises
all 10 assigned architectures via the (mixer, mlp) layer pattern in
``ModelConfig`` (see configs/base.py).

Layer grouping: ``n_layers // period`` identical groups are applied with
``lax.scan`` (stacked params -> O(1) compile time in depth); any remainder
layers are unrolled.  Serving caches mirror the same (groups, rest) structure.

Modes
-----
- ``forward``      : full-sequence (training / encoder / prefill backbone)
- ``prefill``      : forward + cache construction for decode
- ``decode_step``  : one token against the cache (ring buffers for sliding-
                     window layers, CKM-compressed KV for ``long_context="ckm"``)

Modality frontends are STUBS per the assignment: ``vlm`` consumes precomputed
patch embeddings (prepended to the token stream), ``audio`` consumes
precomputed frames into the encoder.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# Dims helpers
# ---------------------------------------------------------------------------


def attn_dims(cfg: ModelConfig, mixer: str) -> L.AttnDims:
    return L.AttnDims(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim_,
        window=cfg.window if mixer == "local" else 0,
        rope_theta=cfg.rope_theta,
        q_block=cfg.q_block,
        score_dtype=cfg.score_dtype,
    )


def mamba_dims(cfg: ModelConfig) -> ssm.MambaDims:
    return ssm.MambaDims(
        cfg.d_model, cfg.ssm_state, cfg.ssm_conv, cfg.ssm_expand, cfg.scan_chunk
    )


def mlstm_dims(cfg: ModelConfig) -> ssm.MLSTMDims:
    return ssm.MLSTMDims(cfg.d_model, cfg.mlstm_heads, cfg.ssm_expand, cfg.scan_chunk)


def moe_dims(cfg: ModelConfig) -> moe_mod.MoEDims:
    return moe_mod.MoEDims(
        cfg.d_model, cfg.d_ff, cfg.moe_experts, cfg.moe_top_k,
        cfg.moe_capacity_factor,
    )


def _kind(cfg: ModelConfig, layer_idx: int) -> tuple[str, str]:
    p = cfg.period
    return cfg.mixer_pattern[layer_idx % p], cfg.mlp_pattern[layer_idx % p]


def _moe_batch_axes(mesh) -> tuple[str, ...]:
    if mesh is None:
        return ("data",)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _sharder(mesh, cfg: ModelConfig | None = None):
    from repro.parallel.sharding import activation_sharder

    seq_shard = cfg is not None and cfg.d_model >= 4096
    return activation_sharder(mesh, seq_shard=seq_shard)


# ---------------------------------------------------------------------------
# Single layer: init / forward / decode-step
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, mixer: str, mlp_kind: str, cross: bool) -> Params:
    keys = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_rmsnorm(cfg.d_model)}
    if mixer in ("attn", "local"):
        p["mixer"] = L.init_attention(keys[0], attn_dims(cfg, mixer))
    elif mixer == "mamba":
        p["mixer"] = ssm.init_mamba(keys[0], mamba_dims(cfg))
    elif mixer == "mlstm":
        p["mixer"] = ssm.init_mlstm(keys[0], mlstm_dims(cfg))
    elif mixer == "slstm":
        p["mixer"] = ssm.init_slstm(keys[0], ssm.SLSTMDims(cfg.d_model, cfg.n_heads))
    else:
        raise ValueError(mixer)
    if cross:
        p["norm_cross"] = L.init_rmsnorm(cfg.d_model)
        p["cross"] = L.init_attention(keys[1], attn_dims(cfg, "attn"))
    if mlp_kind in ("dense", "moe"):
        p["norm2"] = L.init_rmsnorm(cfg.d_model)
        p["mlp"] = (
            L.init_mlp(keys[2], cfg.d_model, cfg.d_ff)
            if mlp_kind == "dense"
            else moe_mod.init_moe(keys[2], moe_dims(cfg))
        )
    return p


def layer_forward(
    p: Params,
    cfg: ModelConfig,
    mixer: str,
    mlp_kind: str,
    x: jax.Array,
    positions: jax.Array,
    mesh,
    causal: bool = True,
    enc_kv=None,
    collect_cache: bool = False,
):
    """Pre-norm residual layer.  Returns (x, aux_loss, cache_or_None)."""
    shard = _sharder(mesh, cfg)
    x = shard(x, "resid")
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    cache = None
    if mixer in ("attn", "local"):
        dims = attn_dims(cfg, mixer)
        if collect_cache:
            out, (k, v) = L.attention_apply(
                p["mixer"], dims, h, positions, causal, return_kv=True, shard=shard
            )
            cache = {"k": k, "v": v}
        else:
            out = L.attention_apply(
                p["mixer"], dims, h, positions, causal, shard=shard
            )
    elif mixer == "mamba":
        out, state = ssm.mamba_apply(p["mixer"], mamba_dims(cfg), h)
        cache = state if collect_cache else None
    elif mixer == "mlstm":
        out, state = ssm.mlstm_apply(p["mixer"], mlstm_dims(cfg), h)
        cache = state if collect_cache else None
    elif mixer == "slstm":
        out, state = ssm.slstm_apply(p["mixer"], ssm.SLSTMDims(cfg.d_model, cfg.n_heads), h)
        cache = state if collect_cache else None
    else:
        raise ValueError(mixer)
    x = x + out
    if enc_kv is not None:
        h = L.rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        x = x + L.cross_attention_apply(p["cross"], attn_dims(cfg, "attn"), h, enc_kv)
    aux = jnp.zeros((), jnp.float32)
    if mlp_kind == "dense":
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h, shard=shard)
    elif mlp_kind == "moe":
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        out, aux = moe_mod.moe_apply(
            p["mlp"], moe_dims(cfg), h, mesh=mesh, batch_axes=_moe_batch_axes(mesh)
        )
        x = x + out
    x = shard(x, "resid")
    return x, aux, cache


def layer_step(
    p: Params,
    cfg: ModelConfig,
    mixer: str,
    mlp_kind: str,
    x: jax.Array,
    cache: Params,
    index: jax.Array,
    mesh,
):
    """Single-token decode.  x: (B, 1, d).  Returns (x, new_cache)."""
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    enc_kv = None
    if "cross_k" in cache:
        enc_kv = (cache["cross_k"], cache["cross_v"])
    if mixer in ("attn", "local"):
        dims = attn_dims(cfg, mixer)
        if "ck" in cache:  # CKM-compressed global attention (long_context)
            from repro.serve.kv_clustering import attention_decode_compressed

            out, kv_cache = attention_decode_compressed(
                p["mixer"], dims, h, cache, index
            )
        else:
            out, ck, cv = L.attention_decode(
                p["mixer"], dims, h, cache["k"], cache["v"], index
            )
            kv_cache = {"k": ck, "v": cv}
        cache = {**cache, **kv_cache}
    elif mixer == "mamba":
        out, st = ssm.mamba_step(p["mixer"], mamba_dims(cfg), h, cache["state"])
        cache = {**cache, "state": st}
    elif mixer == "mlstm":
        out, st = ssm.mlstm_step(p["mixer"], mlstm_dims(cfg), h, cache["state"])
        cache = {**cache, "state": st}
    elif mixer == "slstm":
        out, st = ssm.slstm_step(p["mixer"], ssm.SLSTMDims(cfg.d_model, cfg.n_heads), h, cache["state"])
        cache = {**cache, "state": st}
    else:
        raise ValueError(mixer)
    x = x + out
    if enc_kv is not None:
        h = L.rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        x = x + L.cross_attention_apply(p["cross"], attn_dims(cfg, "attn"), h, enc_kv)
    if mlp_kind == "dense":
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h)
    elif mlp_kind == "moe":
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        out, _ = moe_mod.moe_apply(
            p["mlp"], moe_dims(cfg), h, mesh=mesh, dense_path=True,
            batch_axes=_moe_batch_axes(mesh),
        )
        x = x + out
    return x, cache


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    period = cfg.period
    n_groups = cfg.n_layers // period
    n_rest = cfg.n_layers % period
    cross = cfg.encoder_layers > 0

    def init_group(k):
        ks = jax.random.split(k, period)
        return {
            str(i): init_layer(
                ks[i], cfg, cfg.mixer_pattern[i], cfg.mlp_pattern[i], cross
            )
            for i in range(period)
        }

    params: Params = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "groups": jax.vmap(init_group)(jax.random.split(keys[1], n_groups)),
    }
    if n_rest:
        ks = jax.random.split(keys[2], n_rest)
        params["rest"] = {
            str(i): init_layer(ks[i], cfg, *_kind(cfg, n_groups * period + i), cross)
            for i in range(n_rest)
        }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_lm_head(keys[3], cfg.d_model, cfg.vocab_size)
    if cfg.encoder_layers:
        params["encoder"] = {
            "groups": jax.vmap(
                lambda k: init_layer(k, cfg, "attn", "dense", cross=False)
            )(jax.random.split(keys[4], cfg.encoder_layers)),
            "final_norm": L.init_rmsnorm(cfg.d_model),
        }
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill backbone)
# ---------------------------------------------------------------------------


def _encoder_forward(params, cfg: ModelConfig, frames: jax.Array, mesh):
    """Whisper encoder on precomputed (stub) conv features (B, F, d)."""
    x = frames
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])

    def body(x, p):
        x, _, _ = layer_forward(p, cfg, "attn", "dense", x, pos, mesh, causal=False)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["groups"])
    return L.rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _embed_inputs(params, cfg: ModelConfig, batch: dict, dtype):
    """Token (+ frontend) embedding.  Returns (x, positions, enc_out)."""
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens, dtype)
    x = x * jnp.asarray(jnp.sqrt(cfg.d_model), dtype)
    enc_out = None
    if cfg.frontend == "vision":
        patches = batch["patches"].astype(dtype)  # (B, F, d) stub embeddings
        x = jnp.concatenate([patches, x], axis=1)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    return x, positions, enc_out


def forward(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    mesh=None,
    dtype=jnp.bfloat16,
    remat: str = "none",
):
    """Full-sequence forward.  Returns (final hidden (B, S_total, d), aux)."""
    x, positions, _ = _embed_inputs(params, cfg, batch, dtype)
    cross = cfg.encoder_layers > 0
    enc_out = None
    if cross:
        enc_out = _encoder_forward(params, cfg, batch["frames"].astype(dtype), mesh)
    period = cfg.period

    def group_body(carry, gparams):
        x, aux = carry
        for i in range(period):
            enc_kv = None
            if cross:
                enc_kv = L.encoder_kv(
                    gparams[str(i)]["cross"], attn_dims(cfg, "attn"), enc_out
                )
            x, a, _ = layer_forward(
                gparams[str(i)], cfg, cfg.mixer_pattern[i], cfg.mlp_pattern[i],
                x, positions, mesh, causal=True, enc_kv=enc_kv,
            )
            aux = aux + a
        return (x, aux), None

    body = group_body
    if remat == "full":
        body = jax.checkpoint(group_body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False,
        )
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["groups"])
    if "rest" in params:
        n_groups = cfg.n_layers // period
        for i in range(cfg.n_layers % period):
            enc_kv = None
            if cross:
                enc_kv = L.encoder_kv(
                    params["rest"][str(i)]["cross"], attn_dims(cfg, "attn"), enc_out
                )
            x, a, _ = layer_forward(
                params["rest"][str(i)], cfg, *_kind(cfg, n_groups * period + i),
                x, positions, mesh, causal=True, enc_kv=enc_kv,
            )
            aux = aux + a
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def logits_fn(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], x)
    return L.lm_head(params["lm_head"], x)


def chunked_ce_loss(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,
    labels: jax.Array,
    chunk: int = 256,
) -> jax.Array:
    """Cross-entropy over seq chunks: the (B, S, V) logits never materialise.

    labels: (B, S_total) int32, negative = ignored (frontend/pad positions).
    """
    b, s, d = x.shape
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    nch = x.shape[1] // chunk
    xs = x.reshape(b, nch, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(b, nch, chunk).swapaxes(0, 1)

    def body(acc, inp):
        xc, lc = inp
        logits = logits_fn(params, cfg, xc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None], axis=-1)[
            ..., 0
        ]
        mask = (lc >= 0).astype(jnp.float32)
        return (acc[0] + jnp.sum((logz - gold) * mask), acc[1] + jnp.sum(mask)), None

    (total, count), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xs, ls))
    return total / jnp.maximum(count, 1.0)


def lm_loss(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    mesh=None,
    dtype=jnp.bfloat16,
    remat: str = "none",
    aux_weight: float = 0.01,
) -> jax.Array:
    x, aux = forward(params, cfg, batch, mesh, dtype, remat)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        f = batch["patches"].shape[1]
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], f), -100, labels.dtype), labels], axis=1
        )
    loss = chunked_ce_loss(params, cfg, x, labels)
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode step
# ---------------------------------------------------------------------------

CKM_KV_CENTROIDS = 4096  # compressed-KV size for long_context="ckm"
CKM_KV_RECENT = 1024  # raw ring of most recent tokens alongside centroids


def _layer_cache_spec(cfg: ModelConfig, mixer: str, batch: int, cache_len: int,
                      mode: str, dtype):
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_
    if mixer == "local":
        w = min(cfg.window, cache_len)
        return {
            "k": jnp.zeros((batch, w, kvh, hd), dtype),
            "v": jnp.zeros((batch, w, kvh, hd), dtype),
        }
    if mixer == "attn":
        if mode == "ckm":
            return {
                "ck": jnp.zeros((batch, CKM_KV_CENTROIDS, kvh, hd), dtype),
                "cv": jnp.zeros((batch, CKM_KV_CENTROIDS, kvh, hd), dtype),
                "clogw": jnp.zeros((batch, CKM_KV_CENTROIDS, kvh), jnp.float32),
                "k": jnp.zeros((batch, CKM_KV_RECENT, kvh, hd), dtype),
                "v": jnp.zeros((batch, CKM_KV_RECENT, kvh, hd), dtype),
            }
        return {
            "k": jnp.zeros((batch, cache_len, kvh, hd), dtype),
            "v": jnp.zeros((batch, cache_len, kvh, hd), dtype),
        }
    if mixer == "mamba":
        return {"state": ssm.mamba_init_state(mamba_dims(cfg), batch, dtype)}
    if mixer == "mlstm":
        return {"state": ssm.mlstm_init_state(mlstm_dims(cfg), batch)}
    if mixer == "slstm":
        return {"state": ssm.slstm_init_state(ssm.SLSTMDims(cfg.d_model, cfg.n_heads), batch, dtype)}
    raise ValueError(mixer)


def init_cache(
    cfg: ModelConfig, batch: int, cache_len: int, mode: str = "full",
    dtype=jnp.bfloat16,
) -> Params:
    """Zero cache pytree mirroring the (groups, rest) param structure."""
    period = cfg.period
    n_groups = cfg.n_layers // period
    cross = cfg.encoder_layers > 0

    def one(mixer):
        c = _layer_cache_spec(cfg, mixer, batch, cache_len, mode, dtype)
        if cross:
            c["cross_k"] = jnp.zeros(
                (batch, cfg.frontend_len, cfg.n_kv_heads, cfg.head_dim_), dtype
            )
            c["cross_v"] = jnp.zeros_like(c["cross_k"])
        return c

    group_cache = {str(i): one(cfg.mixer_pattern[i]) for i in range(period)}
    cache: Params = {
        "groups": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups, *x.shape)), group_cache
        ),
    }
    if cfg.n_layers % period:
        cache["rest"] = {
            str(i): one(cfg.mixer_pattern[(n_groups * period + i) % period])
            for i in range(cfg.n_layers % period)
        }
    return cache


def prefill(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    cache_len: int,
    mesh=None,
    dtype=jnp.bfloat16,
):
    """Process the prompt; returns (last-position logits, cache, index)."""
    x, positions, _ = _embed_inputs(params, cfg, batch, dtype)
    s_total = x.shape[1]
    assert cache_len >= s_total, (cache_len, s_total)
    cross = cfg.encoder_layers > 0
    enc_out = None
    if cross:
        enc_out = _encoder_forward(params, cfg, batch["frames"].astype(dtype), mesh)
    period = cfg.period

    def to_cache(mixer, raw, p_layer):
        """Convert layer_forward's collected kv/state into decode cache form."""
        if mixer == "attn":
            k, v = raw["k"], raw["v"]
            pad = cache_len - k.shape[1]
            c = {
                "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            }
        elif mixer == "local":
            w = min(cfg.window, cache_len)
            k, v = raw["k"], raw["v"]
            s = k.shape[1]
            if s >= w:
                # last w entries, placed at their ring slots (pos % w).
                tail_k, tail_v = k[:, s - w :], v[:, s - w :]
                pos = (jnp.arange(s - w, s)) % w
                order = jnp.argsort(pos)
                c = {"k": tail_k[:, order], "v": tail_v[:, order]}
            else:
                c = {
                    "k": jnp.pad(k, ((0, 0), (0, w - s), (0, 0), (0, 0))),
                    "v": jnp.pad(v, ((0, 0), (0, w - s), (0, 0), (0, 0))),
                }
        else:
            c = {"state": raw}
        if cross:
            ck, cv = L.encoder_kv(p_layer["cross"], attn_dims(cfg, "attn"), enc_out)
            c["cross_k"], c["cross_v"] = ck, cv
        return c

    def group_body(x, gparams):
        caches = {}
        for i in range(period):
            enc_kv = None
            if cross:
                enc_kv = L.encoder_kv(
                    gparams[str(i)]["cross"], attn_dims(cfg, "attn"), enc_out
                )
            x, _, raw = layer_forward(
                gparams[str(i)], cfg, cfg.mixer_pattern[i], cfg.mlp_pattern[i],
                x, positions, mesh, causal=True, enc_kv=enc_kv, collect_cache=True,
            )
            caches[str(i)] = to_cache(cfg.mixer_pattern[i], raw, gparams[str(i)])
        return x, caches

    x, group_caches = jax.lax.scan(group_body, x, params["groups"])
    cache: Params = {"groups": group_caches}
    if "rest" in params:
        n_groups = cfg.n_layers // period
        rest = {}
        for i in range(cfg.n_layers % period):
            li = n_groups * period + i
            enc_kv = None
            if cross:
                enc_kv = L.encoder_kv(
                    params["rest"][str(i)]["cross"], attn_dims(cfg, "attn"), enc_out
                )
            x, _, raw = layer_forward(
                params["rest"][str(i)], cfg, *_kind(cfg, li), x, positions, mesh,
                causal=True, enc_kv=enc_kv, collect_cache=True,
            )
            rest[str(i)] = to_cache(_kind(cfg, li)[0], raw, params["rest"][str(i)])
        cache["rest"] = rest
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, cfg, x[:, -1:, :])
    return logits, cache, jnp.asarray(s_total, jnp.int32)


def decode_step(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,
    cache: Params,
    index: jax.Array,
    mesh=None,
    dtype=jnp.bfloat16,
):
    """One decode step.  token: (B, 1) int32; index: () position of token.

    Returns (logits (B, 1, V), new cache).
    """
    x = L.embed(params["embed"], token, dtype)
    x = x * jnp.asarray(jnp.sqrt(cfg.d_model), dtype)
    period = cfg.period

    def group_body(x, xs_):
        gparams, gcache = xs_
        new = {}
        for i in range(period):
            x, c = layer_step(
                gparams[str(i)], cfg, cfg.mixer_pattern[i], cfg.mlp_pattern[i],
                x, gcache[str(i)], index, mesh,
            )
            new[str(i)] = c
        return x, new

    x, new_group_caches = jax.lax.scan(
        group_body, x, (params["groups"], cache["groups"])
    )
    new_cache: Params = {"groups": new_group_caches}
    if "rest" in params:
        n_groups = cfg.n_layers // period
        rest = {}
        for i in range(cfg.n_layers % period):
            li = n_groups * period + i
            x, c = layer_step(
                params["rest"][str(i)], cfg, *_kind(cfg, li), x,
                cache["rest"][str(i)], index, mesh,
            )
            rest[str(i)] = c
        new_cache["rest"] = rest
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x), new_cache
