"""Mixture-of-Experts FFN with expert parallelism (EP) over the "model" axis.

Design (DESIGN.md §3): activations between layers keep ``d_model`` replicated
across the model axis (Megatron-style TP).  That means every model shard
already holds every token — so expert parallelism needs NO token all-to-all:

  each model shard owns E/|model| experts; it sorts+scatters the tokens routed
  to ITS experts into fixed-capacity buffers, runs its expert FFNs, gathers
  results back to token order, and a single psum over the model axis combines
  the per-shard partial outputs (a token's experts live on exactly the shards
  that own them; all other shards contribute zeros).

Cross-shard traffic is ONE (T, d_model) psum per MoE layer — identical in
shape to the dense-MLP TP all-reduce it replaces.  Buffers are
(E_local, capacity, d): the (T, E) one-hot dispatch tensor of GShard never
materialises.  Overflowing tokens beyond capacity are dropped (standard).

Two compute paths:
- ``dispatch`` (sort+scatter, above) for training/prefill where T is large;
- ``dense``   for single-token decode: every shard runs all its local experts
  on the (few) tokens, masked by the router — cheaper than dispatch when
  T * top_k ~ E_local and avoids gather/scatter churn at decode.

The router-initialisation hook from compressive clustering (paper tie-in)
lives in ``router_init_from_ckm``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _dense_init
from repro.utils import compat

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


def init_moe(key, dims: MoEDims) -> Params:
    ks = jax.random.split(key, 4)
    e, d, f = dims.n_experts, dims.d_model, dims.d_ff
    return {
        "router": _dense_init(ks[0], (d, e)),
        "w_gate": _dense_init(ks[1], (e, d, f), in_axis=1),
        "w_up": _dense_init(ks[2], (e, d, f), in_axis=1),
        "w_down": _dense_init(ks[3], (e, f, d), in_axis=1),
    }


def route(params: Params, dims: MoEDims, x_flat: jax.Array):
    """Top-k routing.  x_flat: (T, d) -> (gates (T,k) f32, ids (T,k) i32, aux)."""
    logits = (x_flat.astype(jnp.float32)) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, dims.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Load-balance aux loss (Switch): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(ids[:, 0], dims.n_experts, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = dims.n_experts * jnp.sum(me * ce)
    return gates, ids, aux


def _capacity(t_local: int, dims: MoEDims) -> int:
    cap = int(t_local * dims.top_k * dims.capacity_factor / dims.n_experts) + 1
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


def _expert_ffn(w_gate, w_up, w_down, h):
    """h: (E_local, C, d) -> (E_local, C, d); SwiGLU per expert (MXU einsums)."""
    dt_ = h.dtype
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, w_gate.astype(dt_)))
    u = jnp.einsum("ecd,edf->ecf", h, w_up.astype(dt_))
    return jnp.einsum("ecf,efd->ecd", g * u, w_down.astype(dt_))


def _moe_local(
    x_flat: jax.Array,
    gates: jax.Array,
    ids: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    e_start: jax.Array,
    n_experts_total: int,
    capacity: int,
) -> jax.Array:
    """Sort+scatter MoE over the local expert slice [e_start, e_start+E_local).

    x_flat: (T, d); gates/ids: (T, k); w_*: (E_local, ...).  Returns the local
    partial output (T, d) — zeros for tokens whose experts live elsewhere.
    """
    t, d = x_flat.shape
    k = ids.shape[1]
    e_local = w_gate.shape[0]
    ids_flat = ids.reshape(-1)  # (T*k,)
    gates_flat = gates.reshape(-1)

    # Stable sort by expert id; position-in-expert via cumsum over a small
    # (T*k, ) int workload (never a (T, E) one-hot).
    order = jnp.argsort(ids_flat, stable=True)
    sorted_ids = ids_flat[order]
    # counts per expert (global expert numbering), exclusive prefix.
    counts = jnp.bincount(sorted_ids, length=n_experts_total)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(t * k) - starts[sorted_ids]

    local = (sorted_ids >= e_start) & (sorted_ids < e_start + e_local)
    fits = pos_in_expert < capacity
    valid = local & fits
    slot = jnp.where(
        valid, (sorted_ids - e_start) * capacity + pos_in_expert, e_local * capacity
    )  # invalid -> one-past-end dump slot

    # Memory discipline: ONLY (E_local*C)-sized f32/bf16 tensors exist.  The
    # (T*k, d) "sorted tokens" tensor (7.5 GB for kimi's train_4k) is avoided
    # by building integer slot->token / slot->gate maps (int32, tiny) and
    # gathering straight into the buffers.
    token_idx = order // k  # original token of each routed slot
    slot_token = jnp.zeros((e_local * capacity + 1,), jnp.int32).at[slot].set(
        jnp.where(valid, token_idx, 0).astype(jnp.int32)
    )
    slot_gate = jnp.zeros((e_local * capacity + 1,), jnp.float32).at[slot].set(
        jnp.where(valid, gates_flat[order], 0.0)
    )  # zero gate on the dump slot and on unfilled capacity slots
    buffers = x_flat[slot_token[:-1]]  # (E_local*C, d) gather
    h = _expert_ffn(w_gate, w_up, w_down, buffers.reshape(e_local, capacity, d))
    h = h.reshape(-1, d) * slot_gate[:-1, None].astype(x_flat.dtype)
    # Combine: scatter-add each slot's weighted output back to its token.
    out = jnp.zeros((t, d), x_flat.dtype).at[slot_token[:-1]].add(h)
    return out


def _moe_dense_local(x_flat, gates, ids, w_gate, w_up, w_down, e_start):
    """Decode path: run all local experts on all tokens, router-masked."""
    e_local = w_gate.shape[0]
    t, d = x_flat.shape
    h = jnp.broadcast_to(x_flat[None], (e_local, t, d))
    y = _expert_ffn(w_gate, w_up, w_down, h)  # (E_local, T, d)
    local_expert = ids[None, :, :] == (
        jnp.arange(e_local)[:, None, None] + e_start
    )  # (E_local, T, k)
    w = jnp.sum(
        jnp.where(local_expert, gates[None, :, :], 0.0), axis=-1
    )  # (E_local, T)
    return jnp.einsum("etd,et->td", y, w.astype(x_flat.dtype))


def moe_apply(
    params: Params,
    dims: MoEDims,
    x: jax.Array,
    mesh: jax.sharding.Mesh | None = None,
    batch_axes: tuple[str, ...] = ("data",),
    expert_axis: str = "model",
    dense_path: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """MoE FFN.  x: (B, S, d) -> (out (B, S, d), aux loss scalar).

    With ``mesh``: expert-parallel via partial-manual shard_map (experts over
    ``expert_axis``, tokens over ``batch_axes``; d_model replicated on the
    expert axis).  Without: single-shard local compute (smoke tests).
    """
    b, s, d = x.shape
    if mesh is None:
        x_flat = x.reshape(-1, d)
        gates, ids, aux = route(params, dims, x_flat)
        if dense_path:
            out = _moe_dense_local(
                x_flat, gates, ids, params["w_gate"], params["w_up"],
                params["w_down"], jnp.asarray(0),
            )
        else:
            out = _moe_local(
                x_flat, gates, ids, params["w_gate"], params["w_up"],
                params["w_down"], jnp.asarray(0), dims.n_experts,
                _capacity(x_flat.shape[0], dims),
            )
        return out.reshape(b, s, d), aux

    ep = mesh.shape[expert_axis]
    assert dims.n_experts % ep == 0, (dims.n_experts, ep)
    dp = 1
    for ax in batch_axes:
        dp *= mesh.shape[ax]
    t_local = (b // dp) * s
    capacity = _capacity(t_local, dims)

    def body(x_shard, router, w_gate, w_up, w_down):
        bl, sl, _ = x_shard.shape
        x_flat = x_shard.reshape(-1, d)
        gates, ids, aux = route({"router": router}, dims, x_flat)
        idx = jax.lax.axis_index(expert_axis)
        e_start = idx * (dims.n_experts // ep)
        if dense_path:
            out = _moe_dense_local(x_flat, gates, ids, w_gate, w_up, w_down, e_start)
        else:
            out = _moe_local(
                x_flat, gates, ids, w_gate, w_up, w_down, e_start,
                dims.n_experts, capacity,
            )
        # Combine expert contributions across shards — the only collective.
        out = jax.lax.psum(out, expert_axis)
        aux = jax.lax.psum(aux, expert_axis) / ep
        return out.reshape(bl, sl, d), aux

    # Full-manual over (batch axes + expert axis).  When the batch is not
    # divisible (e.g. B=1 long-context decode) tokens replicate across the
    # data axes and every data shard computes identically — out_spec stays
    # replicated there, which holds by construction.
    shardable = b % dp == 0 and b >= dp
    batch_spec = P(batch_axes if shardable else None, None, None)
    if not shardable:
        t_local = b * s
        capacity = _capacity(t_local, dims)
    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(batch_spec, P(), P(expert_axis), P(expert_axis), P(expert_axis)),
        out_specs=(batch_spec, P()),
        axis_names={expert_axis, *batch_axes},
        check_vma=False,
    )
    return fn(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])


def router_init_from_ckm(centroids: jax.Array, d_model: int) -> jax.Array:
    """Router weights from compressively-clustered hidden states (paper tie-in).

    ``centroids``: (E, d) CKM centroids of a stream of token activations (see
    train/monitor.py).  The router logit for expert e is the inner product
    with its centroid — k-means-style cluster assignment as routing prior.
    """
    c = centroids / jnp.maximum(jnp.linalg.norm(centroids, axis=1, keepdims=True), 1e-6)
    return c.T.astype(jnp.float32)  # (d, E)
