"""Recurrent sequence mixers: Mamba (selective SSM), mLSTM, sLSTM.

TPU adaptation notes (DESIGN.md §3):
- Mamba's diagonal recurrence runs as a ``lax.scan`` over fixed-size chunks
  with a ``lax.associative_scan`` *within* each chunk — the (B, S, d_in, d_state)
  tensor never materialises for the full sequence, only (B, chunk, d_in, d_state).
- mLSTM uses the chunkwise gated-linear-attention form: O(chunk^2) intra-chunk
  attention on the MXU + an O(1) carried matrix state between chunks.  Gate
  exponents are computed as *differences* (always <= 0 after clamping), so no
  unstable exp(+big) ever appears.  The exponential input gate of the paper is
  replaced by a clamped sigmoid gate for bf16 stability (noted in DESIGN.md).
- sLSTM has no parallel form (by design, per the xLSTM paper): the W x term is
  precomputed for the whole sequence in one matmul; only the h R recurrence
  runs sequentially.  This shows up honestly in the roofline (§Perf).

All mixers expose ``init``, ``apply`` (full sequence -> outputs + final state)
and ``step`` (single-token decode).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Mamba (selective SSM), as in Jamba's Mamba layers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MambaDims:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(self.d_model // 16, 1)


def init_mamba(key, dims: MambaDims) -> Params:
    ks = jax.random.split(key, 6)
    di, ds, dr = dims.d_inner, dims.d_state, dims.dt_rank
    return {
        "in_proj": _dense_init(ks[0], (dims.d_model, 2 * di)),
        "conv_w": jax.random.normal(ks[1], (dims.d_conv, di), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": _dense_init(ks[2], (di, dr + 2 * ds)),
        "dt_proj": _dense_init(ks[3], (dr, di)),
        "dt_bias": jnp.full((di,), -2.0, jnp.float32),  # softplus(-2) ~ 0.13
        "a_log": jnp.log(
            jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
        ),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, dims.d_model)),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv over time.  x: (B, S, di); w: (dconv, di).

    ``state``: (B, dconv-1, di) trailing context from a previous segment.
    Returns (y, new_state).
    """
    dconv = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], dconv - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(dconv)
    )
    new_state = xp[:, -(dconv - 1) :, :]
    return y + b.astype(x.dtype), new_state


def _ssm_scan_chunked(dt, b_in, c_in, xc, a, h0, chunk):
    """Selective-SSM recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,
    y_t = h_t . C_t — chunked so the (B, *, di, ds) tensors only ever exist
    for one chunk at a time (built lazily inside the scan body).

    dt: (B,S,di) f32; b_in,c_in: (B,S,ds); xc: (B,S,di); a: (di,ds) f32.
    Returns (y (B,S,di) f32, h_final (B,di,ds) f32).
    """

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    def chunk_body(h, xs_):
        dt_c, b_c, c_c, x_c = xs_  # (B,c,di), (B,c,ds), (B,c,ds), (B,c,di)
        a_bar = jnp.exp(dt_c[..., None] * a)  # (B,c,di,ds) — chunk only
        bx = (
            dt_c[..., None]
            * b_c[:, :, None, :].astype(jnp.float32)
            * x_c[..., None].astype(jnp.float32)
        )
        a_cum, b_cum = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
        h_all = a_cum * h[:, None] + b_cum  # (B,c,di,ds)
        y = jnp.sum(h_all * c_c[:, :, None, :].astype(jnp.float32), axis=-1)
        return h_all[:, -1], y

    b, s = dt.shape[0], dt.shape[1]
    nch = s // chunk
    split = lambda t: t.reshape(b, nch, chunk, *t.shape[2:]).swapaxes(0, 1)
    h_final, ys = jax.lax.scan(
        chunk_body, h0, (split(dt), split(b_in), split(c_in), split(xc))
    )
    y_seq = ys.swapaxes(0, 1).reshape(b, s, -1)
    return y_seq, h_final


def mamba_apply(
    params: Params, dims: MambaDims, x: jax.Array, state: Params | None = None
) -> tuple[jax.Array, Params]:
    """Full-sequence Mamba mixer.  x: (B, S, d_model) -> (out, final state)."""
    b, s, _ = x.shape
    dt_ = x.dtype
    di, ds, dr = dims.d_inner, dims.d_state, dims.dt_rank
    xz = x @ params["in_proj"].astype(dt_)
    xs_, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xc, conv_state = _causal_conv(xs_, params["conv_w"], params["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    dbc = xc @ params["x_proj"].astype(dt_)
    dt_raw, b_ssm, c_ssm = jnp.split(dbc, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(
        dt_raw @ params["dt_proj"].astype(dt_) + params["dt_bias"].astype(dt_)
    ).astype(jnp.float32)  # (B, S, di)
    a = -jnp.exp(params["a_log"])  # (di, ds)
    h0 = (
        jnp.zeros((b, di, ds), jnp.float32)
        if state is None
        else state["ssm"].astype(jnp.float32)
    )
    chunk = min(dims.chunk, s)
    pad = (-s) % chunk
    if pad:
        # dt = 0 on padding -> a_bar = 1, bx = 0: state passes through unchanged.
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_ssm = jnp.pad(b_ssm, ((0, 0), (0, pad), (0, 0)))
        c_ssm = jnp.pad(c_ssm, ((0, 0), (0, pad), (0, 0)))
        xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    else:
        xc_p = xc
    y, h_final = _ssm_scan_chunked(dt, b_ssm, c_ssm, xc_p, a, h0, chunk)
    y = y[:, :s].astype(dt_) + params["d_skip"].astype(dt_) * xc
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dt_)
    return out, {"conv": conv_state, "ssm": h_final}


def mamba_init_state(dims: MambaDims, batch: int, dtype) -> Params:
    return {
        "conv": jnp.zeros((batch, dims.d_conv - 1, dims.d_inner), dtype),
        "ssm": jnp.zeros((batch, dims.d_inner, dims.d_state), jnp.float32),
    }


def mamba_step(
    params: Params, dims: MambaDims, x: jax.Array, state: Params
) -> tuple[jax.Array, Params]:
    """Single-token decode.  x: (B, 1, d_model)."""
    out, new_state = mamba_apply(params, dims, x, state)
    return out, new_state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell), chunkwise gated linear attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLSTMDims:
    d_model: int
    n_heads: int = 4
    expand: int = 2
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


def init_mlstm(key, dims: MLSTMDims) -> Params:
    ks = jax.random.split(key, 7)
    d, di, h = dims.d_model, dims.d_inner, dims.n_heads
    return {
        "up_proj": _dense_init(ks[0], (d, di)),
        "wq": _dense_init(ks[1], (di, di)),
        "wk": _dense_init(ks[2], (di, di)),
        "wv": _dense_init(ks[3], (di, di)),
        "w_gates": _dense_init(ks[4], (d, 2 * h)),  # (input, forget) per head
        "w_ogate": _dense_init(ks[5], (d, di)),
        "down_proj": _dense_init(ks[6], (di, d)),
    }


def _mlstm_chunk(q, k, v, log_f, log_i, state):
    """One chunk of the stabilised GLA recurrence.

    q,k,v: (B, c, H, hd);  log_f, log_i: (B, c, H) f32 (log_f <= 0).
    state: {"C": (B,H,hd,hd) f32, "n": (B,H,hd) f32}.
    """
    bsz, c, h, hd = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    cum_f = jnp.cumsum(log_f, axis=1)  # (B, c, H), inclusive
    # Intra-chunk: gate(i,j) = exp(cum_f[i] - cum_f[j] + log_i[j]) for j <= i.
    # Exponent <= 0 (log_f <= 0, log_i <= 0) -> no overflow, computed directly.
    expo = cum_f[:, :, None, :] - cum_f[:, None, :, :] + log_i[:, None, :, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    gate = jnp.where(mask[None, :, :, None], jnp.exp(expo), 0.0)  # (B,c,c,H)
    scores = jnp.einsum("bihd,bjhd->bijh", qf, kf) * gate
    h_intra = jnp.einsum("bijh,bjhd->bihd", scores, vf)
    n_intra = jnp.einsum("bijh,bjhd->bihd", gate, kf)
    # Inter-chunk: decayed read of the carried state.
    decay_q = jnp.exp(cum_f)  # (B, c, H)
    h_inter = jnp.einsum("bihd,bhde->bihe", qf, state["C"]) * decay_q[..., None]
    n_inter = state["n"][:, None] * decay_q[..., None]
    # Normaliser: h / max(|n . q|, 1)  (xLSTM normalised read-out).
    n_tot = n_intra + n_inter
    denom = jnp.maximum(jnp.abs(jnp.sum(n_tot * qf, axis=-1, keepdims=True)), 1.0)
    out = (h_intra + h_inter) / denom
    # State update to the end of the chunk (exponents again <= 0).
    decay_all = cum_f[:, -1:, :] - cum_f + log_i  # (B, c, H)
    wgt = jnp.exp(decay_all)
    c_new = state["C"] * jnp.exp(cum_f[:, -1])[..., None, None] + jnp.einsum(
        "bjh,bjhd,bjhe->bhde", wgt, kf, vf
    )
    n_new = state["n"] * jnp.exp(cum_f[:, -1])[..., None] + jnp.einsum(
        "bjh,bjhd->bhd", wgt, kf
    )
    return out, {"C": c_new, "n": n_new}


def mlstm_apply(
    params: Params, dims: MLSTMDims, x: jax.Array, state: Params | None = None
) -> tuple[jax.Array, Params]:
    b, s, _ = x.shape
    dt_ = x.dtype
    h, hd, di = dims.n_heads, dims.head_dim, dims.d_inner
    u = jax.nn.silu(x @ params["up_proj"].astype(dt_))
    q = (u @ params["wq"].astype(dt_)).reshape(b, s, h, hd)
    k = (u @ params["wk"].astype(dt_)).reshape(b, s, h, hd) / jnp.sqrt(hd).astype(dt_)
    v = (u @ params["wv"].astype(dt_)).reshape(b, s, h, hd)
    gates = (x @ params["w_gates"].astype(dt_)).astype(jnp.float32)
    log_i = jax.nn.log_sigmoid(gates[..., :h])  # clamped input gate (<=0)
    log_f = jnp.maximum(jax.nn.log_sigmoid(gates[..., h:]), -8.0)

    if state is None:
        state = mlstm_init_state(dims, b)
    c = min(dims.chunk, s)
    pad = (-s) % c
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-30.0)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    nch = q.shape[1] // c

    def body(st, xs_):
        qc, kc, vc, lfc, lic = xs_
        out, st = _mlstm_chunk(qc, kc, vc, lfc, lic, st)
        return st, out

    split = lambda t: t.reshape(b, nch, c, *t.shape[2:]).swapaxes(0, 1)
    state, outs = jax.lax.scan(
        body, state, (split(q), split(k), split(v), split(log_f), split(log_i))
    )
    out = outs.swapaxes(0, 1).reshape(b, nch * c, h, hd)[:, :s]
    out = out.reshape(b, s, di).astype(dt_)
    ogate = jax.nn.sigmoid(x @ params["w_ogate"].astype(dt_))
    return (out * ogate) @ params["down_proj"].astype(dt_), state


def mlstm_init_state(dims: MLSTMDims, batch: int) -> Params:
    h, hd = dims.n_heads, dims.head_dim
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
    }


def mlstm_step(params, dims: MLSTMDims, x, state):
    """Single-token decode: the chunkwise path with chunk == 1."""
    out, state = mlstm_apply(params, dims, x, state)
    return out, state


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar cell) — sequential by construction
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLSTMDims:
    d_model: int
    heads: int = 4  # block-diagonal recurrence, as in the xLSTM paper

    @property
    def head_dim(self) -> int:
        return self.d_model // self.heads


def init_slstm(key, dims: SLSTMDims) -> Params:
    k1, k2 = jax.random.split(key)
    d, h, dh = dims.d_model, dims.heads, dims.head_dim
    return {
        "w": _dense_init(k1, (d, 4 * d)),  # i, f, z, o from x (precomputable)
        # Block-diagonal recurrent matrix (xLSTM §"sLSTM": heads don't mix
        # through R): 4x fewer recurrent weights AND 4x less of the per-step
        # HBM re-read that dominates this arch's roofline (EXPERIMENTS §Perf).
        "r": _dense_init(k2, (h, dh, 4 * dh), in_axis=1) * 0.1,
        "b": jnp.zeros((4 * d,), jnp.float32),
    }


def _slstm_cell(params, wx_t, st):
    """One timestep.  wx_t: (B, 4d) precomputed W x_t.  st: dict of (B, d)."""
    d = st["h"].shape[-1]
    r = params["r"]
    h_heads = st["h"].reshape(st["h"].shape[0], r.shape[0], r.shape[1])
    rec = jnp.einsum(
        "bhd,hde->bhe", h_heads.astype(wx_t.dtype), r.astype(wx_t.dtype)
    )  # (B, H, 4*dh)
    # reorder per-head [i|f|z|o] blocks into the (B, 4d) layout of W x.
    rec = rec.reshape(rec.shape[0], r.shape[0], 4, -1)  # (B, H, 4, dh)
    rec = rec.transpose(0, 2, 1, 3).reshape(rec.shape[0], 4 * d)
    gates = (wx_t + rec).astype(jnp.float32) + params["b"]
    i_log, f_log, z_raw, o_raw = jnp.split(gates, 4, axis=-1)
    f_log = jax.nn.log_sigmoid(f_log)
    m_new = jnp.maximum(f_log + st["m"], i_log)
    i_g = jnp.exp(i_log - m_new)
    f_g = jnp.exp(f_log + st["m"] - m_new)
    c_new = f_g * st["c"] + i_g * jnp.tanh(z_raw)
    n_new = jnp.maximum(f_g * st["n"] + i_g, 1e-6)
    h_new = jax.nn.sigmoid(o_raw) * c_new / n_new
    return {"h": h_new.astype(st["h"].dtype), "c": c_new, "n": n_new, "m": m_new}


def slstm_apply(
    params: Params, dims: SLSTMDims, x: jax.Array, state: Params | None = None
) -> tuple[jax.Array, Params]:
    b, s, d = x.shape
    wx = x @ params["w"].astype(x.dtype)  # (B, S, 4d): one big MXU matmul
    if state is None:
        state = slstm_init_state(dims, b, x.dtype)

    def body(st, wx_t):
        st = _slstm_cell(params, wx_t, st)
        return st, st["h"]

    # Checkpoint the cell: the backward scan then saves only the (h,c,n,m)
    # carry per step and recomputes the gate nonlinearities — roughly halves
    # the stacked f32 residual traffic that dominates this arch (§Perf).
    state, hs = jax.lax.scan(jax.checkpoint(body), state, wx.swapaxes(0, 1))
    return hs.swapaxes(0, 1).astype(x.dtype), state


def slstm_init_state(dims: SLSTMDims, batch: int, dtype) -> Params:
    d = dims.d_model
    return {
        "h": jnp.zeros((batch, d), dtype),
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def slstm_step(params, dims: SLSTMDims, x, state):
    """x: (B, 1, d)."""
    wx = x[:, 0] @ params["w"].astype(x.dtype)
    state = _slstm_cell(params, wx, state)
    return state["h"][:, None, :].astype(x.dtype), state
