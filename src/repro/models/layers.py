"""Transformer building blocks: RMSNorm, RoPE, GQA attention (global / sliding
-window, train / prefill / decode), SwiGLU MLP, embeddings.

Conventions
-----------
- Pure functional: ``init_*`` returns a param pytree; ``*_apply`` consumes it.
- Activations default to bf16; params and softmax/norm statistics in f32.
- Attention is q-block-chunked with lazily materialised masks so a 32k-token
  prefill never builds an (S, S) mask or score matrix; block size is a config.
- Sharding is applied OUTSIDE via GSPMD constraints (parallel/sharding.py);
  layer code stays mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, in_axis=0):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(
        jnp.float32
    )


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 *statistics* but activation-dtype application.

    Upcasting the whole tensor to f32 (the naive way) makes XLA materialise
    and reshard full f32 activations around every layer — measured as the
    second-largest HBM term at mistral scale.  The variance reduction stays
    exact in f32; the normalisation multiply runs in the activation dtype.
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


@jax.custom_vjp
def _softmax_bf16(scores: jax.Array) -> jax.Array:
    """Softmax over the last axis: f32 math inside, bf16 in/out, and —
    crucially — only the bf16 PROBS are saved for backward (plain
    jax.nn.softmax saves its f32 output as the VJP residual, doubling the
    dominant attention HBM term)."""
    x = scores.astype(jnp.float32)
    p = jax.nn.softmax(x, axis=-1)
    return p.astype(jnp.bfloat16)


def _softmax_bf16_fwd(scores):
    p = _softmax_bf16(scores)
    return p, p


def _softmax_bf16_bwd(p, g):
    pf = p.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    dot = jnp.sum(pf * gf, axis=-1, keepdims=True)
    return ((pf * (gf - dot)).astype(jnp.bfloat16),)


_softmax_bf16.defvjp(_softmax_bf16_fwd, _softmax_bf16_bwd)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..,S,1,half)
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    window: int = 0  # 0 -> global causal; >0 -> sliding window
    rope_theta: float = 1e4
    q_block: int = 512  # query chunk for lazy-mask attention
    score_dtype: str = "f32"  # storage dtype of QK^T blocks (see ModelConfig)


def init_attention(key, dims: AttnDims) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, kvh, hd = dims.d_model, dims.n_heads, dims.n_kv_heads, dims.head_dim
    return {
        "wq": _dense_init(kq, (d, h * hd)),
        "wk": _dense_init(kk, (d, kvh * hd)),
        "wv": _dense_init(kv, (d, kvh * hd)),
        "wo": _dense_init(ko, (h * hd, d)),
    }


def _noshard(x, kind):
    return x


def _qkv(params, dims: AttnDims, x, positions, shard=_noshard):
    b, s, _ = x.shape
    h, kvh, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    q = shard((x @ params["wq"].astype(x.dtype)).reshape(b, s, h, hd), "heads")
    k = shard((x @ params["wk"].astype(x.dtype)).reshape(b, s, kvh, hd), "kv")
    v = shard((x @ params["wv"].astype(x.dtype)).reshape(b, s, kvh, hd), "kv")
    q = rope(q, positions, dims.rope_theta)
    k = rope(k, positions, dims.rope_theta)
    return q, k, v


def _attend_block(q_blk, k, v, q_pos, k_pos, dims: AttnDims, causal: bool):
    """q_blk: (B, bq, H, hd); k/v: (B, S, KV, hd). Lazy mask via positions.

    The mask enters as an ADDITIVE f32 bias: addition is linear, so autodiff
    saves no residual for it — a boolean `where` mask would be stacked across
    the q-block scan as an (nblk, B, KV, rep, bq, S) pred residual (terabytes
    at 4k x 256).
    """
    h, kvh = dims.n_heads, dims.n_kv_heads
    rep = h // kvh
    b, bq, _, hd = q_blk.shape
    s = k.shape[1]
    qh = q_blk.reshape(b, bq, kvh, rep, hd)
    # Score storage dtype: the QK^T block is the fusion boundary that
    # dominates HBM traffic at training shapes; bf16 storage halves it.
    # Softmax statistics are always computed in f32 inside the fusion.
    mask = jnp.ones((bq, s), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if dims.window > 0:
        mask &= (q_pos[:, None] - k_pos[None, :]) < dims.window
    if dims.score_dtype == "bf16":
        # bf16 score storage + a softmax whose VJP residual is the bf16
        # probs (a TPU MXU emits bf16 dots directly; plain f32 softmax saves
        # f32 probs — the dominant train-cell HBM term).
        scale = (1.0 / jnp.sqrt(hd)).astype(jnp.bfloat16)
        scores = jnp.einsum("bqkrh,bskh->bkrqs", qh * scale.astype(qh.dtype), k)
        bias = jnp.where(mask, 0.0, -3e38).astype(jnp.bfloat16)
        scores = (scores.astype(jnp.bfloat16) + bias[None, None, None])
        scores = jax.lax.optimization_barrier(scores)
        probs = _softmax_bf16(scores).astype(v.dtype)
    else:
        scores = jnp.einsum("bqkrh,bskh->bkrqs", qh, k).astype(jnp.float32)
        scores *= 1.0 / jnp.sqrt(hd)
        bias = jnp.where(mask, 0.0, -1e30).astype(jnp.float32)  # (bq, s)
        scores = scores + bias[None, None, None]
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, v)
    return out.reshape(b, bq, h * hd)


def attention_apply(
    params: Params,
    dims: AttnDims,
    x: jax.Array,
    positions: jax.Array,
    causal: bool = True,
    return_kv: bool = False,
    shard=_noshard,
):
    """Training/prefill attention, q-chunked (no (S,S) materialisation)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, dims, x, positions, shard)
    blk = min(dims.q_block, s)
    pad = (-s) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = q.shape[1] // blk
    kpos = positions[0] if positions.ndim > 1 else positions

    def body(carry, i):
        qb = jax.lax.dynamic_slice_in_dim(q, i * blk, blk, axis=1)
        qpos = jax.lax.dynamic_slice_in_dim(kpos, i * blk, blk)
        # Padded tail queries read garbage positions; output sliced off below.
        qpos = jnp.where(jnp.arange(blk) + i * blk < s, qpos, kpos[-1])
        return carry, _attend_block(qb, k, v, qpos, kpos, dims, causal)

    _, outs = jax.lax.scan(body, None, jnp.arange(nblk))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nblk * blk, -1)[:, :s]
    out = out @ params["wo"].astype(x.dtype)
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(
    params: Params,
    dims: AttnDims,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    index: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode step against a KV cache.

    x: (B, 1, d); cache_k/v: (B, S_cache, KV, hd); index: () current position.
    Returns (out (B, 1, d), new_cache_k, new_cache_v).  For sliding-window
    layers the cache is a ring buffer of size ``window``.
    """
    b = x.shape[0]
    h, kvh, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    s_cache = cache_k.shape[1]
    pos = jnp.full((b, 1), index, jnp.int32)
    q, k_new, v_new = _qkv(params, dims, x, pos)
    slot = index % s_cache if dims.window > 0 else index
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot, axis=1)

    rep = h // kvh
    qh = q.reshape(b, 1, kvh, rep, hd)
    scores = jnp.einsum("bqkrh,bskh->bkrqs", qh, cache_k).astype(jnp.float32)
    scores *= 1.0 / jnp.sqrt(hd)
    cache_pos = jnp.arange(s_cache)
    if dims.window > 0:
        # Ring buffer: slot i holds absolute position matching (index - delta).
        valid = (cache_pos <= slot) | (index >= s_cache)
        in_window = jnp.ones_like(valid)  # ring size == window
        mask = valid & in_window
    else:
        mask = cache_pos <= index
    scores = jnp.where(mask[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, cache_v).reshape(b, 1, h * hd)
    return out @ params["wo"].astype(x.dtype), cache_k, cache_v


def cross_attention_apply(
    params: Params, dims: AttnDims, x: jax.Array, enc_kv: tuple[jax.Array, jax.Array]
) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (whisper)."""
    b, s, _ = x.shape
    h, kvh, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k, v = enc_kv
    rep = h // kvh
    qh = q.reshape(b, s, kvh, rep, hd)
    scores = jnp.einsum("bqkrh,bskh->bkrqs", qh, k).astype(jnp.float32)
    scores *= 1.0 / jnp.sqrt(hd)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, v).reshape(b, s, h * hd)
    return out @ params["wo"].astype(x.dtype)


def encoder_kv(params: Params, dims: AttnDims, enc_out: jax.Array):
    b, s, _ = enc_out.shape
    kvh, hd = dims.n_kv_heads, dims.head_dim
    k = (enc_out @ params["wk"].astype(enc_out.dtype)).reshape(b, s, kvh, hd)
    v = (enc_out @ params["wv"].astype(enc_out.dtype)).reshape(b, s, kvh, hd)
    return k, v


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d, ff)),
        "w_up": _dense_init(k2, (d, ff)),
        "w_down": _dense_init(k3, (ff, d)),
    }


def mlp_apply(params: Params, x: jax.Array, shard=_noshard) -> jax.Array:
    dt = x.dtype
    gate = shard(jax.nn.silu(x @ params["w_gate"].astype(dt)), "ffn")
    up = shard(x @ params["w_up"].astype(dt), "ffn")
    return (gate * up) @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(params: Params, tokens: jax.Array, dtype) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed(params: Params, x: jax.Array) -> jax.Array:
    return x @ params["table"].astype(x.dtype).T


def init_lm_head(key, d: int, vocab: int) -> Params:
    return {"w": _dense_init(key, (d, vocab))}


def lm_head(params: Params, x: jax.Array) -> jax.Array:
    return x @ params["w"].astype(x.dtype)
