"""Optimizers, self-contained (no optax): AdamW (f32 or int8-quantised state),
Adafactor (factored second moment — the 1T-param option), SGD; warmup-cosine
schedule; global-norm clipping.

State sharding: optimizer state mirrors the parameter shardings (FSDP+TP, see
parallel/sharding.py), so ZeRO-style memory scaling falls out of GSPMD.  For
the largest archs the dry-run uses either Adafactor or int8 Adam states
(blockwise-quantised m/v, 4x smaller) so 1T params fit 512 x 16 GB (DESIGN.md
§5); both are exact drop-ins here.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any
_QBLOCK = 128  # block size for int8 state quantisation
# Per-leaf updates bigger than this (bytes, f32-upcast) run as a lax.map over
# the leading (layer-group) axis: a (61, 384, 7168, 2048) stacked MoE leaf
# would otherwise materialise ~5 GB x several f32 temporaries at once.
_CHUNK_UPDATE_BYTES = 1 << 28


def _chunked_leaf_update(upd, p, *args):
    """Apply ``upd(p_slice, *arg_slices)`` over axis 0 when the leaf is huge."""
    if p.ndim >= 3 and p.shape[0] > 1 and p.size * 4 > _CHUNK_UPDATE_BYTES:
        return jax.lax.map(lambda xs: upd(*xs), (p, *args))
    return upd(p, *args)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def warmup_cosine(base_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, base_lr * cos)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


# ---------------------------------------------------------------------------
# int8 blockwise quantisation for optimizer state
# ---------------------------------------------------------------------------


class Q8(NamedTuple):
    q: jax.Array  # int8 payload, original shape
    scale: jax.Array  # f32 per-block max-abs, shape (..., n_blocks)


def _quantize(x: jax.Array, sqrt_domain: bool = False) -> Q8:
    """Blockwise max-abs int8.  ``sqrt_domain`` compresses the dynamic range
    quadratically — used for Adam's second moment (v ~ g^2 spans too many
    decades for linear int8)."""
    flat = x.reshape(-1)
    if sqrt_domain:
        flat = jnp.sqrt(jnp.maximum(flat, 0.0))
    pad = (-flat.shape[0]) % _QBLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, _QBLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True)
    q = jnp.round(fp / jnp.maximum(scale, 1e-12) * 127.0).astype(jnp.int8)
    return Q8(q, scale[:, 0])


def _dequantize(qs: Q8, shape, sqrt_domain: bool = False) -> jax.Array:
    import math

    fp = qs.q.astype(jnp.float32) * (qs.scale[:, None] / 127.0)
    fp = fp.reshape(-1)[: math.prod(shape)].reshape(shape)
    if sqrt_domain:
        fp = fp * fp
    return fp


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adamw8 | adafactor | sgd
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params, jax.Array], tuple[Params, Any, dict]]


def _adamw(cfg: OptConfig, quantized: bool) -> Optimizer:
    lr_fn = warmup_cosine(cfg.lr, cfg.warmup, cfg.total_steps)

    def init(params):
        if quantized:
            mk = jax.tree.map(lambda p: _quantize(jnp.zeros_like(p, jnp.float32)), params)
            vk = jax.tree.map(lambda p: _quantize(jnp.zeros_like(p, jnp.float32)), params)
        else:
            mk = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
            vk = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": mk, "v": vk, "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _step):
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        count = state["count"] + 1
        lr = lr_fn(count)
        b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            mf = _dequantize(m, p.shape) if quantized else m
            vf = _dequantize(v, p.shape, sqrt_domain=True) if quantized else v
            mf = cfg.b1 * mf + (1 - cfg.b1) * g
            vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
            step_ = lr * (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
            newp = p.astype(jnp.float32) - step_ - lr * cfg.weight_decay * p.astype(
                jnp.float32
            )
            return (
                newp.astype(p.dtype),
                _quantize(mf) if quantized else mf,
                _quantize(vf, sqrt_domain=True) if quantized else vf,
            )

        pflat, tree = jax.tree.flatten(params)
        gflat = jax.tree.leaves(grads)
        mflat = tree.flatten_up_to(state["m"])
        vflat = tree.flatten_up_to(state["v"])
        outs = [
            upd(p, g, m, v)
            if quantized
            else _chunked_leaf_update(upd, p, g, m, v)
            for p, g, m, v in zip(pflat, gflat, mflat, vflat)
        ]
        newp = tree.unflatten([o[0] for o in outs])
        newm = tree.unflatten([o[1] for o in outs])
        newv = tree.unflatten([o[2] for o in outs])
        return newp, {"m": newm, "v": newv, "count": count}, {"lr": lr, "gnorm": gnorm}

    return Optimizer(init, update)


def _adafactor(cfg: OptConfig) -> Optimizer:
    """Factored second-moment (Shazeer & Stern): O(rows+cols) state for 2D+."""
    lr_fn = warmup_cosine(cfg.lr, cfg.warmup, cfg.total_steps)

    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {
            "stats": jax.tree.map(st, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, _step):
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        count = state["count"] + 1
        lr = lr_fn(count)
        decay = 1.0 - count.astype(jnp.float32) ** -0.8

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + 1e-30
            if p.ndim >= 2:
                vr = decay * s["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * s["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., :, None]
                    * vc[..., None, :]
                    / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)[
                        ..., None
                    ]
                )
                step_ = lr * g / jnp.maximum(denom, 1e-30)
                news = {"vr": vr, "vc": vc}
            else:
                v = decay * s["v"] + (1 - decay) * g2
                step_ = lr * g / (jnp.sqrt(v) + 1e-30)
                news = {"v": v}
            newp = p.astype(jnp.float32) - step_ - lr * cfg.weight_decay * p.astype(
                jnp.float32
            )
            return newp.astype(p.dtype), news

        flat, tree = jax.tree.flatten(params)
        gflat = jax.tree.leaves(grads)
        sflat = tree.flatten_up_to(state["stats"])
        outs = [
            _chunked_leaf_update(upd, p, g, s) for p, g, s in zip(flat, gflat, sflat)
        ]
        newp = tree.unflatten([o[0] for o in outs])
        news = tree.unflatten([o[1] for o in outs])
        return newp, {"stats": news, "count": count}, {"lr": lr, "gnorm": gnorm}

    return Optimizer(init, update)


def _sgd(cfg: OptConfig) -> Optimizer:
    lr_fn = warmup_cosine(cfg.lr, cfg.warmup, cfg.total_steps)

    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _step):
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        count = state["count"] + 1
        lr = lr_fn(count)
        newp = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(
                p.dtype
            ),
            params,
            grads,
        )
        return newp, {"count": count}, {"lr": lr, "gnorm": gnorm}

    return Optimizer(init, update)


def make_optimizer(cfg: OptConfig) -> Optimizer:
    if cfg.name == "adamw":
        return _adamw(cfg, quantized=False)
    if cfg.name == "adamw8":
        return _adamw(cfg, quantized=True)
    if cfg.name == "adafactor":
        return _adafactor(cfg)
    if cfg.name == "sgd":
        return _sgd(cfg)
    raise ValueError(cfg.name)
