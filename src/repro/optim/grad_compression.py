"""Cross-pod gradient compression with error feedback.

Within a pod, ICI is fast — gradients reduce exactly (GSPMD-inserted
collectives over the "data"/"model" axes).  ACROSS pods (DCI, ~5-10x slower),
gradients are quantised to int16 with a shared max-abs scale before the
exchange; the quantisation error is fed back into the next step (error
feedback preserves convergence — Karimireddy et al. 2019).  Wire traffic
halves vs f32; the int16 grid at 8 fractional bits keeps single-step error
below 2^-8 of max|g| even before feedback.

Mechanics (inside a partial-manual ``shard_map`` over the "pod" axis only —
data/model sharding stays automatic; check_vma=True, so the cross-pod sum
must be a *provably invariant* collective, i.e. a psum):

  g_pod   = grad(loss)(params, pod-local batch)      # per-pod gradients
  gt      = g_pod + err_carry
  scale   = pmax(max|gt|) / 2^14                     # one scalar psum
  q       = round(gt / scale) : int16                # |q| <= 2^14
  sum     = psum(q) * scale                          # 2-byte wire traffic
  err     = gt - q * scale                           # stays pod-local

|q| <= 2^14 leaves 2 headroom bits: exact for psums of up to 4 pods at full
scale and safe to 2^15/2^14 = 2 pods worst-case adversarial; in practice
gradient max-norms across pods are near-identical.  The error state is stored
with a leading pod axis (sharded P("pod")) so each pod carries ITS residual
across steps; it checkpoints like everything else.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_QMAX = float(1 << 13)  # 13-bit payload: the int16 psum of 2-4 pods can't wrap


def compress_allreduce_tree(grads, err, axis: str):
    """int16 EF all-reduce of a grad pytree over ``axis`` (call inside
    shard_map manual on ``axis``).  ``err`` leaves carry a leading pod dim of
    size 1 (this pod's slice).  Returns (summed grads, new err)."""

    def one(g, e):
        gt = g.astype(jnp.float32) + e[0]
        amax = jax.lax.pmax(jnp.max(jnp.abs(gt)), axis)
        scale = jnp.maximum(amax / _QMAX, 1e-30)
        q = jnp.clip(jnp.round(gt / scale), -_QMAX, _QMAX).astype(jnp.int16)
        total = jax.lax.psum(q, axis).astype(jnp.float32) * scale  # int16 wire
        new_err = (gt - q.astype(jnp.float32) * scale)[None]
        return total.astype(g.dtype), new_err

    pairs = jax.tree.map(one, grads, err)
    summed = jax.tree.map(
        lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_err = jax.tree.map(
        lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    return summed, new_err


def init_error_state(grads_shape, n_pods: int):
    """Zero error-feedback state: leading pod axis, sharded P('pod', ...)."""
    return jax.tree.map(
        lambda g: jnp.zeros((n_pods, *g.shape), jnp.float32), grads_shape
    )


def error_state_specs(grads_specs):
    def spec(s):
        return P("pod", *tuple(s))

    return jax.tree.map(spec, grads_specs, is_leaf=lambda x: isinstance(x, P))
