"""Fault-tolerant checkpointing: atomic, async, elastic.

- ATOMIC: writes land in ``step_<k>.tmp`` and are renamed to ``step_<k>`` only
  after the manifest fsyncs — a preempted writer can never leave a torn
  checkpoint that restore would pick up.
- ASYNC: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes to disk on a daemon thread — the train loop keeps stepping.
- ELASTIC: leaves are stored UNSHARDED (gathered) with their logical
  PartitionSpecs in the manifest; ``restore`` re-places them onto whatever
  mesh the restart has (16x16 today, 2x16x16 tomorrow) — resharding is a
  device_put, not a format migration.
- RETENTION: ``keep`` newest checkpoints are retained, older ones pruned.

On a multi-host cluster the gather/write would be per-host-shard (same layout,
one file per shard); this container is single-process so leaves arrive whole.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(
        self,
        step: int,
        state: Any,
        specs: Any | None = None,
        meta: dict | None = None,
    ):
        """Synchronous atomic save.

        ``meta``: optional JSON-serialisable dict stored verbatim in the
        manifest and returned by :meth:`read_meta` — the slot for state that
        is not an array leaf (a tenant's ``FreqOpSpec`` recipe, quantizer bit
        width, version counters).  ``specs`` remain repr-only provenance.
        """
        self.wait()
        self._write(step, self._snapshot(state), specs, meta)

    def save_async(
        self,
        step: int,
        state: Any,
        specs: Any | None = None,
        meta: dict | None = None,
    ):
        """Snapshot now (device->host), write on a daemon thread."""
        self.wait()
        snap = self._snapshot(state)
        self._thread = threading.Thread(
            target=self._write, args=(step, snap, specs, meta), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _snapshot(self, state: Any):
        leaves, treedef = _flatten(state)
        return [np.asarray(jax.device_get(l)) for l in leaves], treedef

    def _write(self, step: int, snap, specs, meta=None):
        leaves, treedef = snap
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(
                jax.tree_util.tree_unflatten(treedef, list(range(len(leaves))))
            ).__repr__(),
            "leaves": [],
        }
        for i, leaf in enumerate(leaves):
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, leaf)
            manifest["leaves"].append(
                {"file": fname, "dtype": str(leaf.dtype), "shape": list(leaf.shape)}
            )
        if specs is not None:
            spec_leaves = jax.tree_util.tree_leaves(
                jax.tree.map(lambda s: repr(s), specs,
                             is_leaf=lambda x: hasattr(x, "update")),
            )
            manifest["specs"] = [str(s) for s in spec_leaves]
        if meta is not None:
            manifest["meta"] = meta
        with open(tmp / _MANIFEST, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.name.startswith("step_") and not p.name.endswith(".tmp"):
                if (p / _MANIFEST).exists():  # torn dirs (no manifest) ignored
                    out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_meta(self, step: int | None = None) -> dict:
        """The ``meta`` dict stored with :meth:`save` (``{}`` when absent)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        manifest = json.loads(
            (self.dir / f"step_{step:010d}" / _MANIFEST).read_text()
        )
        return manifest.get("meta", {})

    def restore(self, like: Any, step: int | None = None, shardings: Any | None = None):
        """Restore into the structure of ``like`` (a state or shape pytree).

        Every leaf is validated against the manifest's recorded shape AND
        dtype — a float state restored into a quantized ``like`` (same leaf
        count, different accumulator dtype) fails loudly instead of silently
        decoding int32 code sums as float32 garbage.

        ``shardings``: optional sharding pytree for the CURRENT mesh — leaves
        are device_put directly into it (elastic restart path).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / _MANIFEST).read_text())
        leaves, treedef = _flatten(like)
        assert len(leaves) == len(manifest["leaves"]), (
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"state expects {len(leaves)}"
        )
        problems = []
        for i, (leaf, entry) in enumerate(zip(leaves, manifest["leaves"])):
            want_shape = tuple(getattr(leaf, "shape", ()))
            want_dtype = str(getattr(leaf, "dtype", ""))
            if tuple(entry["shape"]) != want_shape:
                problems.append(
                    f"leaf {i}: checkpoint shape {tuple(entry['shape'])} != "
                    f"state shape {want_shape}"
                )
            elif want_dtype and entry["dtype"] != want_dtype:
                problems.append(
                    f"leaf {i}: checkpoint dtype {entry['dtype']} != "
                    f"state dtype {want_dtype}"
                )
        if problems:
            raise ValueError(
                f"checkpoint {d.name} does not fit the requested state "
                "(wrong state flavour — e.g. quantized vs float?):\n"
                + "\n".join(problems)
            )
        loaded = [
            np.load(d / entry["file"]) for entry in manifest["leaves"]
        ]
        state = jax.tree_util.tree_unflatten(treedef, loaded)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return state
