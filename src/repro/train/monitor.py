"""Activation-space monitoring via streaming sketches (paper integration #3).

Every train step folds a mean-pooled final-hidden-state batch into an O(m)
sketch (rides the step; the cross-device merge is just the replicated-output
psum GSPMD already emits).  Offline — at checkpoint boundaries — CKM decodes
K centroids from the sketch ALONE, giving a cluster-level picture of the
representation space over time without ever storing activations.

Drift between two windows = mean matched-centroid displacement, weighted by
mixture mass: cheap early-warning for representation collapse / data shifts
at 1000-node scale, where logging raw activations is impossible.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ckm as ckm_mod
from repro.core import distributed_sketch as ds
from repro.core import freq_ops as fo


@dataclasses.dataclass
class ActivationMonitor:
    dim: int  # d_model
    k: int = 8
    m: int | None = None
    sigma2: float = 1.0
    seed: int = 17
    # Frequency-operator family (core.freq_ops registry).  None resolves by
    # d_model: "structured" at dim >= 512 — monitoring a 4k-dim residual
    # stream must not materialize the (dim, m) dense matrix (O(m) signs +
    # radii instead, O(m·sqrt(dim)) projections) — and the paper's "dense"
    # below that, where the matrix is small and fastest.
    freq_op: str | None = None

    def __post_init__(self):
        self.m_ = self.m or 4 * self.k * self.dim
        if self.freq_op is None:
            self.freq_op = "structured" if self.dim >= 512 else "dense"
        # Spec-carrying operator: checkpoints/peers need only op.spec().
        self.freqs = fo.make_operator(
            self.freq_op, jax.random.PRNGKey(self.seed), self.m_, self.dim,
            self.sigma2,
        )

    def init_state(self) -> ds.SketchState:
        return ds.init_state(self.m_, self.dim)

    def update(self, state: ds.SketchState, pooled: jax.Array) -> ds.SketchState:
        """Fold (B, d) pooled hiddens; call inside or outside the train step."""
        return ds.update(state, pooled.astype(jnp.float32), self.freqs)

    def decode(self, state: ds.SketchState, key=None) -> ckm_mod.CKMResult:
        key = key if key is not None else jax.random.PRNGKey(self.seed + 1)
        z, lo, hi = ds.finalize(state)
        cfg = ckm_mod.CKMConfig(
            k=self.k, m=self.m_, atom_steps=150, joint_steps=100, final_steps=300
        )
        cents, alphas, cost = ckm_mod.decode_sketch(key, z, self.freqs, lo, hi, cfg)
        return ckm_mod.CKMResult(
            cents, alphas, cost, jnp.asarray(self.sigma2), self.freqs, z, (lo, hi)
        )

    def sketch_drift(self, state: ds.SketchState, result: ckm_mod.CKMResult) -> float:
        """O(m) drift of the *live* window against a decoded snapshot: CF
        distance between the current state's sketch and ``result``'s
        re-sketched centroids (``repro.obs.diagnose.sketch_drift``) — no
        decode needed, so it can run every window where :meth:`decode` +
        :meth:`drift` only run at checkpoint boundaries.  Emits the
        ``monitor.sketch_drift`` gauge when telemetry is enabled.
        """
        from repro.obs import runtime as obs_rt
        from repro.obs.diagnose import sketch_drift

        z_live, _, _ = ds.finalize(state)
        score = sketch_drift(
            z_live, result.centroids, result.weights, self.freqs
        )
        if obs_rt.ENABLED:
            from repro.obs import metrics as obs_metrics

            obs_metrics.gauge("monitor.sketch_drift").set(score)
        return score

    @staticmethod
    def drift(prev: ckm_mod.CKMResult, cur: ckm_mod.CKMResult) -> float:
        """Mass-weighted mean displacement between matched centroid sets."""
        a = np.asarray(prev.centroids)
        b = np.asarray(cur.centroids)
        wa = np.asarray(prev.weights)
        d = np.linalg.norm(a[:, None] - b[None], axis=-1)
        moved, used = 0.0, d.copy()
        for _ in range(a.shape[0]):
            i, j = np.unravel_index(np.argmin(used), used.shape)
            moved += wa[i] * d[i, j]
            used[i, :] = np.inf
            used[:, j] = np.inf
        return float(moved / max(wa.sum(), 1e-9))
