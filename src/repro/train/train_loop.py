"""The training loop: checkpoint/restart, preemption, monitoring, balancing.

Fault-tolerance model (designed for 1000+ nodes, exercised here in-process):
- state (params/opt/step/monitor sketch) checkpoints atomically + async every
  ``ckpt_every`` steps; restart resumes from the latest complete checkpoint;
- data is a pure function of (seed, step): resume replays nothing, skips
  nothing, and any worker can regenerate any shard (straggler re-dispatch);
- a preemption signal (SIGTERM or a flag file, as SLURM/Borg deliver) forces
  a final synchronous checkpoint before exit;
- elastic restart: checkpoints store logical specs; a restart may present a
  different mesh (tested: save on (4,2), restore on (2,2,2)).

CKM integrations live here too: the activation monitor folds pooled hidden
states into a sketch each step, and the compressive balancer periodically
re-weights the data mixture from document-embedding sketches.
"""

from __future__ import annotations

import dataclasses
import os
import signal
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import distributed_sketch as ds
from repro.data.clustering import CompressiveBalancer
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.train import (
    build_train_step,
    default_opt_config,
    init_sharded_state,
    state_shapes,
    state_specs,
)
from repro.models import transformer as tfm
from repro.optim.optimizers import make_optimizer
from repro.parallel import sharding as sh
from repro.train.monitor import ActivationMonitor


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    keep: int = 3
    monitor_k: int = 0  # 0 = off
    balance_every: int = 0  # 0 = off; else rebalance mixture every N steps
    preempt_file: str | None = None  # touch this file to request preemption
    log_every: int = 10
    dtype: Any = jnp.bfloat16
    remat: str = "none"


def _pooled_loss(params, cfg, batch, mesh, dtype, remat):
    x, aux = tfm.forward(params, cfg, batch, mesh, dtype, remat)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        f = batch["patches"].shape[1]
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], f), -100, labels.dtype), labels], axis=1
        )
    loss = tfm.chunked_ce_loss(params, cfg, x, labels)
    pooled = jnp.mean(x.astype(jnp.float32), axis=1)  # (B, d)
    return loss + 0.01 * aux, pooled


def run(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    loop: LoopConfig,
    data_cfg: DataConfig | None = None,
    opt_cfg=None,
    seed: int = 0,
) -> dict:
    """Train; resume from the latest checkpoint in loop.ckpt_dir if present."""
    opt_cfg = opt_cfg or default_opt_config(cfg)
    opt = make_optimizer(opt_cfg)
    data_cfg = data_cfg or DataConfig(seed=seed)
    source = SyntheticLM(cfg, shape, data_cfg)
    ckpt = Checkpointer(loop.ckpt_dir, keep=loop.keep)

    monitor = (
        ActivationMonitor(dim=cfg.d_model, k=loop.monitor_k)
        if loop.monitor_k
        else None
    )
    balancer = (
        CompressiveBalancer(
            k=data_cfg.n_domains, dim=data_cfg.embed_dim, seed=seed + 3
        )
        if loop.balance_every
        else None
    )

    # -- build step ----------------------------------------------------------
    def step_fn(state, batch):
        def loss_fn(p):
            return _pooled_loss(p, cfg, batch, mesh, loop.dtype, loop.remat)

        (loss, pooled), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"]
        )
        new_params, new_opt, metrics = opt.update(
            grads, state["opt"], state["params"], state["step"]
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if monitor is not None:
            new_state["monitor"] = ds.update(
                state["monitor"], pooled, monitor.freqs
            )
        return new_state, {"loss": loss, **metrics}

    shapes = state_shapes(cfg, opt)
    specs = state_specs(shapes, cfg, mesh)
    if monitor is not None:
        specs["monitor"] = jax.tree.map(lambda _: sh.P(), monitor.init_state())
    state_shardings = sh.to_shardings(specs, mesh)
    batch_specs = sh.batch_specs(cfg, shape, mesh)
    batch_shardings = sh.to_shardings(batch_specs, mesh)
    jitted = jax.jit(
        step_fn,
        in_shardings=(state_shardings, batch_shardings),
        donate_argnums=(0,),
    )

    # -- init or resume --------------------------------------------------------
    start = ckpt.latest_step()
    state = init_sharded_state(cfg, opt, mesh, seed=seed)
    if monitor is not None:
        state["monitor"] = jax.device_put(
            monitor.init_state(), sh.to_shardings(specs["monitor"], mesh)
        )
    if start is not None:
        state = ckpt.restore(state, shardings=state_shardings)
        print(f"[train] resumed from step {start}")
    start = int(jax.device_get(state["step"]))

    preempted = {"flag": False}

    def _sigterm(_sig, _frm):
        preempted["flag"] = True

    old_handler = signal.signal(signal.SIGTERM, _sigterm)

    history = []
    try:
        for step in range(start, loop.steps):
            batch = source.batch(step)
            meta = {k: batch.pop(k) for k in ("_doc_embeds", "_domains")}
            batch = jax.device_put(batch, batch_shardings)
            state, metrics = jitted(state, batch)
            if balancer is not None:
                balancer.update(meta["_doc_embeds"])
                if (step + 1) % loop.balance_every == 0:
                    res = balancer.cluster()
                    source.set_domain_weights(balancer.balanced_weights(res))
            if (step + 1) % loop.log_every == 0 or step == loop.steps - 1:
                m = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                history.append({"step": step + 1, **m})
                print(f"[train] step {step+1}: loss {m['loss']:.4f}")
            want_ckpt = (step + 1) % loop.ckpt_every == 0
            preempt = preempted["flag"] or (
                loop.preempt_file and Path(loop.preempt_file).exists()
            )
            if want_ckpt or preempt or step == loop.steps - 1:
                (ckpt.save if preempt else ckpt.save_async)(
                    int(jax.device_get(state["step"])), state, specs
                )
                if preempt:
                    print("[train] preemption requested: checkpoint flushed, exiting")
                    break
    finally:
        ckpt.wait()
        signal.signal(signal.SIGTERM, old_handler)

    out = {"history": history, "state": state}
    if monitor is not None:
        out["monitor_result"] = monitor.decode(jax.device_get(state["monitor"]))
    return out
