"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

The dry-run lowers against these — weak-type-correct, shardable, and never
allocated.  Frontend stubs per the assignment: precomputed patch/frame
embeddings replace the vision/audio towers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    s_text = s - cfg.frontend_len if cfg.frontend == "vision" else s
    batch = {
        "tokens": sds((b, s_text), jnp.int32),
        "labels": sds((b, s_text), jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["patches"] = sds((b, cfg.frontend_len, cfg.d_model), jnp.float32)
    elif cfg.frontend == "audio":
        batch["frames"] = sds((b, cfg.frontend_len, cfg.d_model), jnp.float32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    batch = train_batch_specs(cfg, shape)
    batch.pop("labels")
    return batch


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    return sds((shape.global_batch, 1), jnp.int32)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key=None) -> dict:
    """Materialise a random batch matching the specs (small shapes only)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = train_batch_specs(cfg, shape)
    kt, kf = jax.random.split(key)
    out = {
        "tokens": jax.random.randint(
            kt, specs["tokens"].shape, 0, cfg.vocab_size, jnp.int32
        ),
    }
    out["labels"] = jnp.roll(out["tokens"], -1, axis=1)
    for name in ("patches", "frames"):
        if name in specs:
            out[name] = jax.random.normal(kf, specs[name].shape, jnp.float32)
    return out
