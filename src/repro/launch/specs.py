"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

The dry-run lowers against these — weak-type-correct, shardable, and never
allocated.  Frontend stubs per the assignment: precomputed patch/frame
embeddings replace the vision/audio towers.

Also home of :class:`SketchJobSpec`, the launchable description of a
distributed sketch workload (backend x merge topology x ingest mode) —
drivers (``examples/full_pipeline.py``, benchmarks) build their
``CKMConfig`` from it so topology/ingest choices are named in one place.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class SketchJobSpec:
    """How a sketch pass is deployed, independent of what it sketches.

    ``validate()`` fails fast against the live registries (engine backends,
    ``core.topology``), so a launch config cannot name a topology that does
    not exist; ``ckm_overrides()`` is the kwargs dict to splat into
    ``dataclasses.replace(CKMConfig(...), **...)``.
    """

    backend: str = "xla"
    reduce_topology: str = "allreduce"
    ingest: str = "sync"
    ingest_prefetch: int = 2
    sketch_quantization: str = "none"
    # Frequency-operator family (core.freq_ops registry): "dense" |
    # "structured" | any registered name.
    freq_op: str = "dense"
    # Sketch decoder (core.decoders registry): "clompr" | "sketch_shift" |
    # "amp" | any registered name.
    decoder: str = "clompr"
    # -- fleet deployment (multi-tenant sketch serving, core.fleet) ---------
    # Number of independent tenant sketch states held stacked in one
    # FleetEngine state; 1 = the classic single-sketch job.
    n_tenants: int = 1
    # How many shards the tenant axis splits into (each shard holds a
    # contiguous block of n_tenants / tenant_shards rows); n_tenants must be
    # divisible by this extent.
    tenant_shards: int = 1
    # Mesh-axis name the tenant shards map onto in a multi-device deployment.
    tenant_shard_axis: str = "tenant"
    # LRU capacity of the decode-on-demand cache (decoded models, keyed on
    # (tenant, state-version)); 0 disables caching.
    decode_cache_entries: int = 256
    # -- temporal sketching (core.engine decay / core.window) ---------------
    # Exponential decay base gamma in (0, 1] for the timestamped state
    # transform; None = lifetime sketch.
    decay: float | None = None
    # W > 0 turns on the bucketed ring-of-sketches window (core.window):
    # reads merge the last W buckets; 0 = no window.
    window_buckets: int = 0
    # Width of one window bucket on the t axis (must be positive when
    # window_buckets > 0).
    window_bucket_ticks: float = 1.0
    # CF-distance drift bound for unattended fleet maintenance
    # (FleetService): on breach the tenant's cached decode is invalidated
    # and re-decoded (counter fleet.redecode.drift); None = no maintenance.
    drift_threshold: float | None = None

    def validate(self) -> "SketchJobSpec":
        from repro.core.decoders import get_decoder
        from repro.core.engine import BACKENDS
        from repro.core.freq_ops import get_freq_op
        from repro.core.topology import get_topology

        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}"
            )
        get_topology(self.reduce_topology)
        get_freq_op(self.freq_op)
        get_decoder(self.decoder)
        if self.ingest not in ("sync", "async"):
            raise ValueError(
                f"ingest must be 'sync' or 'async', got {self.ingest!r}"
            )
        if self.ingest_prefetch < 1:
            raise ValueError(
                f"ingest_prefetch must be >= 1, got {self.ingest_prefetch}"
            )
        if self.n_tenants < 1:
            raise ValueError(f"n_tenants must be >= 1, got {self.n_tenants}")
        if self.tenant_shards < 1:
            raise ValueError(
                f"tenant_shards must be >= 1, got {self.tenant_shards}"
            )
        if self.n_tenants % self.tenant_shards:
            raise ValueError(
                f"n_tenants={self.n_tenants} is not divisible by the tenant "
                f"shard extent tenant_shards={self.tenant_shards}; every "
                f"'{self.tenant_shard_axis}' shard must hold an equal block "
                "of tenant rows"
            )
        if not self.tenant_shard_axis:
            raise ValueError("tenant_shard_axis must be a non-empty axis name")
        if self.decode_cache_entries < 0:
            raise ValueError(
                f"decode_cache_entries must be >= 0, got "
                f"{self.decode_cache_entries}"
            )
        if self.n_tenants > 1 and self.backend not in ("xla", "pallas"):
            raise ValueError(
                f"fleet jobs (n_tenants={self.n_tenants}) run on the "
                f"vmapped xla|pallas backends, got {self.backend!r}"
            )
        if self.decay is not None and not 0.0 < self.decay <= 1.0:
            raise ValueError(
                f"decay must be in (0, 1], got {self.decay!r}"
            )
        if self.window_buckets < 0:
            raise ValueError(
                f"window_buckets must be >= 0, got {self.window_buckets}"
            )
        if self.window_buckets > 0 and not self.window_bucket_ticks > 0:
            raise ValueError(
                f"window_bucket_ticks must be positive, got "
                f"{self.window_bucket_ticks}"
            )
        if self.drift_threshold is not None and not self.drift_threshold > 0:
            raise ValueError(
                f"drift_threshold must be positive, got "
                f"{self.drift_threshold!r}"
            )
        return self

    def ckm_overrides(self) -> dict:
        self.validate()
        return {
            "sketch_backend": self.backend,
            "reduce_topology": self.reduce_topology,
            "ingest": self.ingest,
            "ingest_prefetch": self.ingest_prefetch,
            "sketch_quantization": self.sketch_quantization,
            "freq_op": self.freq_op,
            "decoder": self.decoder,
            "decay": self.decay,
        }

    def fleet_kwargs(self) -> dict:
        """Kwargs to splat into ``FleetEngine(specs, **...)`` for this job.

        ``tenant_shards > 1`` turns on mesh sharding (``sharding="mesh"``)
        over ``tenant_shard_axis`` — the engine builds/validates the device
        mesh itself, so the caller only names the extent here."""
        self.validate()
        kwargs: dict = {"backend": self.backend, "decay": self.decay}
        if self.tenant_shards > 1:
            kwargs.update(
                sharding="mesh",
                tenant_shards=self.tenant_shards,
                tenant_shard_axis=self.tenant_shard_axis,
            )
        return kwargs

    def service_kwargs(self) -> dict:
        """Kwargs to splat into ``FleetService(engine, config, **...)``:
        the decode-cache size, drift maintenance bound, and window shape."""
        self.validate()
        return {
            "decode_cache_entries": self.decode_cache_entries,
            "drift_threshold": self.drift_threshold,
            "window_buckets": self.window_buckets,
            "window_bucket_ticks": self.window_bucket_ticks,
        }

    def describe(self) -> str:
        base = (
            f"backend={self.backend} topology={self.reduce_topology} "
            f"ingest={self.ingest}(depth={self.ingest_prefetch}) "
            f"quantize={self.sketch_quantization} freq_op={self.freq_op} "
            f"decoder={self.decoder}"
        )
        if self.n_tenants > 1:
            base += (
                f" fleet={self.n_tenants}x{self.tenant_shards}shards"
                f"(axis={self.tenant_shard_axis},"
                f"cache={self.decode_cache_entries})"
            )
        if self.decay is not None:
            base += f" decay={self.decay}"
        if self.window_buckets > 0:
            base += (
                f" window={self.window_buckets}x{self.window_bucket_ticks}"
            )
        if self.drift_threshold is not None:
            base += f" drift_threshold={self.drift_threshold}"
        return base


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    s_text = s - cfg.frontend_len if cfg.frontend == "vision" else s
    batch = {
        "tokens": sds((b, s_text), jnp.int32),
        "labels": sds((b, s_text), jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["patches"] = sds((b, cfg.frontend_len, cfg.d_model), jnp.float32)
    elif cfg.frontend == "audio":
        batch["frames"] = sds((b, cfg.frontend_len, cfg.d_model), jnp.float32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    batch = train_batch_specs(cfg, shape)
    batch.pop("labels")
    return batch


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    return sds((shape.global_batch, 1), jnp.int32)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key=None) -> dict:
    """Materialise a random batch matching the specs (small shapes only)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = train_batch_specs(cfg, shape)
    kt, kf = jax.random.split(key)
    out = {
        "tokens": jax.random.randint(
            kt, specs["tokens"].shape, 0, cfg.vocab_size, jnp.int32
        ),
    }
    out["labels"] = jnp.roll(out["tokens"], -1, axis=1)
    for name in ("patches", "frames"):
        if name in specs:
            out[name] = jax.random.normal(kf, specs[name].shape, jnp.float32)
    return out
