"""Serving step construction: sharded prefill / decode (serve_step).

``decode_*`` / ``long_*`` cells lower ``serve_step`` — one new token against a
KV cache of seq_len — per the assignment.  Cache shardings: sequence dim over
"model" (SP decode attention), batch over (pod, data) where divisible.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.parallel import sharding as sh


def cache_mode(cfg: ModelConfig, shape: ShapeConfig) -> str:
    return "ckm" if (shape.kind == "long_decode" and cfg.long_context == "ckm") else "full"


def cache_shapes(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: tfm.init_cache(
            cfg, shape.global_batch, shape.seq_len, cache_mode(cfg, shape), dtype
        )
    )


def params_shapes(cfg: ModelConfig, dtype=jnp.bfloat16):
    """Serving params are bf16 (123B f32 would not fit a 16-chip TP slice)."""

    def init():
        p = tfm.init_lm(jax.random.PRNGKey(0), cfg)
        return jax.tree.map(
            lambda x: x.astype(dtype) if x.dtype == jnp.float32 else x, p
        )

    return jax.eval_shape(init)


def jit_serve_step(
    cfg: ModelConfig, shape: ShapeConfig, mesh, dtype=jnp.bfloat16, donate=True
):
    """Jitted decode step + (shapes, shardings) for the dry-run."""
    pshapes = params_shapes(cfg)
    # 2D weight sharding at serve too: "F" dims over data (123B bf16 / 16 TP
    # shards alone is 15 GB/chip; over data x model it is <1 GB).
    pspecs = sh.param_specs(pshapes, cfg, mesh, fsdp_axis="data")
    cshapes = cache_shapes(cfg, shape, dtype)
    cspecs = sh.cache_specs(cshapes, cfg, shape, mesh)
    ba = sh.batch_axes(mesh)
    dp = 1
    for a in ba:
        dp *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    tok_spec = P(ba if shape.global_batch % dp == 0 and shape.global_batch >= dp else None, None)

    def serve_step(params, token, cache, index):
        logits, new_cache = tfm.decode_step(
            params, cfg, token, cache, index, mesh=mesh, dtype=dtype
        )
        return logits, new_cache

    shardings = lambda spec: sh.to_shardings(spec, mesh)
    jitted = jax.jit(
        serve_step,
        in_shardings=(
            shardings(pspecs),
            NamedSharding(mesh, tok_spec),
            shardings(cspecs),
            NamedSharding(mesh, P()),
        ),
        out_shardings=(
            NamedSharding(mesh, tok_spec),
            shardings(cspecs),
        ),
        donate_argnums=(2,) if donate else (),
    )
    token_shape = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    index_shape = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted, (pshapes, token_shape, cshapes, index_shape)


def jit_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh, dtype=jnp.bfloat16):
    """Jitted prefill for prefill_* cells."""
    from repro.launch.specs import prefill_batch_specs

    pshapes = params_shapes(cfg)
    pspecs = sh.param_specs(pshapes, cfg, mesh, fsdp_axis="data")
    bspecs = sh.batch_specs(cfg, shape, mesh)
    bspecs.pop("labels", None)
    cshapes = cache_shapes(cfg, shape, dtype)
    cspecs = sh.cache_specs(cshapes, cfg, shape, mesh)
    ba = sh.batch_axes(mesh)
    dp = 1
    for a in ba:
        dp *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    tok_spec = P(ba if shape.global_batch % dp == 0 and shape.global_batch >= dp else None, None)

    def prefill(params, batch):
        return tfm.prefill(params, cfg, batch, cache_len=shape.seq_len, mesh=mesh, dtype=dtype)

    shardings = lambda spec: sh.to_shardings(spec, mesh)
    jitted = jax.jit(
        prefill,
        in_shardings=(shardings(pspecs), shardings(bspecs)),
        out_shardings=(
            NamedSharding(mesh, tok_spec),
            shardings(cspecs),
            NamedSharding(mesh, P()),
        ),
    )
    return jitted, (pshapes, prefill_batch_specs(cfg, shape))
