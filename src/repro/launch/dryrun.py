import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN).

For every (arch x shape) cell: build the production mesh, jit the train /
prefill / serve step with full FSDP+TP(+EP/SP) shardings, ``.lower()``,
``.compile()``, print ``memory_analysis()`` + ``cost_analysis()``, and write
the roofline terms to experiments/dryrun/<arch>__<shape>__<mesh>.json.

    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--jobs N]

The XLA_FLAGS line above MUST precede any jax import (jax locks the device
count at first init) — the 512 placeholder CPU devices exist only here.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import ARCHS, SHAPES, ShapeConfig, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import prefill_batch_specs, train_batch_specs
from repro.utils import roofline as rl

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def cell_skipped(cfg, shape: ShapeConfig) -> str | None:
    if shape.kind == "long_decode" and cfg.long_context == "skip":
        return "pure full-attention arch: long_500k skipped per DESIGN.md §4"
    return None


def _parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    return k, v


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    verbose: bool = True,
    overrides: dict | None = None,
) -> dict:
    import dataclasses as _dc

    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "kind": shape.kind,
    }
    skip = cell_skipped(cfg, shape)
    if skip:
        result["status"] = "skipped"
        result["reason"] = skip
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    tokens = shape.global_batch * shape.seq_len

    if shape.kind == "train":
        from repro.launch.train import default_opt_config, jit_train_step
        from repro.optim.optimizers import make_optimizer

        jitted, shapes, state_sh, _ = jit_train_step(cfg, shape, mesh)
        batch = train_batch_specs(cfg, shape)
        lowered = jitted.lower(shapes, batch)
        model_flops = rl.train_model_flops(cfg.active_param_count(), tokens)
    elif shape.kind == "prefill":
        from repro.launch.serve import jit_prefill

        jitted, (pshapes, bshapes) = jit_prefill(cfg, shape, mesh)
        lowered = jitted.lower(pshapes, bshapes)
        model_flops = 2.0 * cfg.active_param_count() * tokens
    else:  # decode / long_decode
        from repro.launch.serve import jit_serve_step

        jitted, (pshapes, tok, cshapes, idx) = jit_serve_step(cfg, shape, mesh)
        lowered = jitted.lower(pshapes, tok, cshapes, idx)
        model_flops = rl.decode_model_flops(
            cfg.active_param_count(), shape.global_batch
        )

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    roof = rl.analyze(compiled, chips, model_flops)
    result.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        chips=chips,
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
        tokens=tokens,
        flops_per_device=roof.flops,
        hbm_bytes_per_device=roof.hbm_bytes,
        collective_bytes_per_device=roof.collective_bytes,
        compute_s=roof.compute_s,
        memory_s=roof.memory_s,
        collective_s=roof.collective_s,
        dominant=roof.dominant,
        model_flops=roof.model_flops,
        useful_ratio=round(roof.useful_ratio, 4),
        roofline_fraction=round(roof.roofline_fraction(), 4),
    )
    from repro.utils import hlo as hlo_mod

    coll = hlo_mod.analyze_compiled(compiled)
    result["collectives"] = {
        op: {"bytes": b, "count": int(coll.coll_count[op])}
        for op, b in sorted(coll.coll_by_op.items())
    }
    try:
        result["memory_analysis"] = {
            "argument_size": mem.argument_size_in_bytes,
            "output_size": mem.output_size_in_bytes,
            "temp_size": mem.temp_size_in_bytes,
            "generated_code_size": mem.generated_code_size_in_bytes,
        }
    except AttributeError:
        result["memory_analysis"] = str(mem)
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}]")
        print(f"  memory_analysis: {result['memory_analysis']}")
        print(
            f"  flops/dev {roof.flops:.3e}  hbm/dev {roof.hbm_bytes:.3e}  "
            f"coll/dev {roof.collective_bytes:.3e}"
        )
        print(
            f"  compute {roof.compute_s*1e3:.2f} ms | memory {roof.memory_s*1e3:.2f} ms"
            f" | collective {roof.collective_s*1e3:.2f} ms -> {roof.dominant}-bound"
        )
        print(
            f"  useful_ratio {roof.useful_ratio:.3f}  roofline_fraction "
            f"{roof.roofline_fraction():.3f}  (lower {t_lower:.0f}s compile {t_compile:.0f}s)"
        )
    return result


def save(result: dict):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}.json"
    (OUT_DIR / name).write_text(json.dumps(result, indent=2))


def main():  # pragma: no cover - CLI
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument(
        "--set", action="append", default=[],
        help="config override key=value (repeatable), e.g. --set score_dtype=bf16",
    )
    ap.add_argument("--tag", default=None, help="suffix for the output json")
    args = ap.parse_args()
    overrides = dict(_parse_override(kv) for kv in args.set) or None

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        try:
            result = run_cell(arch, shape, args.multi_pod, overrides=overrides)
        except Exception as e:  # noqa: BLE001 - report and continue
            traceback.print_exc()
            result = {
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if args.multi_pod else "16x16",
                "status": "error", "error": f"{type(e).__name__}: {e}",
            }
            failures += 1
        if args.tag:
            result["tag"] = args.tag
            result["mesh"] = f"{result['mesh']}__{args.tag}"
        save(result)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
