"""Production mesh construction (assignment: MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state.  Callers needing 512 placeholder devices must set XLA_FLAGS
before any jax import (see launch/dryrun.py's first two lines).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int | None = None) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
