"""Training step construction: FSDP+TP sharded ``train_step`` per arch.

Used three ways:
- dry-run: ``.lower(shapes).compile()`` against ShapeDtypeStructs (launch/dryrun.py);
- real training: examples/train_lm.py and train/train_loop.py;
- tests: small meshes over forced host devices.

Also runnable as a CLI:  python -m repro.launch.train --arch llama3.2-1b ...
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, get_config
from repro.models import transformer as tfm
from repro.optim.optimizers import OptConfig, Optimizer, make_optimizer
from repro.parallel import sharding as sh
from repro.utils import compat


def default_opt_config(cfg: ModelConfig) -> OptConfig:
    """Adafactor for the giants (1T fits 512 chips), AdamW otherwise."""
    big = cfg.param_count() > 50e9
    return OptConfig(name="adafactor" if big else "adamw")


def default_param_dtype(cfg: ModelConfig):
    """bf16 stored params for >=400B models (adafactor keeps f32 statistics);
    f32 otherwise.  1T f32 params would eat 8 of 16 GB/chip on their own."""
    return jnp.bfloat16 if cfg.param_count() > 400e9 else jnp.float32


def state_shapes(cfg: ModelConfig, opt: Optimizer, key=None, param_dtype=None) -> dict:
    """ShapeDtypeStruct pytree of the train state (no allocation)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    param_dtype = param_dtype or default_param_dtype(cfg)

    def init():
        params = tfm.init_lm(key, cfg)
        params = jax.tree.map(
            lambda p: p.astype(param_dtype) if p.dtype == jnp.float32 else p, params
        )
        return {
            "params": params,
            "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32),
        }

    return jax.eval_shape(init)


def state_specs(state_shape: dict, cfg: ModelConfig, mesh) -> dict:
    pspecs = sh.param_specs(state_shape["params"], cfg, mesh)
    return {
        "params": pspecs,
        "opt": sh.opt_state_specs(state_shape["opt"], pspecs),
        "step": P(),
    }


def build_train_step(
    cfg: ModelConfig,
    opt: Optimizer,
    mesh=None,
    remat: str = "full",
    dtype=jnp.bfloat16,
):
    """Returns train_step(state, batch) -> (state, metrics) — un-jitted."""

    def train_step(state, batch):
        def loss_fn(params):
            return tfm.lm_loss(params, cfg, batch, mesh=mesh, dtype=dtype, remat=remat)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        new_params, new_opt, metrics = opt.update(
            grads, state["opt"], state["params"], state["step"]
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        return new_state, {"loss": loss, **metrics}

    return train_step


def build_compressed_train_step(
    cfg: ModelConfig,
    opt: Optimizer,
    mesh,
    remat: str = "full",
    dtype=jnp.bfloat16,
):
    """Train step with int8 error-feedback gradient exchange across pods.

    Gradients are computed per pod (partial-manual shard_map over "pod"; the
    data/model sharding stays automatic), int8-compressed for the cross-pod
    exchange, then the optimizer runs on the exact-within-pod /
    compressed-across-pod sum.  State gains an "err" entry (leading pod dim).
    """
    from repro.optim.grad_compression import compress_allreduce_tree

    assert "pod" in mesh.axis_names, "compressed step needs a pod axis"
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]

    def grads_fn(params, batch, err):
        def loss_fn(p):
            return tfm.lm_loss(p, cfg, batch, mesh=mesh, dtype=dtype, remat=remat)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, new_err = compress_allreduce_tree(grads, err, "pod")
        # mean over pods (each pod's loss/grads average its own batch slice)
        grads = jax.tree.map(lambda g: g / n_pods, grads)
        loss = jax.lax.pmean(loss, "pod")
        return loss, grads, new_err

    def pod_specs(tree, leading_pod=False):
        return jax.tree.map(
            lambda _: P("pod") if leading_pod else P(), tree
        )

    def train_step(state, batch):
        batch_in = {k: P("pod") for k in batch}
        sharded = compat.shard_map(
            grads_fn,
            mesh=mesh,
            in_specs=(P(), batch_in, pod_specs(state["err"], True)),
            out_specs=(P(), P(), pod_specs(state["err"], True)),
            axis_names={"pod"},
            check_vma=True,
        )
        loss, grads, new_err = sharded(state["params"], batch, state["err"])
        new_params, new_opt, metrics = opt.update(
            grads, state["opt"], state["params"], state["step"]
        )
        return {
            "params": new_params,
            "opt": new_opt,
            "err": new_err,
            "step": state["step"] + 1,
        }, {"loss": loss, **metrics}

    return train_step


def jit_train_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    opt_cfg: OptConfig | None = None,
    remat: str = "full",
    dtype=jnp.bfloat16,
    donate: bool = True,
):
    """Fully-sharded jitted train step + its (state shapes, shardings)."""
    opt_cfg = opt_cfg or default_opt_config(cfg)
    opt = make_optimizer(opt_cfg)
    shapes = state_shapes(cfg, opt)
    specs = state_specs(shapes, cfg, mesh)
    state_shardings = sh.to_shardings(specs, mesh)
    batch_shardings = sh.to_shardings(sh.batch_specs(cfg, shape, mesh), mesh)
    step = build_train_step(cfg, opt, mesh=mesh, remat=remat, dtype=dtype)
    metric_sharding = NamedSharding(mesh, P())
    jitted = jax.jit(
        step,
        in_shardings=(state_shardings, batch_shardings),
        out_shardings=(
            state_shardings,
            jax.tree.map(lambda _: metric_sharding, {"loss": 0, "lr": 0, "gnorm": 0}),
        ),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, shapes, state_shardings, batch_shardings


def init_sharded_state(
    cfg: ModelConfig, opt: Optimizer, mesh, seed: int = 0, param_dtype=None
):
    """Materialise the train state directly into its shardings (no host hop)."""
    param_dtype = param_dtype or default_param_dtype(cfg)
    shapes = state_shapes(cfg, opt, param_dtype=param_dtype)
    specs = state_specs(shapes, cfg, mesh)
    shardings = sh.to_shardings(specs, mesh)
    key = jax.random.PRNGKey(seed)

    def init():
        params = tfm.init_lm(key, cfg)
        params = jax.tree.map(
            lambda p: p.astype(param_dtype) if p.dtype == jnp.float32 else p, params
        )
        return {
            "params": params,
            "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32),
        }

    return jax.jit(init, out_shardings=shardings)()


def main():  # pragma: no cover - CLI
    import argparse

    from repro.configs.base import SHAPES
    from repro.launch.mesh import make_local_mesh
    from repro.launch.specs import make_batch

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    args = ap.parse_args()

    from repro.configs.base import get_smoke_config

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = make_local_mesh()
    step, shapes, state_sh, batch_sh = jit_train_step(cfg, shape, mesh)
    opt = make_optimizer(default_opt_config(cfg))
    state = init_sharded_state(cfg, opt, mesh)
    for i in range(args.steps):
        batch = jax.device_put(
            make_batch(cfg, shape, jax.random.PRNGKey(i)), batch_sh
        )
        state, metrics = step(state, batch)
        print(f"step {i}: loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
