"""Trip-count-aware cost analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — a model
stacked with ``lax.scan`` (all of ours) under-reports flops/bytes/collectives
by the layer count.  Optimized HLO, however, annotates every while with
``backend_config={"known_trip_count":{"n":...}}``.  This module parses the
HLO text into computations, costs each one, and multiplies loop bodies by
their trip counts (recursively, so chunked-scan-inside-layer-scan nests work).

Cost model (per computation):
- flops: 2 * prod(output dims) * prod(contracting dims) per ``dot``
  (+ recursion into fusion/call/while sub-computations).  Elementwise flops
  are ignored — matmuls dominate every assigned architecture.
- bytes: fusion-boundary traffic — every materialising instruction reads its
  operands and writes its result(s); internals of a fusion stay in
  registers/VMEM.  Bookkeeping ops (tuple/GTE/parameter/bitcast/constant) are
  free.
- collective bytes: operand sizes of all-reduce / all-gather / reduce-scatter
  / all-to-all / collective-permute (start variants counted once).

These are PER-DEVICE quantities (the compiled module is the SPMD per-device
program).
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*"
    r"(?P<out>\([^)]*\)|[a-z][a-z0-9]*\[[^\]]*\]\S*)\s+"
    r"(?P<op>[\w\-]+)\((?P<operands>.*?)\)(?P<rest>.*)$"
)
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_BOOKKEEPING = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "custom-call",  # layout/annotation custom-calls; real ones rare here
}

# Ops that READ only a slice/subset of their big operand (scan xs indexing,
# embedding lookups, cache updates).  Charging the full operand would bill a
# while body for its entire stacked xs on every iteration.
_SLICING = {"dynamic-slice", "gather", "slice"}
_UPDATING = {"dynamic-update-slice", "scatter", "scatter-add"}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
    "all-reduce-start", "all-gather-start", "collective-permute-start",
}
_COLLECTIVE_DONE = {"all-reduce-done", "all-gather-done", "collective-permute-done"}


def _shape_bytes(text: str) -> int:
    return sum(
        _DTYPE_BYTES.get(d, 4) * math.prod(int(x) for x in dims.split(",") if x)
        for d, dims in _SHAPE_RE.findall(text)
    )


def _shape_elems(text: str) -> int:
    m = _SHAPE_RE.search(text)
    if not m:
        return 0
    return math.prod(int(x) for x in m.group(2).split(",") if x)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_count: dict = dataclasses.field(default_factory=lambda: defaultdict(int))

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_op.items():
            self.coll_by_op[k] += v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] += int(v * mult)


def _parse_computations(text: str) -> tuple[dict[str, list[str]], str | None]:
    comps: dict[str, list[str]] = {}
    entry = None
    cur: list[str] | None = None
    cur_name = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line.strip())
            # " = " (spaced) marks an instruction; "=" alone also appears in
            # type comments like /*index=5*/ inside computation signatures.
            if m and ("{" in line) and (" = " not in line.split("{")[0]):
                cur_name = m.group(1)
                cur = []
                if line.strip().startswith("ENTRY"):
                    entry = cur_name
        else:
            if line.strip() == "}":
                comps[cur_name] = cur
                cur = None
            else:
                cur.append(line)
    return comps, entry


def _dot_flops(out_type: str, lhs_type: str, rest: str) -> float:
    out_elems = _shape_elems(out_type)
    m = _CONTRACT_RE.search(rest)
    lhs_shape = _SHAPE_RE.search(lhs_type)
    contract = 1
    if m and lhs_shape:
        dims = [int(x) for x in lhs_shape.group(2).split(",") if x]
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(dims):
                contract *= dims[idx]
    return 2.0 * out_elems * contract


def _parse_instrs(lines):
    """Parse instruction lines + build name -> (type, op, operands) tables."""
    instrs = []
    types: dict[str, str] = {}
    producers: dict[str, tuple[str, list[str]]] = {}
    consumers: dict[str, list[str]] = {}
    for line in lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name = m.group("name")
        types[name] = m.group("out")
        ops = _OPERAND_NAME_RE.findall(m.group("operands"))
        producers[name] = (m.group("op"), ops)
        for o in ops:
            consumers.setdefault(o, []).append(name)
        instrs.append(m)
    return instrs, types, producers, consumers


def _operand_types(operands: str, types: dict[str, str]) -> list[str]:
    return [types.get(n, "") for n in _OPERAND_NAME_RE.findall(operands)]


def _is_convert(name: str, producers) -> bool:
    if name not in producers:
        return False
    op, _ = producers[name]
    # XLA CPU wraps bf16->f32 casts as "convert" or "wrapped_convert*" fusions.
    return op == "convert" or (op == "fusion" and "convert" in name)


def _effective_bytes(name: str, types, producers) -> int:
    """Bytes of a value at its SEMANTIC dtype (TPU target model).

    The CPU backend has no native bf16 compute: it inserts convert(bf16->f32)
    around every dot, so the compiled artifact moves f32 where a TPU moves
    bf16.  When a value is produced by such a convert, count the bytes of the
    convert's INPUT type instead.
    """
    own = _shape_bytes(types.get(name, ""))
    if _is_convert(name, producers):
        _, ops = producers[name]
        if ops:
            src = _shape_bytes(types.get(ops[0], ""))
            if 0 < src < own:
                return src
    return own


def _result_effective_bytes(name: str, types, producers, consumers) -> int:
    """Result bytes, narrowed when every consumer immediately converts down
    (models the TPU dot/all-reduce emitting bf16 directly)."""
    own = _shape_bytes(types.get(name, ""))
    cons = consumers.get(name, [])
    if cons and all(_is_convert(c, producers) for c in cons):
        narrowest = min(_shape_bytes(types.get(c, "")) for c in cons)
        if 0 < narrowest < own:
            return narrowest
    return own


_FUSION_PARAM_CACHE: dict[int, dict] = {}


def _fusion_param_bytes(comp_name: str, comps) -> dict[int, int] | None:
    """Per-parameter effective read bytes for a fusion computation.

    If parameter i is consumed ONLY by slicing ops (dynamic-slice/gather),
    the fusion reads just those slices — map i -> sum(slice output bytes).
    Returns None when the computation is unknown.
    """
    cache_key = id(comps)
    per_mod = _FUSION_PARAM_CACHE.setdefault(cache_key, {})
    if comp_name in per_mod:
        return per_mod[comp_name]
    lines = comps.get(comp_name)
    if lines is None:
        per_mod[comp_name] = None
        return None
    instrs, types, producers, consumers = _parse_instrs(lines)
    param_names: dict[int, str] = {}
    for m in instrs:
        if m.group("op") == "parameter":
            idx_m = re.match(r"\s*(\d+)", m.group("operands"))
            if idx_m:
                param_names[int(idx_m.group(1))] = m.group("name")
    out: dict[int, int] = {}
    for idx, pname in param_names.items():
        cons = consumers.get(pname, [])
        if cons and all(
            producers.get(c, ("", []))[0] in _SLICING for c in cons
        ):
            out[idx] = sum(_shape_bytes(types.get(c, "")) for c in cons)
    per_mod[comp_name] = out
    return out


def _cost_computation(name, comps, memo) -> Costs:
    if name in memo:
        return memo[name]
    total = Costs()
    memo[name] = total  # guards cycles (none expected)
    instrs, types, producers, consumers = _parse_instrs(comps.get(name, ()))
    for m in instrs:
        op = m.group("op")
        iname = m.group("name")
        out = m.group("out")
        operands = m.group("operands")
        rest = m.group("rest")
        op_names = _OPERAND_NAME_RE.findall(operands)
        op_types = _operand_types(operands, types)
        op_bytes = sum(
            _effective_bytes(n, types, producers) for n in op_names
        ) or sum(_shape_bytes(t) for t in op_types)
        if op in _COLLECTIVE_DONE:
            continue
        if op in _COLLECTIVES:
            # wire bytes at the semantic dtype.  Only all-reduce results are
            # narrowed by their consumer converts: a TPU dot emits bf16
            # directly, so the psum right after it is bf16 (the f32 here is a
            # CPU-lowering shim).  all-gather/all-to-all results keep their
            # stored dtype — casting before the gather is a real graph
            # change, measured as such.
            nbytes = op_bytes or _shape_bytes(out)
            base = op.replace("-start", "")
            if base == "all-reduce":
                narrowed = _result_effective_bytes(
                    iname, types, producers, consumers
                )
                own_out = _shape_bytes(out)
                if own_out and narrowed < own_out:
                    nbytes = int(nbytes * narrowed / own_out)
            total.coll_bytes += nbytes
            total.coll_by_op[base] += nbytes
            total.coll_count[base] += 1
            total.bytes += nbytes  # collectives also touch HBM
            continue
        if op == "while":
            trip = 1
            tm = _TRIP_RE.search(rest)
            if tm:
                trip = int(tm.group(1))
            cm = re.search(r"body=%?([\w\.\-]+)", rest)
            if cm:
                total.add(_cost_computation(cm.group(1), comps, memo), trip)
            continue
        if op == "conditional":
            bm = _BRANCHES_RE.search(rest)
            branches = []
            if bm:
                branches = [b.strip().lstrip("%") for b in bm.group(1).split(",")]
            else:
                branches = re.findall(r"(?:true|false)_computation=%?([\w\.\-]+)", rest)
            sub = [_cost_computation(b, comps, memo) for b in branches]
            if sub:
                worst = max(sub, key=lambda c: c.flops + c.bytes)
                total.add(worst)
            continue
        if op in ("fusion", "call", "async-start"):
            cm = _CALLS_RE.search(rest)
            fusion_bytes = op_bytes
            if cm:
                sub = _cost_computation(cm.group(1), comps, memo)
                total.flops += sub.flops  # dots inside fusions still run
                total.coll_bytes += sub.coll_bytes
                for k, v in sub.coll_by_op.items():
                    total.coll_by_op[k] += v
                for k, v in sub.coll_count.items():
                    total.coll_count[k] += v
                # Params consumed ONLY by slicing ops inside the fusion are
                # read at slice granularity (scan xs indexing pattern).
                adj = _fusion_param_bytes(cm.group(1), comps)
                if adj is not None:
                    fusion_bytes = 0
                    for i, n in enumerate(op_names):
                        full = _effective_bytes(n, types, producers)
                        fusion_bytes += min(full, adj.get(i, full))
            # bytes: fusion boundary only
            total.bytes += fusion_bytes + _shape_bytes(out)
            continue
        if op in _SLICING:
            # reads the slice (~= output) + indices, not the whole operand
            total.bytes += 2 * _shape_bytes(out)
            continue
        if op in _UPDATING:
            # in-place: reads the update operand, writes the slice region
            upd_bytes = (
                _effective_bytes(op_names[1], types, producers)
                if len(op_names) > 1
                else _shape_bytes(out)
            )
            total.bytes += 2 * upd_bytes
            continue
        if op in ("dot", "convolution"):
            lhs = op_types[0] if op_types else ""
            total.flops += _dot_flops(out, lhs, rest)
            total.bytes += op_bytes + _result_effective_bytes(
                iname, types, producers, consumers
            )
            continue
        if op in _BOOKKEEPING:
            continue
        if _is_convert(iname, producers):
            continue  # CPU-only dtype shim: free on the TPU target
        # generic materialising op (copy, broadcast, reduce, sort, rng, ...)
        total.bytes += op_bytes + _shape_bytes(out)
    return total


def analyze_text(text: str) -> Costs:
    comps, entry = _parse_computations(text)
    if entry is None:
        return Costs()
    memo: dict[str, Costs] = {}
    # memo must not return the in-progress guard object for entry
    return _cost_computation(entry, comps, memo)


def analyze_compiled(compiled) -> Costs:
    return analyze_text(compiled.as_text())
