"""JAX version-compat shims.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax.shard_map`` (and its kwargs were renamed: ``check_rep``/``auto`` became
``check_vma``/``axis_names``).  Similarly ``jax.lax.pcast`` (marking a value
as varying over manual mesh axes) only exists on newer JAX; on older versions
replication tracking is disabled instead, so the cast is a no-op.

Every module in this repo that needs shard_map goes through this shim — the
call sites use the NEW spelling (``axis_names=...``) and this module translates
for whichever JAX is installed.
"""

from __future__ import annotations

from typing import Callable

import jax

__all__ = ["shard_map", "pvary"]

_HAS_NATIVE = hasattr(jax, "shard_map")


def shard_map(
    f: Callable,
    mesh,
    in_specs,
    out_specs,
    axis_names: set | frozenset | None = None,
    check_vma: bool | None = None,
):
    """Version-portable ``shard_map``.

    ``axis_names``: mesh axes the body is *manual* over (None = all axes).
    ``check_vma``: varying-manual-axes checking; ignored (forced off) on JAX
    versions whose replication checker predates ``pvary``/``pcast`` semantics,
    where bodies written for the new rules would be rejected spuriously.
    """
    if _HAS_NATIVE:
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    fn = _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False,
        auto=auto,
    )
    if auto:
        # The pre-0.5 eager impl raises NotImplementedError for partial-manual
        # (auto) meshes; the jit path handles it, so force tracing.
        fn = jax.jit(fn)
    return fn


def pvary(x, axis_names) -> jax.Array:
    """Mark ``x`` as varying over manual ``axis_names`` (no-op on old JAX).

    Newer JAX tracks varying-manual-axes (VMA) types inside shard_map and
    requires scan carries etc. to be explicitly cast with ``jax.lax.pcast``;
    older versions have no such type, so the identity is the correct shim.
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    axis_names = tuple(axis_names)
    if not axis_names:
        return x
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axis_names, to="varying")
    return x
