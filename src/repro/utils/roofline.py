"""Roofline accounting from compiled dry-run artifacts (assignment §ROOFLINE).

All quantities are PER-DEVICE: the compiled module of an SPMD program is the
per-device program, so ``cost_analysis()`` flops/bytes and the collective
bytes parsed from ``compiled.as_text()`` are per-chip numbers.

    compute_s    = HLO_flops / peak_flops            (197 TFLOP/s bf16, v5e)
    memory_s     = HLO_bytes / hbm_bw                (819 GB/s)
    collective_s = collective_bytes / link_bw        (~50 GB/s/link ICI)

The dominant term is the step-time lower bound; MODEL_FLOPS/HLO_FLOPs
measures how much compiled compute is "useful" (remat/dispatch waste).
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

# e.g.  %x = bf16[16,512]{1,0} all-gather(bf16[1,512]{1,0} %p), ...
_INSTR_RE = re.compile(
    r"=\s*(?P<out>\([^=]*?\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?P<start>-start)?\("
    r"(?P<operands>[^)]*)\)"
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int]
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op in optimized HLO text."""
    bytes_by_op: dict[str, int] = {}
    count_by_op: dict[str, int] = {}
    for m in _INSTR_RE.finditer(hlo_text):
        op = m.group("op")
        # operand types appear inline in HLO text: "bf16[8,16]{1,0} %arg"
        nbytes = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(m.group("operands"))
        )
        if nbytes == 0:  # fall back to the output shape
            nbytes = sum(
                _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(m.group("out"))
            )
        bytes_by_op[op] = bytes_by_op.get(op, 0) + nbytes
        count_by_op[op] = count_by_op.get(op, 0) + 1
    return CollectiveStats(bytes_by_op, count_by_op)


@dataclasses.dataclass
class Roofline:
    flops: float  # per device
    hbm_bytes: float  # per device
    collective_bytes: float  # per device
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float  # global useful flops (6 N D)
    useful_ratio: float  # model_flops / (flops * chips)

    def bound_step_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """useful-compute time / bound step time (the score axis)."""
        t_useful = self.model_flops / self.chips / PEAK_FLOPS
        b = self.bound_step_time()
        return t_useful / b if b > 0 else 0.0


def analyze(compiled, chips: int, model_flops: float) -> Roofline:
    """Roofline terms from the compiled artifact.

    Uses the trip-count-aware HLO analyzer (utils/hlo.py): XLA's own
    ``cost_analysis()`` counts scan bodies once, which would undercount every
    layer-stacked model here by its depth.
    """
    from repro.utils import hlo as hlo_mod

    costs = hlo_mod.analyze_compiled(compiled)
    flops = costs.flops
    hbm = costs.bytes
    coll = CollectiveStats(
        dict(costs.coll_by_op),
        {k: int(v) for k, v in costs.coll_count.items()},
    )
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll.total_bytes / LINK_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
        key=lambda kv: kv[1],
    )[0]
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=float(coll.total_bytes),
        chips=chips,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=model_flops / (flops * chips) if flops else 0.0,
    )


def freq_transform_model(
    n_pts: int, n: int, m: int, d: int, nblocks: int
) -> dict:
    """Flops/bytes/arithmetic-intensity model of the two frequency operators.

    Dense projection: one ``(N, n) @ (n, m)`` matmul — ``2·N·n·m`` flops
    moving ``4·(N·n + n·m + N·m)`` bytes.  Structured projection: per block,
    three Kronecker-factored WHTs (``H_d = H_a ⊗ H_b``; two dense
    contractions of ``2·N·d·(a+b)`` flops each) plus the diagonal and radial
    elementwise stages — ``O(N·m·sqrt(d))`` total, moving only
    ``4·(N·d + O(m) operator leaves + N·m)`` bytes.  The flops here count
    dot-issued work only (matching ``utils.hlo.analyze_compiled``'s cost
    model, which is how the benchmark cross-checks this model against the
    compiled HLO); elementwise trig/diagonals are excluded on both sides.
    """
    a = 1 << (((d.bit_length() - 1) + 1) // 2) if d > 1 else 1
    b = max(d // a, 1)
    dense_flops = 2.0 * n_pts * n * m
    structured_flops = 3.0 * nblocks * 2.0 * n_pts * d * (a + b)
    dense_bytes = 4.0 * (n_pts * n + n * m + n_pts * m)
    structured_bytes = 4.0 * (n_pts * d + 4 * nblocks * d + n_pts * m)
    return {
        "dense_flops": dense_flops,
        "structured_flops": structured_flops,
        "flops_ratio": dense_flops / max(structured_flops, 1.0),
        "dense_bytes": dense_bytes,
        "structured_bytes": structured_bytes,
        "dense_intensity": dense_flops / dense_bytes,
        "structured_intensity": structured_flops / structured_bytes,
    }


def train_model_flops(param_count: int, tokens: int) -> float:
    """6 N D (N = active params)."""
    return 6.0 * param_count * tokens


def decode_model_flops(param_count: int, batch: int) -> float:
    """One token per sequence: 2 N per token forward (decode has no backward)."""
    return 2.0 * param_count * batch
