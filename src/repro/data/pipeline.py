"""Deterministic sharded data pipeline.

Fault-tolerance contract: batches are a pure function of (seed, step) — a
restart from step k reproduces the exact token stream with no iterator state
to checkpoint.  The same contract gives straggler-safe re-dispatch: any worker
can regenerate any step's shard.

Sources:
- ``SyntheticLM``: zipf-ish token stream with planted cluster structure in a
  "document embedding" side-channel (drives the CKM data-clustering demo);
- ``MixtureSource``: weighted mixture of sources whose weights can be re-set
  from the compressive cluster balancer (data/clustering.py).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    n_domains: int = 8  # planted "topic" clusters for the CKM demo
    embed_dim: int = 16  # document-embedding side channel


class SyntheticLM:
    """Batch = f(seed, step): deterministic, restartable, shardable."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, data: DataConfig):
        self.cfg = cfg
        self.shape = shape
        self.data = data
        rng = np.random.default_rng(data.seed)
        # Per-domain unigram tables (zipf with domain-specific permutations)
        # and domain embedding centroids (the ground truth the CKM balancer
        # should recover).
        v = cfg.vocab_size
        base = 1.0 / (np.arange(1, v + 1) ** 1.1)
        self.domain_perm = np.stack(
            [rng.permutation(v) for _ in range(data.n_domains)]
        )
        self.base_p = base / base.sum()
        self.domain_centroids = rng.normal(
            size=(data.n_domains, data.embed_dim)
        ).astype(np.float32) * 3.0
        self.domain_weights = np.full(data.n_domains, 1.0 / data.n_domains)

    def set_domain_weights(self, w: np.ndarray):
        w = np.maximum(np.asarray(w, np.float64), 1e-9)
        self.domain_weights = w / w.sum()

    def batch(self, step: int) -> dict:
        """Produce the global batch for ``step`` (tokens, labels, embeds)."""
        cfg, shape = self.cfg, self.shape
        rng = np.random.default_rng((self.data.seed, step))
        b = shape.global_batch
        s_text = shape.seq_len - (
            cfg.frontend_len if cfg.frontend == "vision" else 0
        )
        domains = rng.choice(
            self.data.n_domains, size=b, p=self.domain_weights
        )
        # Tokens: domain-permuted zipf draws (cheap, deterministic).
        u = rng.random((b, s_text + 1))
        cdf = np.cumsum(self.base_p)
        ranks = np.searchsorted(cdf, u).clip(max=cfg.vocab_size - 1)
        tokens = np.take_along_axis(
            self.domain_perm[domains][:, None, :].reshape(b, -1),
            ranks.reshape(b, -1),
            axis=1,
        ).reshape(b, s_text + 1)
        batch = {
            "tokens": jnp.asarray(tokens[:, :-1], jnp.int32),
            "labels": jnp.asarray(tokens[:, 1:], jnp.int32),
        }
        if cfg.frontend == "vision":
            batch["patches"] = jnp.asarray(
                rng.normal(size=(b, cfg.frontend_len, cfg.d_model)), jnp.float32
            )
        elif cfg.frontend == "audio":
            batch["frames"] = jnp.asarray(
                rng.normal(size=(b, cfg.frontend_len, cfg.d_model)), jnp.float32
            )
        # Document-embedding side channel (noisy domain centroid) — consumed
        # by the compressive balancer, not by the model.
        embeds = self.domain_centroids[domains] + rng.normal(
            size=(b, self.data.embed_dim)
        ).astype(np.float32)
        batch["_doc_embeds"] = jnp.asarray(embeds)
        batch["_domains"] = jnp.asarray(domains, jnp.int32)
        return batch

    def iter(self, start_step: int, shardings=None) -> Iterator[dict]:
        step = start_step
        while True:
            batch = self.batch(step)
            meta = {k: batch.pop(k) for k in ("_doc_embeds", "_domains")}
            if shardings is not None:
                batch = jax.device_put(batch, shardings)
            batch.update(meta)
            yield batch
            step += 1

    def embedding_stream(self, start_step: int, steps: int) -> Iterator[jax.Array]:
        """Document-embedding batches only — a point stream for the streaming
        SketchEngine / ``ckm.fit_streaming`` (each batch is f(seed, step), so
        the stream is restartable and shardable like everything else)."""
        for step in range(start_step, start_step + steps):
            yield self.batch(step)["_doc_embeds"]


def chunked(x, size: int) -> Iterator[jax.Array]:
    """View an in-memory ``(N, n)`` array as a batch iterator of ``size``-row
    chunks (last chunk ragged) — adapts datasets to the one-pass streaming
    API (a ``core.ingest.BatchSource``); also the reference harness for
    streaming-vs-in-memory parity tests."""
    if size <= 0:
        raise ValueError(f"chunk size must be positive, got {size}")
    for i in range(0, x.shape[0], size):
        yield x[i : i + size]


def with_latency(source, seconds: float) -> Iterator[jax.Array]:
    """Model a host-I/O-bound ``BatchSource``: each batch costs ``seconds``
    of producer time before it is yielded (disk read, network fetch, decode).

    This is the stand-in for the regime the paper targets — data arriving
    from storage at 10^7-point scale — on a container where everything is
    resident in memory.  The async ingest path (``core.ingest``) hides this
    latency under sketch compute; ``benchmarks/kernels.py`` uses this source
    for its sync-vs-async overlap rows.
    """
    import time

    if seconds < 0:
        raise ValueError(f"latency must be >= 0, got {seconds}")
    for batch in source:
        time.sleep(seconds)
        yield batch
