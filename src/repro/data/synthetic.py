"""Synthetic datasets used by the paper's experiments (§4.1).

- ``gaussian_mixture``: K unit Gaussians in R^n with uniform weights, means
  drawn N(0, c K^{1/n} Id), c = 1.5 ("so that clusters are sufficiently
  separated with high probability").
- ``sbm_spectral``: offline stand-in for the paper's MNIST spectral-clustering
  pipeline (SIFT + kNN graph + Laplacian eigenvectors are not reproducible in
  this container): a stochastic block model graph whose normalised-Laplacian
  eigenvectors give the same kind of 10-dimensional spectral features the
  paper clusters.  Protocol (embed -> K-means -> ARI) is unchanged.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gaussian_mixture(
    key: jax.Array,
    n_points: int,
    k: int,
    n: int,
    c: float = 1.5,
    return_labels: bool = False,
):
    """Draw ``n_points`` from the paper's mixture of K unit Gaussians in R^n."""
    kmu, kz, kx = jax.random.split(key, 3)
    means = jax.random.normal(kmu, (k, n)) * jnp.sqrt(c * k ** (1.0 / n))
    labels = jax.random.randint(kz, (n_points,), 0, k)
    x = means[labels] + jax.random.normal(kx, (n_points, n))
    if return_labels:
        return x.astype(jnp.float32), labels, means
    return x.astype(jnp.float32)


def sbm_spectral(
    seed: int,
    n_nodes: int,
    k: int = 10,
    p_in: float = 0.08,
    p_out: float = 0.005,
    dim: int | None = None,
):
    """Spectral embedding of a stochastic block model graph.

    Returns ``(features (n_nodes, dim), labels (n_nodes,))`` where features are
    the first ``dim`` (default K) eigenvectors of the normalised Laplacian —
    the same 10-dim feature vectors the paper runs CKM on for MNIST.
    Dense numpy eigendecomposition: keep ``n_nodes`` at a few thousand.
    """
    rng = np.random.default_rng(seed)
    dim = dim or k
    labels = rng.integers(0, k, size=n_nodes)
    same = labels[:, None] == labels[None, :]
    probs = np.where(same, p_in, p_out)
    upper = np.triu(rng.random((n_nodes, n_nodes)) < probs, 1)
    adj = (upper | upper.T).astype(np.float64)
    deg = adj.sum(1)
    deg = np.maximum(deg, 1.0)
    d_isqrt = 1.0 / np.sqrt(deg)
    lap = np.eye(n_nodes) - d_isqrt[:, None] * adj * d_isqrt[None, :]
    vals, vecs = np.linalg.eigh(lap)
    feats = vecs[:, :dim]  # eigenvectors of the smallest eigenvalues
    # Row-normalise (standard spectral clustering post-processing).
    feats = feats / np.maximum(np.linalg.norm(feats, axis=1, keepdims=True), 1e-12)
    return feats.astype(np.float32), labels


def adjusted_rand_index(a: np.ndarray, b: np.ndarray) -> float:
    """ARI [32] between two label vectors (pure numpy)."""
    a = np.asarray(a)
    b = np.asarray(b)
    n = a.size
    ca = np.unique(a, return_inverse=True)[1]
    cb = np.unique(b, return_inverse=True)[1]
    table = np.zeros((ca.max() + 1, cb.max() + 1), np.int64)
    np.add.at(table, (ca, cb), 1)
    comb = lambda x: x * (x - 1) / 2.0
    sum_ij = comb(table).sum()
    sum_a = comb(table.sum(1)).sum()
    sum_b = comb(table.sum(0)).sum()
    expected = sum_a * sum_b / comb(n)
    max_index = 0.5 * (sum_a + sum_b)
    denom = max_index - expected
    if denom == 0:
        return 1.0
    return float((sum_ij - expected) / denom)
