"""Compressive data clustering for pipeline balancing (paper integration #2).

A 1000-node ingestion tier cannot afford a second pass over the corpus to
cluster document embeddings — but it CAN afford an O(m) mergeable sketch per
worker (the paper's central object).  This module:

1. folds document-embedding batches into a streaming ``SketchState`` (one per
   worker; merged with ``distributed_sketch.merge`` / a psum),
2. decodes K domain centroids with CKM *from the sketch alone*,
3. estimates per-cluster mass from the decoded mixture weights alpha, and
4. emits rebalanced sampling weights (inverse-propensity toward uniform).

No raw data is retained anywhere: this is exactly the paper's
"sketch-then-discard" contract applied to a data pipeline.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ckm as ckm_mod
from repro.core import distributed_sketch as ds
from repro.core import freq_ops as fo
from repro.core import frequencies as fq


@dataclasses.dataclass
class CompressiveBalancer:
    """Streaming sketch of document embeddings -> cluster-balanced weights."""

    k: int
    dim: int
    m: int | None = None
    sigma2: float | None = None  # None: estimated on the FIRST batch (paper's
    # small-sketch regression on a data fraction, §3.3 step 1)
    # The [5] estimator targets GMM decoding, where the model absorbs the
    # cluster envelope e^{-R^2 sigma_c^2/2}.  K-means decodes DIRACS: at the
    # GMM scale the envelope is ~0.4 at typical frequencies and CLOMPR
    # "explains" a wide cluster better with two split atoms than one —
    # catastrophic under imbalance (the split halves outweigh small clusters
    # at hard-thresholding).  Boosting sigma^2 (lowering frequencies) to
    # where the envelope is ~flat removes the incentive; separability is
    # unaffected while separation >> cluster std.  (Beyond-paper; see
    # EXPERIMENTS.md §Paper notes.)
    freq_scale_boost: float = 6.0
    seed: int = 0
    # Tiny reservoir kept alongside the sketch: CLOMPR's step-1 ascent starts
    # from sampled points (paper §4.2 "Sample" init) — random "Range" starts
    # cannot find far-separated clusters whose basins occupy ~(w/box)^dim of
    # the volume.  One pass, O(reservoir) memory: the compressive contract
    # (no second data pass, no full retention) is preserved.
    reservoir: int = 256

    def __post_init__(self):
        self.m_ = self.m or 10 * self.k * self.dim
        self.state = ds.init_state(self.m_, self.dim)
        self.freqs = None
        self._seen = 0
        self._rng = np.random.default_rng(self.seed + 13)
        self._reservoir = np.zeros((self.reservoir, self.dim), np.float32)
        if self.sigma2 is not None:
            self._draw(float(self.sigma2))

    def _draw(self, sigma2: float):
        self.sigma2 = sigma2
        key = jax.random.PRNGKey(self.seed)
        # A spec-carrying operator: a worker can broadcast op.spec() (O(1)
        # bytes) and peers rebuild the identical operator locally.
        self.freqs = fo.make_operator("dense", key, self.m_, self.dim, sigma2)

    def _reservoir_update(self, embeds: np.ndarray):
        for row in embeds:
            if self._seen < self.reservoir:
                self._reservoir[self._seen] = row
            else:
                j = self._rng.integers(0, self._seen + 1)
                if j < self.reservoir:
                    self._reservoir[j] = row
            self._seen += 1

    def update(self, embeds: jax.Array):
        """Fold one batch of document embeddings (B, dim) into the sketch."""
        if self.freqs is None:
            s2 = fq.estimate_sigma2(jax.random.PRNGKey(self.seed + 7), embeds)
            self._draw(float(s2) * self.freq_scale_boost)
        self.state = ds.update(self.state, embeds, self.freqs)
        self._reservoir_update(np.asarray(embeds, np.float32))

    def merge(self, other: "CompressiveBalancer"):
        self.state = ds.merge(self.state, other.state)

    def cluster(self, key=None) -> ckm_mod.CKMResult:
        """Decode centroids + mixture weights from the sketch (+ reservoir
        inits for step 1 — paper §4.2 Sample strategy)."""
        key = key if key is not None else jax.random.PRNGKey(self.seed + 1)
        z, lo, hi = ds.finalize(self.state)
        cfg = ckm_mod.CKMConfig(k=self.k, m=self.m_, init="kpp", atom_restarts=4)
        x_init = jnp.asarray(self._reservoir[: min(self._seen, self.reservoir)])
        cents, alphas, cost = ckm_mod.decode_sketch(
            key, z, self.freqs, lo, hi, cfg, x_init=x_init
        )
        return ckm_mod.CKMResult(
            cents, alphas, cost, jnp.asarray(self.sigma2), self.freqs, z, (lo, hi)
        )

    def balanced_weights(self, result: ckm_mod.CKMResult | None = None) -> np.ndarray:
        """Per-cluster sampling weights pushing the stream toward uniform."""
        result = result or self.cluster()
        alpha = np.maximum(np.asarray(result.weights), 1e-6)
        w = 1.0 / alpha
        return w / w.sum()

    def assign_clusters(self, embeds: jax.Array, result: ckm_mod.CKMResult):
        return ckm_mod.predict(embeds, result.centroids)
