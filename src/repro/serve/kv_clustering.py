"""CKM-compressed KV cache for long-context decode (beyond-paper feature).

The paper reads a dataset as a mixture of K weighted Diracs recovered from a
sketch.  A transformer's KV cache *is* a point cloud per head — so for the
``long_500k`` cells we compress each global-attention head's S=524288 keys
into K centroids with weights (cluster sizes), and decode-time attention runs
over [centroids ∪ recent-token ring]:

    softmax_j( q.k_j )  over S keys   ≈   softmax_c( q.ck_c + log w_c ) over K
                                          centroids (+ exact recent window)

The ``log w_c`` bias makes a centroid of w collapsed keys contribute like w
near-identical keys — exactly the paper's weighted-Dirac mixture view.
Compression itself can run with CKM (sketch -> CLOMPR; the compressive path —
the cache never needs to be gathered to one host, only its O(m) sketch) or
with Lloyd-Max (fast local baseline) — both from repro.core.

Attention cost per step drops from O(S) to O(K + recent): 524288 -> 5120 per
head (~100x) for the assigned long_500k shapes.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import ckm as ckm_mod
from repro.core import lloyd as lloyd_mod
from repro.models import layers as L

Params = dict[str, Any]


def compress_head(key, keys_1h, values_1h, n_centroids, method="lloyd",
                  ckm_cfg: ckm_mod.CKMConfig | None = None):
    """Compress one head's cache.  keys/values: (S, hd) -> (K, hd)x2 + logw."""
    if method == "ckm":
        res = ckm_mod.fit(key, keys_1h, ckm_cfg)
        cents = res.centroids
    else:
        res = lloyd_mod.lloyd(
            key, keys_1h,
            lloyd_mod.LloydConfig(k=n_centroids, max_iters=25, init="kpp"),
        )
        cents = res.centroids
    assign = ckm_mod.predict(keys_1h, cents)
    one_hot = jax.nn.one_hot(assign, n_centroids, dtype=jnp.float32)
    counts = jnp.sum(one_hot, axis=0)  # (K,)
    # Centroid value = mean of member values; key = mean of member keys
    # (recomputed from the hard assignment for both methods).
    ck = (one_hot.T @ keys_1h.astype(jnp.float32)) / jnp.maximum(counts[:, None], 1.0)
    cv = (one_hot.T @ values_1h.astype(jnp.float32)) / jnp.maximum(counts[:, None], 1.0)
    logw = jnp.where(counts > 0, jnp.log(jnp.maximum(counts, 1.0)), -1e30)
    return ck, cv, logw


def compress_kv(
    key: jax.Array,
    k: jax.Array,
    v: jax.Array,
    n_centroids: int,
    method: str = "lloyd",
):
    """k, v: (B, S, KV, hd) -> dict(ck (B,K,KV,hd), cv, clogw (B,K,KV)).

    Offline (per-compression-epoch) path — not part of the decode step.  For
    ``method="ckm"`` one frequency scale is estimated from a key sample and
    shared across heads (Dirac-regime boost, see data/clustering.py).
    """
    b, s, kvh, hd = k.shape
    kk = k.transpose(0, 2, 1, 3).reshape(b * kvh, s, hd)
    vv = v.transpose(0, 2, 1, 3).reshape(b * kvh, s, hd)
    keys = jax.random.split(key, b * kvh)
    ckm_cfg = None
    if method == "ckm":
        from repro.core import frequencies as fq

        sample = kk.reshape(-1, hd)[: 4096].astype(jnp.float32)
        s2 = float(fq.estimate_sigma2(key, sample)) * 6.0
        ckm_cfg = ckm_mod.CKMConfig(
            k=n_centroids, m=5 * n_centroids * hd, sigma2=s2,
            init="sample", atom_steps=80, joint_steps=60, nnls_iters=40,
            final_steps=200, atom_restarts=2,
        )
    ck, cv, logw = jax.vmap(
        lambda kc, kh, vh: compress_head(kc, kh, vh, n_centroids, method, ckm_cfg)
    )(keys, kk.astype(jnp.float32), vv.astype(jnp.float32))
    ck = ck.reshape(b, kvh, n_centroids, hd).transpose(0, 2, 1, 3).astype(k.dtype)
    cv = cv.reshape(b, kvh, n_centroids, hd).transpose(0, 2, 1, 3).astype(v.dtype)
    clogw = logw.reshape(b, kvh, n_centroids).transpose(0, 2, 1)
    return {"ck": ck, "cv": cv, "clogw": clogw}


def build_compressed_cache(
    key: jax.Array,
    k: jax.Array,
    v: jax.Array,
    n_centroids: int,
    ring: int,
    method: str = "lloyd",
) -> Params:
    """Full compressed-cache constructor for a prefix of S tokens.

    Position layout (S = k.shape[1], decode continues at index S):
    - centroids cover positions [0, S-ring]  (inclusive),
    - the exact ring holds positions (S-ring, S) — ring-1 entries at their
      ``pos % ring`` slots, leaving slot ``S % ring`` vacant for the incoming
      token (so the first decode step overwrites nothing live).
    Steady state: tokens aging out of the ring between recompressions are
    approximated only by the centroid mass (bounded by the recompression
    period — same contract as H2O/SnapKV-style cache eviction, but here the
    evicted mass is *summarised*, not dropped).
    """
    b, s, kvh, hd = k.shape
    assert s > ring >= 1, (s, ring)
    split = s - ring + 1  # centroids cover [0, split)
    comp = compress_kv(key, k[:, :split], v[:, :split], n_centroids, method)
    ring_k = jnp.zeros((b, ring, kvh, hd), k.dtype)
    ring_v = jnp.zeros((b, ring, kvh, hd), v.dtype)
    pos = jnp.arange(split, s)
    slots = pos % ring
    ring_k = ring_k.at[:, slots].set(k[:, split:])
    ring_v = ring_v.at[:, slots].set(v[:, split:])
    return {**comp, "k": ring_k, "v": ring_v}


def attention_decode_compressed(
    params: Params,
    dims: L.AttnDims,
    x: jax.Array,
    cache: Params,
    index: jax.Array,
):
    """Decode attention over [centroids + recent ring].  x: (B, 1, d).

    cache: {"ck","cv","clogw","k","v"} — the raw ring ("k","v") holds the most
    recent tokens exactly; older history lives in the weighted centroids.
    Returns (out (B, 1, d), updated kv cache entries).
    """
    b = x.shape[0]
    h, kvh, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    ring = cache["k"].shape[1]
    pos = jnp.full((b, 1), index, jnp.int32)
    q, k_new, v_new = L._qkv(params, dims, x, pos)
    slot = index % ring
    ck_ring = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    cv_ring = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)

    rep = h // kvh
    qh = q.reshape(b, 1, kvh, rep, hd)
    # Scores over centroids, with the log-cluster-size bias.
    s_cent = jnp.einsum("bqkrh,bskh->bkrqs", qh, cache["ck"]).astype(jnp.float32)
    s_cent = s_cent / jnp.sqrt(hd) + cache["clogw"].transpose(0, 2, 1)[:, :, None, None, :]
    # Scores over the exact recent ring.
    s_ring = jnp.einsum("bqkrh,bskh->bkrqs", qh, ck_ring).astype(jnp.float32)
    s_ring = s_ring / jnp.sqrt(hd)
    ring_pos = jnp.arange(ring)
    valid = (ring_pos <= slot) | (index >= ring)
    s_ring = jnp.where(valid[None, None, None, None, :], s_ring, -1e30)

    scores = jnp.concatenate([s_cent, s_ring], axis=-1)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    vals = jnp.concatenate([cache["cv"], cv_ring], axis=1)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, vals).reshape(b, 1, h * hd)
    out = out @ params["wo"].astype(x.dtype)
    return out, {"k": ck_ring, "v": cv_ring}
