"""Tenant-sharded sketch serving: ingest, decode-on-demand, evict/restore.

``FleetService`` is the request-facing wrapper around
:class:`repro.core.fleet.FleetEngine`: it buffers interleaved
``(tenant_id, batch)`` requests, flushes them through the async ingest
pipeline (``core.ingest.prefetched`` stages host->device transfer under
compute, exactly like ``fit_streaming``'s async mode) into the stacked state
via the engine's segment-scatter, and serves **decode-on-demand**: a tenant's
centroids are only computed when asked for, and memoised in an LRU keyed on
``(tenant, state_version)`` — traffic for other tenants never invalidates a
cached decode, and any write to a tenant bumps its version so a stale decode
can never be served.

Cold tenants are evicted through ``checkpoint.checkpointer.Checkpointer``:
the tenant's O(m) state row plus its ``FreqOpSpec`` (the ~70 B operator
recipe — never the matrix) land in an atomic per-tenant checkpoint, the row
is reset to the monoid identity, and the first request or decode that
touches the tenant again restores it transparently.  Restore reproduces the
exact pre-eviction accumulators (bitwise — `tests/test_fleet.py`), so
evict/restore is invisible in the sketch algebra.

Default decoder: ``"sketch_shift"`` (Belhadji & Gribonval 2023) — the cheap
decoder the hot decode path wants; any registered decoder name works.

Shard-aware routing: when the engine is mesh-sharded
(``FleetEngine(sharding="mesh")``), :meth:`FleetService.flush` partitions the
interleaved request stream **host-side** by owning shard
(:func:`shard_partition`) before grouping, so every segment-scatter dispatch
touches exactly one shard's contiguous block of rows.  Per-tenant arrival
order is preserved (a tenant's shard is fixed), which keeps the bitwise
isolation contract; only the never-observable cross-tenant interleaving
across shards is reordered.  Decode-on-demand, drift maintenance, and
evict/restore go through the engine's tenant surgery, which reads/writes the
owning shard's rows — the ``(tenant, version)`` LRU and drift-triggered
re-decode work unchanged.

Windowed serving: ``FleetService(window_buckets=W)`` additionally folds every
flush into a ``core.window.SketchWindow`` ring over the same engine (requests
must then carry their tick: ``submit(tenant, batch, t=...)``), and
evict/restore checkpoints the tenant's W bucket-column rows alongside the
lifetime row — bucket count/ticks are validated against the manifest meta,
and on restore only columns whose slot still holds the checkpointed tick
re-enter the ring (slots reclaimed by newer ticks hold other tenants' fresh
buckets; the evicted tenant's data there is expired by definition).
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from pathlib import Path
from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import ckm as ckm_mod
from repro.core import fleet as fleet_mod
from repro.core import ingest as ingest_mod
from repro.obs import runtime as obs_rt

__all__ = [
    "DecodeResult",
    "FleetServiceStats",
    "FleetService",
    "shard_partition",
]


def shard_partition(pending, owner, n_shards: int):
    """Stable host-side partition of ``(tenant, ...)`` requests by shard.

    Returns the requests regrouped shard 0 first, preserving each shard's —
    and therefore each *tenant's* — internal arrival order (``owner`` is a
    function of the tenant id alone).  With a mesh-sharded engine this is
    what makes every flush dispatch's scatter land inside one shard's
    contiguous row block; the cross-shard reordering it introduces touches
    only request pairs of different tenants, whose relative order was never
    observable (different rows of the stacked monoid state).
    """
    buckets: list[list] = [[] for _ in range(n_shards)]
    for req in pending:
        buckets[owner(req[0])].append(req)
    return [req for bucket in buckets for req in bucket], buckets


class DecodeResult(NamedTuple):
    """One tenant's decoded model + the cache bookkeeping around it."""

    centroids: jax.Array  # (K, n)
    weights: jax.Array  # (K,)
    cost: jax.Array  # sketch-domain objective of the decode
    version: int  # tenant state version the decode corresponds to
    cached: bool  # True when served from the LRU


@dataclasses.dataclass
class FleetServiceStats:
    requests: int = 0  # (tenant, batch) requests folded in
    points: int = 0  # data points folded in
    flushes: int = 0  # ingest dispatches into the stacked state
    decodes: int = 0  # decode calls answered
    decode_hits: int = 0  # served from the LRU
    decode_misses: int = 0  # freshly decoded
    decode_cache_evictions: int = 0  # LRU entries dropped at capacity
    evictions: int = 0
    restores: int = 0
    drift_redecodes: int = 0  # decodes forced by a drift_threshold breach

    @property
    def hit_rate(self) -> float:
        return self.decode_hits / self.decodes if self.decodes else 0.0


class FleetService:
    """Multi-tenant sketch service over one stacked FleetEngine state.

    Parameters
    ----------
    engine : the :class:`~repro.core.fleet.FleetEngine` holding the fleet.
    decode_config : ``CKMConfig`` used for every decode (``decoder`` defaults
        to ``"sketch_shift"`` when the caller leaves the CKMConfig default
        ``"clompr"`` untouched — pass an explicit decoder to override).
    decode_cache_entries : LRU capacity in decoded models (0 disables).
    checkpoint_dir : directory for per-tenant eviction checkpoints (required
        by :meth:`evict`).
    decode_key : PRNG key for decoder inits; tenant t decodes under
        ``fold_in(decode_key, t)`` so decodes are deterministic per tenant.
    drift_threshold : optional CF-distance bound for unattended drift
        maintenance — a positive scalar (one bound for the whole fleet) or
        a per-tenant array of shape ``(n_tenants,)`` so hot tenants can
        re-decode more aggressively than cold ones.  When set, every
        :meth:`flush` scores the flushed tenants' live sketches against
        their *cached* decodes (``obs.diagnose.sketch_drift``); a tenant
        over its bound has its cache entries invalidated and is re-decoded
        immediately (counter ``fleet.redecode.drift``; the applied bound is
        exported as the per-tenant gauge ``fleet.drift.threshold``).
        Tenants without a cached decode are never scored — maintenance
        refreshes stale models, it does not force first decodes.
    window_buckets, window_bucket_ticks : ``window_buckets=W > 0`` attaches
        a W-bucket ``core.window.SketchWindow`` ring over the same engine
        (``SketchJobSpec.window_buckets`` / ``window_bucket_ticks``): every
        flush folds into the lifetime state AND the request tick's bucket,
        so windowed reads/finalizes are available next to lifetime decodes,
        and evict/restore carries the tenant's bucket columns.  Windowed
        submissions must pass their tick (``submit(..., t=...)``).
    """

    def __init__(
        self,
        engine: fleet_mod.FleetEngine,
        decode_config: ckm_mod.CKMConfig,
        *,
        decode_cache_entries: int = 256,
        checkpoint_dir: str | Path | None = None,
        decode_key: jax.Array | None = None,
        drift_threshold=None,
        window_buckets: int = 0,
        window_bucket_ticks: float = 1.0,
    ):
        self.engine = engine
        if decode_config.decoder == "clompr":
            decode_config = dataclasses.replace(
                decode_config, decoder="sketch_shift"
            )
        self.decode_config = decode_config
        self.state = engine.init_state()
        self.decode_cache_entries = int(decode_cache_entries)
        self.checkpoint_dir = (
            Path(checkpoint_dir) if checkpoint_dir is not None else None
        )
        self.decode_key = (
            decode_key if decode_key is not None else jax.random.PRNGKey(0)
        )
        if drift_threshold is None:
            self.drift_threshold = None
        else:
            arr = np.asarray(drift_threshold, np.float64)
            if arr.ndim == 0:
                if not arr > 0:
                    raise ValueError(
                        f"drift_threshold must be positive, got "
                        f"{drift_threshold!r}"
                    )
                self.drift_threshold = float(arr)
            else:
                if arr.shape != (engine.n_tenants,):
                    raise ValueError(
                        f"per-tenant drift_threshold must have shape "
                        f"({engine.n_tenants},), got {arr.shape}"
                    )
                if not np.all(arr > 0):
                    raise ValueError(
                        "per-tenant drift_threshold entries must all be "
                        "positive"
                    )
                self.drift_threshold = arr
        if window_buckets < 0:
            raise ValueError(
                f"window_buckets must be >= 0, got {window_buckets}"
            )
        self.window = None
        self.window_state = None
        if window_buckets:
            from repro.core.window import SketchWindow

            self.window = SketchWindow(
                engine, int(window_buckets),
                bucket_ticks=float(window_bucket_ticks),
            )
            self.window_state = self.window.init_state()
        self.stats = FleetServiceStats()
        self._versions = np.zeros(engine.n_tenants, np.int64)
        self._cache: OrderedDict[tuple[int, int], DecodeResult] = OrderedDict()
        self._pending: list[tuple[int, np.ndarray, float | None]] = []
        self._evicted: set[int] = set()

    # -- versions -----------------------------------------------------------

    def version(self, tenant: int) -> int:
        """Monotone per-tenant write counter — the decode-cache key half."""
        return int(self._versions[tenant])

    def _touch(self, tenants: Iterable[int]):
        for t in set(int(t) for t in tenants):
            self._versions[t] += 1

    # -- ingest -------------------------------------------------------------

    def submit(self, tenant: int, batch, t: float | None = None) -> None:
        """Queue one ``(tenant, (B, n) batch)`` request for the next flush.

        ``t`` is the request's tick for decay-enabled or windowed fleets
        (forwarded to ``FleetEngine.ingest`` / the window's bucket ring);
        ``t=None`` folds at each tenant's current stamp.  Passing ``t``
        without decay or a window is an error; a windowed service requires
        it (every request must name its bucket)."""
        tid = int(tenant)
        if not 0 <= tid < self.engine.n_tenants:
            raise ValueError(
                f"tenant {tid} out of range [0, {self.engine.n_tenants})"
            )
        if t is not None and self.engine.decay is None and self.window is None:
            raise ValueError(
                "submit(t=...) requires a decay-enabled fleet "
                "(FleetEngine(..., decay=gamma)) or a windowed service "
                "(FleetService(..., window_buckets=W))"
            )
        if t is None and self.window is not None:
            raise ValueError(
                "a windowed FleetService needs every request's tick: "
                "submit(tenant, batch, t=...)"
            )
        self._pending.append((tid, batch, None if t is None else float(t)))

    def flush(self, *, async_ingest: bool = False, prefetch: int = 2) -> int:
        """Fold every queued request into the stacked state; returns the
        number of requests folded.

        Requests are folded in arrival order (the bitwise tenant-isolation
        contract).  Consecutive requests sharing a batch shape are routed as
        ONE segment-scatter dispatch; ``async_ingest=True`` threads the
        request stream through ``core.ingest.prefetched`` so host->device
        staging of batch r+1 overlaps the fold of batch r.

        With a mesh-sharded engine the flush is first partitioned by owning
        shard (:func:`shard_partition`) so each dispatch's scatter touches
        one shard's contiguous rows; per-tenant order — the observable one —
        is untouched.  A windowed service additionally folds every dispatch
        into its tick's bucket.
        """
        pending, self._pending = self._pending, []
        if not pending:
            return 0
        t_flush = time.perf_counter()
        for t, _, _ in pending:
            if t in self._evicted:
                self.restore(t)
        if self.engine.tenant_shards > 1:
            pending, by_shard = shard_partition(
                pending, self.engine.owner_shard, self.engine.tenant_shards
            )
            if obs_rt.ENABLED:
                from repro.obs import metrics as obs_metrics

                for s, bucket in enumerate(by_shard):
                    if bucket:
                        obs_metrics.counter(
                            "fleet.flush.shard_requests", shard=s
                        ).inc(len(bucket))

        def requests():
            for t, b, ts in pending:
                yield t, jnp.asarray(b, jnp.float32), ts

        stream: Iterable = requests()
        if async_ingest:
            stream = ingest_mod.prefetched(
                requests(),
                prefetch,
                place=lambda tb: (tb[0], jax.device_put(tb[1]), tb[2]),
            )

        group_ids: list[int] = []
        group_batches: list[jax.Array] = []
        group_t: list[float | None] = [None]

        def dispatch():
            if not group_ids:
                return
            ids = np.asarray(group_ids)
            stacked = jnp.stack(group_batches)
            kwargs = {}
            if self.engine.decay is not None:
                kwargs["t"] = group_t[0]
            self.state = self.engine.ingest(self.state, ids, stacked, **kwargs)
            if self.window is not None:
                self.window_state = self.window.ingest(
                    self.window_state, ids, stacked, t=group_t[0]
                )
            self.stats.flushes += 1
            group_ids.clear()
            group_batches.clear()

        from repro.obs import trace as obs_trace

        with obs_trace.span(
            "fleet.flush", requests=len(pending), async_ingest=async_ingest
        ):
            for t, b, ts in stream:
                if group_batches and (
                    b.shape != group_batches[0].shape or ts != group_t[0]
                ):
                    dispatch()  # ragged boundary: keep arrival order intact
                group_ids.append(t)
                group_batches.append(b)
                group_t[0] = ts
                self.stats.requests += 1
                self.stats.points += int(b.shape[0])
            dispatch()
            if obs_rt.ENABLED:
                # Sync so the flush span/histogram measure the fold, not its
                # async dispatch; the untelemetered path keeps dispatching.
                jax.block_until_ready(self.state)
        self._touch(t for t, _, _ in pending)
        if obs_rt.ENABLED:
            from repro.obs import metrics as obs_metrics

            obs_metrics.histogram("fleet.flush.seconds").observe(
                time.perf_counter() - t_flush
            )
            obs_metrics.counter("fleet.flush.requests").inc(len(pending))
        if self.drift_threshold is not None:
            self.maintain(set(t for t, _, _ in pending))
        return len(pending)

    def ingest(
        self,
        tenant_ids,
        batches,
        *,
        async_ingest: bool = False,
        t: float | None = None,
    ) -> int:
        """Submit + flush in one call (aligned request arrays or lists)."""
        for tid, b in zip(tenant_ids, batches):
            self.submit(int(tid), b, t)
        return self.flush(async_ingest=async_ingest)

    def merge_partial(self, tenant: int, partial) -> None:
        """Fold an externally produced partial state (edge sketcher, another
        host's engine) into one tenant's row — monoid merge, versioned."""
        t = int(tenant)
        if t in self._evicted:
            self.restore(t)
        self.state = self.engine.merge_tenant(self.state, t, partial)
        self._touch([t])

    # -- decode-on-demand ---------------------------------------------------

    def decode(self, tenant: int, *, use_cache: bool = True) -> DecodeResult:
        """Centroids for one tenant, from its sketch alone (O(m) state read +
        one decode), memoised on ``(tenant, version)``."""
        t = int(tenant)
        if t in self._evicted:
            self.restore(t)
        self.stats.decodes += 1
        key = (t, self.version(t))
        if use_cache and key in self._cache:
            self._cache.move_to_end(key)
            self.stats.decode_hits += 1
            if obs_rt.ENABLED:
                from repro.obs import metrics as obs_metrics

                obs_metrics.counter("fleet.decode.hits").inc()
            return self._cache[key]._replace(cached=True)
        self.stats.decode_misses += 1
        from repro.obs import trace as obs_trace

        with obs_trace.span("fleet.decode", tenant=t, version=key[1]):
            z, lo, hi = self.engine.finalize_tenant(self.state, t)
            cents, alphas, cost = ckm_mod.decode_sketch(
                jax.random.fold_in(self.decode_key, t),
                z,
                self.engine.operator(t),
                lo,
                hi,
                self.decode_config,
            )
        result = DecodeResult(cents, alphas, cost, key[1], cached=False)
        if use_cache and self.decode_cache_entries > 0:
            self._cache[key] = result
            self._cache.move_to_end(key)
            while len(self._cache) > self.decode_cache_entries:
                self._cache.popitem(last=False)
                self.stats.decode_cache_evictions += 1
                if obs_rt.ENABLED:
                    from repro.obs import metrics as obs_metrics

                    obs_metrics.counter("fleet.decode.cache_evictions").inc()
        if obs_rt.ENABLED:
            from repro.obs import metrics as obs_metrics

            obs_metrics.counter("fleet.decode.misses").inc()
        return result

    def cache_len(self) -> int:
        return len(self._cache)

    def served_model(self, tenant: int) -> DecodeResult | None:
        """The decoded model this tenant is currently being served — its most
        recently used cache entry, at whatever state-version it was decoded.
        Returns None when the tenant has no cached decode (nothing is being
        served; :meth:`decode` would have to run).  Never decodes: this is
        the read-only probe :meth:`drift` and the maintenance loop score
        staleness against."""
        t = int(tenant)
        for ct, cv in reversed(self._cache):
            if ct == t:
                return self._cache[(ct, cv)]
        return None

    def drift(self, tenant: int) -> float:
        """O(m) sketch-space drift of one tenant: how far the live sketch has
        moved from the decoded model currently being served.

        The served model is the tenant's most recently used cache entry
        (whatever version it was decoded at); with no cached decode, a fresh
        decode is taken — drift then just reports that decode's residual.
        Emits the ``fleet.drift{tenant=...}`` gauge when telemetry is on.

        A tenant whose sketch is all-zero — fresh, reset, or fully decayed
        (``weight_sum -> 0``) — has nothing to drift *from*: the score is
        defined as 0.0 and no decode is attempted (decoding an empty sketch
        with ±inf data bounds would manufacture NaN centroids).
        """
        from repro.obs.diagnose import sketch_drift

        t = int(tenant)
        if t in self._evicted:
            self.restore(t)
        row = self.engine.tenant_state(self.state, t)
        if not float(row.weight_sum) > 0:
            if obs_rt.ENABLED:
                from repro.obs import metrics as obs_metrics

                obs_metrics.gauge("fleet.drift", tenant=t).set(0.0)
            return 0.0
        served = self.served_model(t)
        if served is None:
            served = self.decode(t)
        z_live, _, _ = self.engine.finalize_tenant(self.state, t)
        score = sketch_drift(
            z_live, served.centroids, served.weights, self.engine.operator(t)
        )
        if obs_rt.ENABLED:
            from repro.obs import metrics as obs_metrics

            obs_metrics.gauge("fleet.drift", tenant=t).set(score)
        return score

    # -- drift-triggered maintenance ----------------------------------------

    def threshold(self, tenant: int) -> float | None:
        """The drift bound applied to one tenant: the fleet-wide scalar, the
        tenant's entry of a per-tenant array, or None when maintenance is
        off."""
        if self.drift_threshold is None:
            return None
        if isinstance(self.drift_threshold, float):
            return self.drift_threshold
        return float(self.drift_threshold[int(tenant)])

    def maintain(self, tenants: Iterable[int] | None = None) -> int:
        """Score drift for the given tenants (default: every tenant with a
        cached decode) and re-decode the ones over ``drift_threshold``.

        On a breach the tenant's cache entries are invalidated first, so the
        forced decode can never be served from the LRU; the fresh model is
        cached at the current version and ``fleet.redecode.drift`` counts
        the event.  Only tenants that already have a cached decode are
        scored — a tenant nobody has decoded has no served model to go
        stale.  Returns the number of re-decodes.  :meth:`flush` calls this
        automatically for the flushed tenants when ``drift_threshold`` is
        set, which is what lets a fleet run unattended on drifting traffic.
        """
        if self.drift_threshold is None:
            return 0
        cached = {t for t, _ in self._cache}
        check = (
            sorted(cached)
            if tenants is None
            else sorted(cached & {int(t) for t in tenants})
        )
        redecoded = 0
        for t in check:
            thr = self.threshold(t)
            if obs_rt.ENABLED:
                from repro.obs import metrics as obs_metrics

                obs_metrics.gauge("fleet.drift.threshold", tenant=t).set(thr)
            if self.drift(t) <= thr:
                continue
            for key in [k for k in self._cache if k[0] == t]:
                del self._cache[key]
            self.decode(t)
            redecoded += 1
            self.stats.drift_redecodes += 1
            if obs_rt.ENABLED:
                from repro.obs import metrics as obs_metrics

                obs_metrics.counter("fleet.redecode.drift").inc()
        return redecoded

    # -- evict / restore ----------------------------------------------------

    def _checkpointer(self, tenant: int) -> Checkpointer:
        if self.checkpoint_dir is None:
            raise ValueError(
                "FleetService needs checkpoint_dir= to evict/restore tenants"
            )
        return Checkpointer(self.checkpoint_dir / f"tenant_{tenant:06d}")

    def evict(self, tenant: int) -> None:
        """Checkpoint a cold tenant's row (state + operator spec) and reset
        the row to the monoid identity — its fleet slot is reusable scratch
        until the tenant returns.  A windowed service checkpoints the
        tenant's bucket-column rows alongside the lifetime row and resets
        them too."""
        t = int(tenant)
        if t in self._evicted:
            return
        spec = self.engine.specs[t]
        if spec is None:
            raise ValueError(
                f"tenant {t} has no operator spec; eviction checkpoints the "
                "spec, not the operator leaves"
            )
        row = self.engine.tenant_state(self.state, t)
        meta = {
            "tenant": t,
            "version": self.version(t),
            "freq_op_spec": list(spec),
            "quantized_bits": self.engine.bits,
            "decay": self.engine.decay,
        }
        if self.window is None:
            payload = row
        else:
            payload = {
                "row": row,
                "window": list(self.window.tenant_column(self.window_state, t)),
            }
            meta.update(
                window_buckets=self.window.buckets,
                window_bucket_ticks=self.window.bucket_ticks,
                window_slot_tick=[int(x) for x in self.window_state.slot_tick],
                window_head=int(self.window_state.head),
            )
        ckpt = self._checkpointer(t)
        ckpt.save(self.version(t), payload, meta=meta)
        self.state = self.engine.reset_tenant(self.state, t)
        if self.window is not None:
            self.window_state = self.window.reset_tenant(self.window_state, t)
        self._evicted.add(t)
        self.stats.evictions += 1
        if obs_rt.ENABLED:
            from repro.obs import metrics as obs_metrics

            obs_metrics.counter("fleet.tenant.evictions").inc()

    def restore(self, tenant: int) -> None:
        """Load the latest eviction checkpoint back into the tenant's row.

        The stored spec must match the fleet's (the checkpoint is the
        tenant's identity, not just its numbers); the state row is restored
        bitwise and the version rewinds to the evicted one, so decodes
        cached before eviction become valid again.

        For a windowed service the checkpoint also carries the tenant's
        bucket columns: bucket count/ticks are validated against the
        manifest meta, and a checkpointed column re-enters the ring only if
        its slot still holds the tick it was saved under — slots the ring
        has since reclaimed for newer ticks stay untouched (the evicted
        tenant's bucket there is expired by definition).
        """
        t = int(tenant)
        if t not in self._evicted:
            return
        ckpt = self._checkpointer(t)
        meta = ckpt.read_meta()
        like = self.engine.tenant_engine(t).init_state()
        has_window = "window_buckets" in meta
        if has_window != (self.window is not None):
            raise ValueError(
                f"tenant {t} checkpoint "
                + (
                    f"carries {meta.get('window_buckets')} window buckets "
                    "but this FleetService is not windowed"
                    if has_window
                    else "has no window buckets but this FleetService runs "
                    f"window_buckets={self.window.buckets}"
                )
            )
        if self.window is None:
            row = ckpt.restore(like)
        else:
            if int(meta["window_buckets"]) != self.window.buckets:
                raise ValueError(
                    f"tenant {t} checkpoint was written with "
                    f"window_buckets={meta['window_buckets']}, service runs "
                    f"{self.window.buckets}"
                )
            if float(meta["window_bucket_ticks"]) != self.window.bucket_ticks:
                raise ValueError(
                    f"tenant {t} checkpoint was written with "
                    f"window_bucket_ticks={meta['window_bucket_ticks']}, "
                    f"service runs {self.window.bucket_ticks}"
                )
            payload = ckpt.restore(
                {"row": like, "window": [like] * self.window.buckets}
            )
            row = payload["row"]
            column = list(self.window.tenant_column(self.window_state, t))
            for slot, tick in enumerate(meta["window_slot_tick"]):
                if int(tick) >= 0 and int(tick) == int(
                    self.window_state.slot_tick[slot]
                ):
                    column[slot] = payload["window"][slot]
            self.window_state = self.window.set_tenant_column(
                self.window_state, t, column
            )
        spec = self.engine.specs[t]
        stored = meta.get("freq_op_spec")
        if stored is not None and spec is not None:
            stored_spec = type(spec)(
                *[tuple(v) if isinstance(v, list) else v for v in stored]
            )
            if stored_spec != spec:
                raise ValueError(
                    f"tenant {t} checkpoint spec {stored_spec} does not match "
                    f"the fleet's {spec}"
                )
        if meta.get("quantized_bits") != self.engine.bits:
            raise ValueError(
                f"tenant {t} checkpoint was written at "
                f"{meta.get('quantized_bits')} bits, fleet runs "
                f"{self.engine.bits}"
            )
        if meta.get("decay") != self.engine.decay:
            raise ValueError(
                f"tenant {t} checkpoint was written with decay="
                f"{meta.get('decay')}, fleet runs decay={self.engine.decay}"
            )
        self.state = self.engine.set_tenant(self.state, t, row)
        self._versions[t] = int(meta.get("version", self.version(t)))
        self._evicted.discard(t)
        self.stats.restores += 1
        if obs_rt.ENABLED:
            from repro.obs import metrics as obs_metrics

            obs_metrics.counter("fleet.tenant.restores").inc()

    @property
    def evicted(self) -> frozenset[int]:
        return frozenset(self._evicted)
