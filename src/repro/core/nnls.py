"""Non-negative least squares, jit-friendly (fixed shapes, fixed iterations).

CLOMPR's steps 3 and 4 solve ``min_{beta >= 0} ||z - A beta||_2`` where ``A``
stacks the (possibly normalised) atoms of the current support.  The support is
kept as a *padded* buffer with a boolean column mask so the whole decoder stays
inside one ``jit``.  We use FISTA (accelerated projected gradient) with a power
-iteration Lipschitz estimate — Matlab's ``lsqnonneg`` (active set) is replaced
by a fixed-iteration method with identical fixed points.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("iters", "power_iters"))
def nnls(
    a: jax.Array,
    z: jax.Array,
    mask: jax.Array,
    iters: int = 200,
    power_iters: int = 16,
) -> jax.Array:
    """Solve ``min_{beta>=0} ||z - a @ beta||`` with masked-out columns pinned to 0.

    a:    (d, s)  — atom matrix (columns are atoms; padded columns arbitrary)
    z:    (d,)    — target sketch
    mask: (s,)    — True for active columns
    """
    maskf = mask.astype(a.dtype)
    # Zero out dead columns with a select, not a multiply: padded columns may
    # hold NaN/inf, and 0 * NaN = NaN would poison the gram matrix.
    a = jnp.where(maskf[None, :] > 0, a, 0.0)
    gram = a.T @ a  # (s, s) — s is small (<= 2K), cheap & reused every step
    atz = a.T @ z

    # Lipschitz constant of grad: 2 * lambda_max(gram), via power iteration.
    def pw(v, _):
        v = gram @ v
        return v / jnp.maximum(jnp.linalg.norm(v), 1e-30), None

    v0 = jnp.ones((a.shape[1],), a.dtype) / jnp.sqrt(a.shape[1])
    v, _ = jax.lax.scan(pw, v0, None, length=power_iters)
    lam = v @ (gram @ v)
    # Empty support (all columns masked) or an all-zero atom matrix gives
    # gram = 0 and a Rayleigh quotient of ~0; the old 1e-12 floor turned that
    # into a ~5e11 step size and NaN iterates.  The fixed point is beta = 0
    # regardless, so freeze the iteration with a zero step instead.
    step = jnp.where(lam > 1e-12, 1.0 / (2.0 * jnp.maximum(lam, 1e-12)), 0.0)

    def body(carry, _):
        beta, y, t = carry
        grad = 2.0 * (gram @ y - atz)
        beta_next = jnp.maximum(y - step * grad, 0.0) * maskf
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_next = beta_next + ((t - 1.0) / t_next) * (beta_next - beta)
        return (beta_next, y_next, t_next), None

    beta0 = jnp.zeros((a.shape[1],), a.dtype)
    (beta, _, _), _ = jax.lax.scan(body, (beta0, beta0, jnp.asarray(1.0, a.dtype)), None, length=iters)
    return beta
