"""Pluggable reduction topologies for the SketchEngine's monoid ``merge``.

The engine's contract (``core/engine.py``) is that partial sketch states form
a **commutative monoid**: any merge schedule — flat all-reduce, binary tree,
ring token passing, stragglers folded in whenever they arrive — yields the
same finalized sketch.  This module makes the *schedule* a first-class,
registered object so the cross-device (and cross-host) cost of the merge can
be chosen per deployment instead of being hard-wired to one ``psum``:

- **host level** — :func:`reduce_states` folds a list of partial states with
  the engine's ``merge`` following a named schedule; :class:`StragglerMerger`
  is the online variant that absorbs partials in *arrival* order (delayed
  stragglers are legal by commutativity).
- **device level** — :func:`axis_reduce` is the in-``shard_map`` collective
  the sharded backend calls instead of a bare ``jax.lax.psum``: ``allreduce``
  lowers to the native psum/pmin/pmax, ``tree`` to a butterfly
  (recursive-doubling) exchange of ``ppermute`` steps, ``ring`` to token
  passing around the data axis.  All are built from ``jax.lax`` collectives,
  so they work under the ``utils/compat.py`` shard_map shim on every JAX
  version — call sites never touch ``jax.shard_map`` directly.

Why topology choice matters: for a p-device merge of an S-byte partial state,
the three schedules move different amounts of data and serialize different
numbers of hops (:func:`wire_cost_model`).  With QCKM-quantized int32 states
2-4x smaller on the wire (``core.quantize.state_wire_bytes``), the per-hop
latency term starts to dominate, and tree (log2 p hops) beats ring (p-1 hops)
on high-latency links while ring wins on bandwidth-bound fat states.

Numerics: integer states (the quantized path) reduce **bitwise identically**
under every topology — int32 addition is exactly associative and commutative.
Float states agree to roundoff (~1e-6 relative): the schedules re-associate
sums, which is exactly the freedom the monoid contract grants.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "Topology",
    "TOPOLOGIES",
    "register_topology",
    "get_topology",
    "available_topologies",
    "merge_schedule",
    "reduce_states",
    "StragglerMerger",
    "axis_reduce",
    "wire_cost_model",
    "fleet_wire_cost_model",
]

# Elementwise combine ops a reduction may carry.  "sum" is the monoid's
# accumulator add; "min"/"max" merge the box bounds harvested in the same pass.
_COMBINE = {"sum": jnp.add, "min": jnp.minimum, "max": jnp.maximum}
_PSUM_LIKE = {"sum": jax.lax.psum, "min": jax.lax.pmin, "max": jax.lax.pmax}


@dataclasses.dataclass(frozen=True)
class Topology:
    """A named merge schedule.

    ``plan(n)`` returns the host-level schedule as rounds of ``(dst, src)``
    merges over ``n`` partial states: within a round, merges touch disjoint
    states (they could run concurrently); ``dst`` accumulates ``src`` and the
    reduction's result ends up at ``root(n)``.  ``device_reduce`` performs the
    equivalent in-mesh collective over one named axis (inside ``shard_map``).
    """

    name: str
    plan: Callable[[int], list[list[tuple[int, int]]]]
    device_reduce: Callable[[jax.Array, str, Callable], jax.Array]
    root: Callable[[int], int] = lambda n: 0


TOPOLOGIES: dict[str, Topology] = {}


def register_topology(topo: Topology) -> Topology:
    """Add a topology to the registry (name collisions are an error)."""
    if topo.name in TOPOLOGIES:
        raise ValueError(f"topology {topo.name!r} already registered")
    TOPOLOGIES[topo.name] = topo
    return topo


def get_topology(name: str) -> Topology:
    if name not in TOPOLOGIES:
        raise ValueError(
            f"unknown reduce topology {name!r}; registered: "
            f"{available_topologies()}"
        )
    return TOPOLOGIES[name]


def available_topologies() -> tuple[str, ...]:
    return tuple(sorted(TOPOLOGIES))


# ---------------------------------------------------------------------------
# Host-level plans
# ---------------------------------------------------------------------------


def _flat_plan(n: int) -> list[list[tuple[int, int]]]:
    """All-reduce stand-in on the host: one accumulator, everyone folds in.

    (A real psum is p concurrent reduce-scatters; host-side the equivalent
    work is a flat left fold into rank 0.)
    """
    return [[(0, i)] for i in range(1, n)]


def _tree_plan(n: int) -> list[list[tuple[int, int]]]:
    """Balanced binary tree: ceil(log2 n) rounds of disjoint pairwise merges."""
    rounds: list[list[tuple[int, int]]] = []
    step = 1
    while step < n:
        rnd = [
            (dst, dst + step)
            for dst in range(0, n - step, 2 * step)
        ]
        if rnd:
            rounds.append(rnd)
        step *= 2
    return rounds


def _ring_plan(n: int) -> list[list[tuple[int, int]]]:
    """Token passing: rank i hands its accumulated token to rank i+1."""
    return [[(i + 1, i)] for i in range(n - 1)]


def merge_schedule(n: int, topology: str) -> list[list[tuple[int, int]]]:
    """The host-level schedule ``topology`` uses to reduce ``n`` partials."""
    if n < 1:
        raise ValueError(f"need at least one partial state, got n={n}")
    return get_topology(topology).plan(n)


def reduce_states(
    merge: Callable[[Any, Any], Any],
    states: Sequence[Any],
    topology: str = "allreduce",
    order: Sequence[int] | None = None,
) -> Any:
    """Fold partial states with ``merge`` following a named schedule.

    ``order`` optionally permutes the states first — the *arrival* order of
    delayed stragglers.  By the monoid laws every (topology, order) pair
    produces the same result: bitwise for integer states, to roundoff for
    float.  That invariance is property-tested in ``tests/test_topology.py``.
    """
    states = list(states)
    if order is not None:
        if sorted(order) != list(range(len(states))):
            raise ValueError(f"order must permute range({len(states)})")
        states = [states[i] for i in order]
    if not states:
        raise ValueError("need at least one partial state")
    topo = get_topology(topology)
    slots: list[Any] = list(states)
    for rnd in topo.plan(len(states)):
        for dst, src in rnd:
            slots[dst] = merge(slots[dst], slots[src])
    return slots[topo.root(len(states))]


class StragglerMerger:
    """Online, arrival-order fold — the straggler-tolerant merge.

    A coordinator does not have to wait for a schedule: partial states can be
    absorbed the moment they arrive (``add``), in any order, and the result is
    the same monoid reduction.  ``identity`` is the engine's ``init_state()``.
    """

    def __init__(self, merge: Callable[[Any, Any], Any], identity: Any):
        self._merge = merge
        self._acc = identity
        self.arrived = 0

    def add(self, state: Any) -> "StragglerMerger":
        self._acc = self._merge(self._acc, state)
        self.arrived += 1
        return self

    def result(self) -> Any:
        return self._acc


# ---------------------------------------------------------------------------
# Device-level (in-shard_map) collectives
# ---------------------------------------------------------------------------


def _axis_size(axis_name: str) -> int:
    # psum of a concrete 1 is evaluated at trace time -> a static int.
    return int(jax.lax.psum(1, axis_name))


def _allreduce_device(x: jax.Array, axis_name: str, combine) -> jax.Array:
    op = {jnp.add: "sum", jnp.minimum: "min", jnp.maximum: "max"}[combine]
    return _PSUM_LIKE[op](x, axis_name)


def _tree_device(x: jax.Array, axis_name: str, combine) -> jax.Array:
    """Butterfly (recursive doubling): log2 p full-permutation exchanges.

    Every step XORs the partner index, so all devices participate in every
    hop — no zero-filled ``ppermute`` holes, which keeps the same schedule
    valid for min/max bound merges, not just sums.
    """
    p = _axis_size(axis_name)
    if p & (p - 1):
        raise ValueError(
            f"tree (butterfly) reduction needs a power-of-two axis size, got "
            f"{p}; use 'ring' or 'allreduce' for this mesh"
        )
    step = 1
    while step < p:
        peer = jax.lax.ppermute(
            x, axis_name, [(i, i ^ step) for i in range(p)]
        )
        x = combine(x, peer)
        step *= 2
    return x


def _ring_device(x: jax.Array, axis_name: str, combine) -> jax.Array:
    """Ring token passing: p-1 neighbour hops, each carries the running fold.

    Unchunked (the whole state is the token): per-device traffic is
    (p-1)·S — latency-light per hop but bandwidth-heavier than psum's
    reduce-scatter; see :func:`wire_cost_model`.
    """
    p = _axis_size(axis_name)
    acc = x
    perm = [(i, (i + 1) % p) for i in range(p)]
    for _ in range(p - 1):
        acc = combine(jax.lax.ppermute(acc, axis_name, perm), x)
    return acc


register_topology(
    Topology("allreduce", _flat_plan, _allreduce_device)
)
register_topology(Topology("tree", _tree_plan, _tree_device))
register_topology(
    Topology("ring", _ring_plan, _ring_device, root=lambda n: n - 1)
)


def axis_reduce(
    x: jax.Array,
    axis_names: Sequence[str] | str,
    topology: str = "allreduce",
    op: str = "sum",
) -> jax.Array:
    """Reduce ``x`` over mesh ``axis_names`` inside a ``shard_map`` body.

    Drop-in for ``jax.lax.psum(x, axes)`` / ``pmin`` / ``pmax`` (``op``) that
    routes through the registered topology.  Multiple axes reduce
    sequentially, one collective per axis — a (data, pod) reduction becomes a
    within-pod pass followed by a cross-pod pass, which is exactly the
    hierarchical schedule multi-host deployments want.
    """
    if op not in _COMBINE:
        raise ValueError(f"op must be one of {sorted(_COMBINE)}, got {op!r}")
    topo = get_topology(topology)
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    for ax in axis_names:
        x = topo.device_reduce(x, ax, _COMBINE[op])
    return x


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


def wire_cost_model(state_bytes: int, p: int, topology: str) -> dict:
    """Per-device bytes sent and serialized hop count for a p-way merge.

    The standard alpha-beta model of one S-byte monoid state reduced over p
    links (documented in ``docs/scaling.md``'s topology matrix):

    ==========  =======================  ==================
    topology    bytes sent / device      serialized hops
    ==========  =======================  ==================
    allreduce   2·S·(p-1)/p              2·(p-1)   (ring RS+AG, the usual psum lowering)
    tree        S·log2(p)                log2(p)
    ring        S·(p-1)                  p-1       (unchunked token)
    ==========  =======================  ==================
    """
    get_topology(topology)  # validate the name
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    if p == 1:
        return {"topology": topology, "p": 1, "bytes_per_device": 0, "hops": 0}
    if topology == "allreduce":
        bytes_dev = 2.0 * state_bytes * (p - 1) / p
        hops = 2 * (p - 1)
    elif topology == "tree":
        hops = max(1, math.ceil(math.log2(p)))
        bytes_dev = float(state_bytes * hops)
    elif topology == "ring":
        bytes_dev = float(state_bytes * (p - 1))
        hops = p - 1
    else:  # a user-registered topology: no closed form — report unknowns
        return {"topology": topology, "p": p, "bytes_per_device": None,
                "hops": None}
    return {
        "topology": topology,
        "p": p,
        "bytes_per_device": bytes_dev,
        "hops": hops,
    }


def fleet_wire_cost_model(
    row_bytes: int,
    n_tenants: int,
    tenant_shards: int,
    topology: str = "tree",
) -> dict:
    """Wire cost of a tenant-sharded fleet's data paths.

    Sharding the tenant axis is pure data parallelism — every tenant's whole
    state lives on exactly one shard, so the serving hot path (update /
    ingest / finalize) moves **zero** bytes between shards
    (``steady_state_bytes``; the compiled program carries no collectives).
    What remains on the wire is the control plane, per tenant row of
    ``row_bytes`` (``quantize.state_wire_bytes`` for the quantized twin):

    - **checkpoint** (evict/restore): one tenant's O(m) row moves between
      the host and its *owning* shard only — ``row_bytes`` over one
      host-device link, independent of the shard count.
    - **broadcast** (shipping a spec/config/decode artifact to every
      shard): the reverse of ``merge_schedule``'s reduce plan — each of the
      ``p - 1`` non-root shards receives the row once
      (``broadcast_bytes_total``), serialized over the plan's round count
      (tree: ``ceil(log2 p)``, ring/flat: ``p - 1``).

    ``rows_per_shard``/``shard_state_bytes`` give the per-device residency
    the contiguous-block placement implies.  Documented as the fleet-sharding
    wire table in ``docs/scaling.md``.
    """
    get_topology(topology)  # validate the name
    p = int(tenant_shards)
    if p < 1:
        raise ValueError(f"tenant_shards must be >= 1, got {tenant_shards}")
    if n_tenants < 1 or n_tenants % p:
        raise ValueError(
            f"n_tenants={n_tenants} must be a positive multiple of "
            f"tenant_shards={p} (contiguous equal blocks per shard)"
        )
    rows = n_tenants // p
    return {
        "topology": topology,
        "tenant_shards": p,
        "rows_per_shard": rows,
        "row_bytes": int(row_bytes),
        "shard_state_bytes": int(row_bytes) * rows,
        "steady_state_bytes": 0,
        "checkpoint_bytes": int(row_bytes),
        "checkpoint_hops": 1,
        "broadcast_bytes_total": float(row_bytes * (p - 1)),
        "broadcast_hops": len(merge_schedule(p, topology)) if p > 1 else 0,
    }
