"""Multi-tenant fleet engine: thousands of sketch states as ONE stacked state.

The paper's selling point — sketch size O(K·n) independent of dataset size —
compounds across users: a tenant's entire clustering state is its O(m) sketch
accumulators plus the ~70 B ``FreqOpSpec`` rebuild recipe (PR 5), so thousands
of independent tenants fit in the memory one Lloyd-Max run would need.  This
module is the compute layer that exploits that: per-tenant
:class:`~repro.core.engine.SketchEngineState` s are held **stacked along a
leading tenant axis** (``cos_acc (T, m)``, ``lower (T, n)``, …) and every
monoid op runs ``vmap``-ed over that axis — one XLA dispatch for the whole
fleet instead of T Python-dispatched engine calls.

Contract: the vmapped monoid law
--------------------------------
For every tenant t, ``FleetEngine`` update/merge/finalize is **bitwise
identical** to a per-tenant :class:`~repro.core.engine.SketchEngine` with the
same operator/quantizer — the stacked path batches the *same* per-tenant
trace (`tests/test_fleet.py` pins this for float and quantized states on the
xla and pallas backends).  Everything the single-sketch stack guarantees
(split invariance, merge associativity/commutativity, quantized bitwise
merges) therefore lifts to the fleet for free.

Request routing: segment-scatter
--------------------------------
Serving traffic arrives as interleaved ``(tenant_id, batch)`` requests, not
as one aligned ``(T, B, n)`` block.  :meth:`FleetEngine.ingest` computes all
request partials in one vmapped pass (per-request operators gathered from the
stacked leaves by tenant id) and folds them into the stacked state with a
segment-scatter: when tenant ids are unique within the call this is one XLA
scatter-add/min/max per leaf; when a flush carries several requests for the
same tenant it falls back to an ordered ``lax.scan`` fold so float partials
combine in **arrival order** — exactly the association the tenant's isolated
engine would have used, keeping the bitwise-isolation contract.

Tenant state surgery (``tenant_state`` / ``set_tenant`` / ``reset_tenant``)
is what eviction/restore builds on: a cold tenant's row is checkpointed
(state leaves + spec), reset to the monoid identity, and scattered back in
on demand — see ``repro.serve.fleet_service``.

Mesh sharding: tenant parallelism
---------------------------------
``FleetEngine(sharding="mesh", tenant_shards=p)`` splits the stacked state
over a p-device mesh along ``tenant_shard_axis``: device s owns the
contiguous block of ``n_tenants / p`` tenant rows ``[s·block, (s+1)·block)``
— float and quantized int32 twins, the stacked operator leaves, dither rows,
and decay stamps all shard together (every fleet leaf leads with the tenant
axis, so one ``P(axis)`` spec rule covers the tree).  Tenants never talk to
each other, so this is *pure* data parallelism: ``update``/``finalize`` run
through ``utils.compat.shard_map`` (never ``jax.shard_map`` directly — repo
rule) with the same vmapped per-tenant trace inside each shard — one
dispatch per device, zero cross-shard collectives in the compiled program
(:meth:`FleetEngine.mesh_update_hlo` exposes the HLO so tests/benchmarks can
assert that), and per-tenant results stay bitwise equal to the unsharded
stack and to isolated engines.  ``merge`` and the tenant surgery are
elementwise/row-wise, so XLA keeps them on the owning shard without an
explicit shard_map; ``ingest`` scatters land on the owning shard's rows
(``serve.fleet_service`` routes interleaved requests host-side so each
dispatch touches one shard's block).  Wire costs of the remaining
control-plane paths are modeled by ``core.topology.fleet_wire_cost_model``.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import engine as eng_mod
from repro.core import freq_ops as fo
from repro.core import quantize as qz
from repro.core import sketch as sk
from repro.core.engine import (
    DecayedQuantizedSketchEngineState,
    DecayedSketchEngineState,
    QuantizedSketchEngineState,
    SketchEngineState,
)

__all__ = [
    "FLEET_BACKENDS",
    "FLEET_SHARDINGS",
    "FleetEngine",
    "fleet_specs",
    "fleet_quantizers",
    "stack_operators",
]

# The fleet batches per-tenant compute with vmap; the sharded backend manages
# its own mesh collective and is not a per-tenant trace to batch.
FLEET_BACKENDS = ("xla", "pallas")

# How the stacked state is placed: "none" keeps every tenant row on the
# default device; "mesh" splits the tenant axis over a device mesh (the
# per-tenant trace backend above stays orthogonal — both backends vmap
# within each shard).
FLEET_SHARDINGS = ("none", "mesh")


def fleet_specs(
    key: jax.Array,
    n_tenants: int,
    name: str,
    m: int,
    n: int,
    sigma2,
    *,
    dist: str = "adapted_radius",
    dtype=jnp.float32,
) -> list[fo.FreqOpSpec]:
    """Independent per-tenant operator specs from one parent key.

    Tenant t draws from ``fold_in(key, t)`` — the recipe list is what a
    control plane ships (~70 B/tenant) and what :class:`FleetEngine` rebuilds
    operators from.
    """
    specs = []
    for t in range(n_tenants):
        op = fo.make_operator(
            name, jax.random.fold_in(key, t), m, n, sigma2, dist=dist,
            dtype=dtype,
        )
        specs.append(op.spec())
    return specs


def fleet_quantizers(
    key: jax.Array, n_tenants: int, m: int, spec: str
) -> list[qz.SketchQuantizer] | None:
    """Per-tenant quantizers (independent dither draws) or None for float."""
    if spec == "none":
        return None
    return [
        qz.make_quantizer(jax.random.fold_in(key, t), m, spec)
        for t in range(n_tenants)
    ]


def stack_operators(ops: Sequence[fo.FrequencyOperator]):
    """Stack operator leaves along a new leading tenant axis.

    Returns ``(stacked_op, treedefs)``: ``stacked_op`` is a pytree of the
    operator class whose array leaves carry the tenant axis (valid *only* as
    a vmap/gather carrier — its static n/m/spec aux comes from tenant 0), and
    ``treedefs`` the per-tenant treedefs used to slice true per-tenant
    operators back out.
    """
    flat = [jax.tree_util.tree_flatten(op) for op in ops]
    leaves0, treedef0 = flat[0]
    for t, (leaves, _) in enumerate(flat[1:], start=1):
        if len(leaves) != len(leaves0) or any(
            a.shape != b.shape or a.dtype != b.dtype
            for a, b in zip(leaves, leaves0)
        ):
            raise ValueError(
                f"tenant {t} operator leaves do not match tenant 0 "
                "(all fleet tenants must share the operator family and (n, m))"
            )
    stacked = [jnp.stack(ls) for ls in zip(*(leaves for leaves, _ in flat))]
    return (
        jax.tree_util.tree_unflatten(treedef0, stacked),
        [treedef for _, treedef in flat],
    )


class FleetEngine:
    """T independent sketch engines as one vmapped, stacked-state engine.

    Parameters
    ----------
    operators : per-tenant frequency operators **or** their ``FreqOpSpec`` s
        (rebuilt via ``freq_ops.from_spec`` — the ~70 B recipe is the
        canonical fleet description).  All tenants must share the family and
        ``(n, m)``; keys/scales may differ freely.
    backend : ``"xla"`` or ``"pallas"`` — the per-tenant update trace that is
        vmapped (same dispatch as ``SketchEngine``'s backend matrix).
    quantizers : optional per-tenant ``SketchQuantizer`` s (one dither row
        each, shared bit width) — switches the stacked state to the int32
        :class:`~repro.core.engine.QuantizedSketchEngineState` twin.
    chunk, block_n, block_m, interpret : forwarded to the per-tenant trace.
    decay : optional per-tick exponential decay base gamma in (0, 1], shared
        by every tenant — switches the stacked state to the timestamped
        decayed twin (stamps ``(T,)``), exactly as
        ``SketchEngine(decay=...)`` does per tenant.  ``update``/``ingest``
        then accept a keyword ``t`` and :meth:`decay_to` advances the whole
        fleet's clock in one dispatch.
    sharding : ``"none"`` (default — the whole stack on one device) or
        ``"mesh"`` — split the tenant axis over a device mesh so shard s
        owns the contiguous rows ``[s·T/p, (s+1)·T/p)``.  Update/finalize
        then run the vmapped trace *within each shard* through the
        ``utils.compat.shard_map`` shim: one dispatch per device, zero
        cross-shard collectives, bitwise the unsharded rows.
    mesh : the 1-D mesh to shard over (``sharding="mesh"`` only).  Default:
        ``parallel.sharding.tenant_mesh(tenant_shards, tenant_shard_axis)``
        over the first ``tenant_shards`` local devices.
    tenant_shards : shard count p — must divide ``n_tenants`` (matches
        ``SketchJobSpec.tenant_shards`` validation).  Default: the given
        mesh's axis size, else every local device.
    tenant_shard_axis : mesh-axis name the tenant axis maps onto
        (``SketchJobSpec.tenant_shard_axis``).
    """

    def __init__(
        self,
        operators: Sequence[fo.FrequencyOperator | fo.FreqOpSpec],
        *,
        backend: str = "xla",
        quantizers: Sequence[qz.SketchQuantizer] | None = None,
        chunk: int = 8192,
        block_n: int = 1024,
        block_m: int = 512,
        interpret: bool | None = None,
        decay: float | None = None,
        sharding: str = "none",
        mesh=None,
        tenant_shards: int | None = None,
        tenant_shard_axis: str = "tenant",
    ):
        if backend not in FLEET_BACKENDS:
            raise ValueError(
                f"fleet backend must be one of {FLEET_BACKENDS}, got "
                f"{backend!r}"
            )
        if sharding not in FLEET_SHARDINGS:
            raise ValueError(
                f"fleet sharding must be one of {FLEET_SHARDINGS}, got "
                f"{sharding!r}"
            )
        if sharding == "none" and (mesh is not None or tenant_shards not in (None, 1)):
            raise ValueError(
                "mesh=/tenant_shards= require FleetEngine(sharding='mesh')"
            )
        if decay is not None and not 0.0 < float(decay) <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay!r}")
        if not operators:
            raise ValueError("a fleet needs at least one tenant operator")
        ops = [
            fo.from_spec(o) if isinstance(o, fo.FreqOpSpec) else o
            for o in operators
        ]
        self.n_tenants = len(ops)
        self.n, self.m = ops[0].n, ops[0].m
        self.backend = backend
        self.chunk = chunk
        self.block_n = block_n
        self.block_m = block_m
        self.interpret = interpret
        self.decay = None if decay is None else float(decay)
        self.specs: tuple[fo.FreqOpSpec | None, ...] = tuple(
            self._try_spec(op) for op in ops
        )
        self._stacked_op, self._op_treedefs = stack_operators(ops)
        self._op_leaves = jax.tree_util.tree_leaves(self._stacked_op)
        self.bits: int | None = None
        self.dither: jax.Array | None = None
        if quantizers is not None:
            if len(quantizers) != self.n_tenants:
                raise ValueError(
                    f"{len(quantizers)} quantizers for {self.n_tenants} "
                    "tenants"
                )
            bits = {q.bits for q in quantizers}
            if len(bits) != 1:
                raise ValueError(
                    f"all fleet tenants must share a bit width, got {bits}"
                )
            self.bits = bits.pop()
            self.dither = jnp.stack([q.dither for q in quantizers])
            if self.dither.shape != (self.n_tenants, self.m):
                raise ValueError(
                    f"stacked dither shape {self.dither.shape} != "
                    f"{(self.n_tenants, self.m)}"
                )
        self.sharding = sharding
        self.tenant_shard_axis = str(tenant_shard_axis)
        self.mesh = None
        self.tenant_shards = 1
        self._tenant_sharding = None
        self._mesh_update_jit = None
        self._mesh_finalize_jit = None
        if sharding == "mesh":
            from repro.parallel.sharding import axis_extent, tenant_mesh

            if mesh is None:
                mesh = tenant_mesh(
                    tenant_shards
                    if tenant_shards is not None
                    else len(jax.devices()),
                    axis=self.tenant_shard_axis,
                )
            if self.tenant_shard_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh axes {mesh.axis_names} do not include the tenant "
                    f"shard axis {self.tenant_shard_axis!r}"
                )
            p = axis_extent(mesh, (self.tenant_shard_axis,))
            if tenant_shards is not None and int(tenant_shards) != p:
                raise ValueError(
                    f"tenant_shards={tenant_shards} but the mesh's "
                    f"{self.tenant_shard_axis!r} axis has {p} devices"
                )
            if self.n_tenants % p:
                raise ValueError(
                    f"n_tenants={self.n_tenants} is not divisible by "
                    f"tenant_shards={p}; every shard must hold an equal "
                    "contiguous block of tenant rows"
                )
            self.mesh = mesh
            self.tenant_shards = p
            self._tenant_sharding = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(self.tenant_shard_axis)
            )
            # The stacked operator leaves and dither rows live with their
            # tenants: placed once here, a shard's update never reads
            # another device's memory.
            self._stacked_op = jax.tree_util.tree_map(
                lambda l: jax.device_put(l, self._tenant_sharding),
                self._stacked_op,
            )
            self._op_leaves = jax.tree_util.tree_leaves(self._stacked_op)
            if self.dither is not None:
                self.dither = jax.device_put(
                    self.dither, self._tenant_sharding
                )

    @property
    def shard_rows(self) -> int:
        """Tenant rows per shard (= n_tenants with ``sharding="none"``)."""
        return self.n_tenants // self.tenant_shards

    def owner_shard(self, tenant: int) -> int:
        """The shard whose contiguous block holds ``tenant``'s row — what
        ``serve.fleet_service`` partitions interleaved requests by."""
        t = int(tenant)
        if not 0 <= t < self.n_tenants:
            raise ValueError(
                f"tenant {t} out of range [0, {self.n_tenants})"
            )
        return t // self.shard_rows

    def place_state(self, state):
        """Pin a stacked state's leaves onto the tenant sharding (identity
        for ``sharding="none"``).  ``init_state`` places automatically; use
        this after building a stacked state host-side (restored checkpoints,
        restacked rows) so the hot path starts on the owning devices."""
        if self._tenant_sharding is None:
            return state
        return jax.tree_util.tree_map(
            lambda l: jax.device_put(l, self._tenant_sharding), state
        )

    @staticmethod
    def _try_spec(op: fo.FrequencyOperator) -> fo.FreqOpSpec | None:
        try:
            return op.spec()
        except ValueError:
            return None

    @property
    def quantized(self) -> bool:
        return self.bits is not None

    # -- per-tenant views ---------------------------------------------------

    def operator(self, tenant: int) -> fo.FrequencyOperator:
        """Tenant ``tenant``'s own operator, sliced from the stacked leaves
        (bitwise the operator it was constructed from)."""
        leaves = [l[tenant] for l in self._op_leaves]
        return jax.tree_util.tree_unflatten(self._op_treedefs[tenant], leaves)

    def quantizer(self, tenant: int) -> qz.SketchQuantizer | None:
        if self.bits is None:
            return None
        return qz.SketchQuantizer(bits=self.bits, dither=self.dither[tenant])

    def tenant_engine(self, tenant: int) -> eng_mod.SketchEngine:
        """A plain single-tenant ``SketchEngine`` over tenant's operator —
        the reference this fleet is bitwise-parity-tested against."""
        return eng_mod.SketchEngine(
            self.operator(tenant),
            self.backend,
            chunk=self.chunk,
            block_n=self.block_n,
            block_m=self.block_m,
            interpret=self.interpret,
            quantizer=self.quantizer(tenant),
            decay=self.decay,
        )

    # -- stacked monoid ops -------------------------------------------------

    def init_state(self):
        """Stacked monoid identity: every tenant row is ``init_state()``."""
        t, n, m = self.n_tenants, self.n, self.m
        if self.quantized:
            base = QuantizedSketchEngineState(
                qcos_acc=jnp.zeros((t, m), jnp.int32),
                qsin_acc=jnp.zeros((t, m), jnp.int32),
                weight_sum=jnp.zeros((t,), jnp.float32),
                lower=jnp.full((t, n), jnp.inf, jnp.float32),
                upper=jnp.full((t, n), -jnp.inf, jnp.float32),
                count=jnp.zeros((t,), jnp.float32),
            )
        else:
            base = SketchEngineState(
                cos_acc=jnp.zeros((t, m), jnp.float32),
                sin_acc=jnp.zeros((t, m), jnp.float32),
                weight_sum=jnp.zeros((t,), jnp.float32),
                lower=jnp.full((t, n), jnp.inf, jnp.float32),
                upper=jnp.full((t, n), -jnp.inf, jnp.float32),
                count=jnp.zeros((t,), jnp.float32),
            )
        if self.decay is not None:
            base = self._lift_parts(
                base, jnp.full((t,), -jnp.inf, jnp.float32)
            )
        return self.place_state(base)

    def _lift_parts(self, parts, stamps):
        """Wrap stacked base partials as decayed states stamped ``stamps``
        (``(R,)`` — one tick per row), mirroring
        ``SketchEngine._lift_partial``."""
        stamps = jnp.asarray(stamps, jnp.float32)
        gamma = jnp.full(jnp.shape(stamps), self.decay, jnp.float32)
        if isinstance(parts, QuantizedSketchEngineState):
            return DecayedQuantizedSketchEngineState(
                qcos_acc=parts.qcos_acc,
                qsin_acc=parts.qsin_acc,
                dcos_acc=jnp.zeros_like(parts.qcos_acc, jnp.float32),
                dsin_acc=jnp.zeros_like(parts.qsin_acc, jnp.float32),
                weight_sum=parts.weight_sum,
                lower=parts.lower,
                upper=parts.upper,
                count=parts.count,
                stamp=stamps,
                gamma=gamma,
            )
        return DecayedSketchEngineState(
            cos_acc=parts.cos_acc,
            sin_acc=parts.sin_acc,
            weight_sum=parts.weight_sum,
            lower=parts.lower,
            upper=parts.upper,
            count=parts.count,
            stamp=stamps,
            gamma=gamma,
        )

    def _tenant_part(self, op, x, weights):
        """One tenant's batch partial — the SAME trace SketchEngine._batch_state
        runs, factored over the operator argument so vmap can batch it."""
        if self.backend == "pallas":
            from repro.kernels import ops

            cos_s, sin_s = ops.fourier_sketch_sums(
                x,
                op,
                weights,
                block_n=self.block_n,
                block_m=self.block_m,
                interpret=self.interpret,
            )
        else:
            part = sk.sketch(
                x,
                op,
                weights=weights,
                chunk=min(self.chunk, max(x.shape[0], 1)),
            )
            cos_s, sin_s = part[: self.m], -part[self.m :]
        return SketchEngineState(
            cos_acc=cos_s,
            sin_acc=sin_s,
            weight_sum=jnp.sum(weights),
            lower=jnp.min(x, axis=0),
            upper=jnp.max(x, axis=0),
            count=jnp.asarray(x.shape[0], jnp.float32),
        )

    def _tenant_qpart(self, op, dither, x):
        if self.backend == "pallas":
            from repro.kernels import ops

            qcos, qsin = ops.quantized_fourier_sketch_sums(
                x,
                op,
                dither,
                bits=self.bits,
                block_n=self.block_n,
                block_m=self.block_m,
                interpret=self.interpret,
            )
        else:
            qcos, qsin = sk.sketch_quantized(
                x,
                op,
                dither,
                bits=self.bits,
                chunk=min(self.chunk, max(x.shape[0], 1)),
            )
        n_pts = jnp.asarray(x.shape[0], jnp.float32)
        return QuantizedSketchEngineState(
            qcos_acc=qcos,
            qsin_acc=qsin,
            weight_sum=n_pts,
            lower=jnp.min(x, axis=0),
            upper=jnp.max(x, axis=0),
            count=n_pts,
        )

    def _parts(self, stacked_op, batches, weights):
        """Vmapped per-tenant partial states for stacked ``(R, B, n)`` batches."""
        x = jnp.asarray(batches, jnp.float32)
        if x.ndim != 3 or x.shape[-1] != self.n:
            raise ValueError(
                f"batches must be (T, B, {self.n}), got {x.shape}"
            )
        if self.quantized:
            if weights is not None:
                raise ValueError(
                    "quantized fleet states accumulate unit-weight integer "
                    "counts; per-point weights are not representable"
                )
            return jax.vmap(self._tenant_qpart)(stacked_op, self.dither, x)
        if weights is None:
            weights = jnp.ones(x.shape[:2], jnp.float32)
        else:
            weights = jnp.asarray(weights, jnp.float32)
        return jax.vmap(self._tenant_part)(stacked_op, x, weights)

    # -- mesh-sharded hot path ----------------------------------------------

    def _row_specs(self, tree):
        """``P(tenant_shard_axis)`` per leaf — every fleet leaf leads with
        the tenant axis (same rule as ``parallel.sharding.tenant_shard_specs``,
        inlined to keep this module importable without the parallel pkg)."""
        row = jax.sharding.PartitionSpec(self.tenant_shard_axis)
        return jax.tree_util.tree_map(lambda _: row, tree)

    def _mesh_update_fn(self, state):
        """The shard-mapped update, built once per engine: each device runs
        the SAME vmapped per-tenant trace over its contiguous block of rows
        (so row t is bitwise the unsharded row t), and no collective ever
        enters the program — tenants are independent."""
        if self._mesh_update_jit is not None:
            return self._mesh_update_jit
        from repro.utils import compat

        quantized, decayed = self.quantized, self.decay is not None

        def body(st, op, x, aux, *stamps):
            if quantized:
                parts = jax.vmap(self._tenant_qpart)(op, aux, x)
            else:
                parts = jax.vmap(self._tenant_part)(op, x, aux)
            if decayed:
                parts = self._lift_parts(parts, stamps[0])
            return eng_mod._merge_states(st, parts)

        row = jax.sharding.PartitionSpec(self.tenant_shard_axis)
        in_specs = (
            self._row_specs(state),
            self._row_specs(self._stacked_op),
            row,
            row,
        ) + ((row,) if decayed else ())
        fn = compat.shard_map(
            body,
            self.mesh,
            in_specs=in_specs,
            out_specs=self._row_specs(state),
            check_vma=False,
        )
        self._mesh_update_jit = jax.jit(fn)
        return self._mesh_update_jit

    def _mesh_update_args(self, state, batches, weights, t):
        """Validated ``(jitted_fn, operands)`` of the mesh update — shared by
        :meth:`update` and :meth:`mesh_update_hlo`."""
        x = jnp.asarray(batches, jnp.float32)
        if x.ndim != 3 or x.shape[-1] != self.n:
            raise ValueError(
                f"batches must be (T, B, {self.n}), got {x.shape}"
            )
        if self.quantized:
            if weights is not None:
                raise ValueError(
                    "quantized fleet states accumulate unit-weight integer "
                    "counts; per-point weights are not representable"
                )
            aux = self.dither  # (T, m), placed with its tenants
        elif weights is None:
            aux = jnp.ones(x.shape[:2], jnp.float32)
        else:
            aux = jnp.asarray(weights, jnp.float32)
        operands = (state, self._stacked_op, x, aux)
        if self.decay is not None:
            if t is None:
                stamps = jnp.where(
                    jnp.isfinite(state.stamp), state.stamp, 0.0
                )
            else:
                stamps = jnp.broadcast_to(
                    jnp.asarray(t, jnp.float32), (self.n_tenants,)
                )
            operands += (stamps,)
        return self._mesh_update_fn(state), operands

    def mesh_update_hlo(self, state, batches, weights=None, *, t=None) -> str:
        """Compiled HLO of the shard-mapped :meth:`update` — the artifact
        tests/benchmarks grep to assert the hot path carries ZERO cross-shard
        collectives (no all-reduce/all-gather/collective-permute/all-to-all:
        tenant sharding is pure data parallelism)."""
        if self.sharding != "mesh":
            raise ValueError("mesh_update_hlo requires sharding='mesh'")
        fn, operands = self._mesh_update_args(state, batches, weights, t)
        return fn.lower(*operands).compile().as_text()

    def update(self, state, batches, weights=None, *, t=None):
        """Fold one aligned block ``batches: (T, B, n)`` — one batch per
        tenant — into the stacked state in a single vmapped dispatch.

        Row t is bitwise what ``tenant_engine(t).update`` would produce.
        Under ``decay``, ``t`` is the block's tick — a scalar (every tenant)
        or ``(T,)`` (per tenant); ``t=None`` reuses each row's current stamp
        (empty rows resolve to tick 0), matching ``SketchEngine.update``.
        With ``sharding="mesh"`` the same trace runs shard-mapped: one
        dispatch per device over its own block, nothing on the wire.
        """
        if t is not None and self.decay is None:
            raise ValueError(
                "update(t=...) requires a decay-enabled fleet "
                "(FleetEngine(..., decay=gamma))"
            )
        if self.sharding == "mesh":
            fn, operands = self._mesh_update_args(state, batches, weights, t)
            return fn(*operands)
        parts = self._parts(self._stacked_op, batches, weights)
        if self.decay is not None:
            if t is None:
                stamps = jnp.where(
                    jnp.isfinite(state.stamp), state.stamp, 0.0
                )
            else:
                stamps = jnp.broadcast_to(
                    jnp.asarray(t, jnp.float32), (self.n_tenants,)
                )
            parts = self._lift_parts(parts, stamps)
        return eng_mod._merge_states(state, parts)

    def merge(self, a, b):
        """Stacked associative+commutative combine (elementwise, so the
        single-engine merge applies to (T, …) leaves unchanged)."""
        return eng_mod._merge_states(a, b)

    def finalize(self, state):
        """-> ``(z (T, 2m), lower (T, n), upper (T, n))``, all tenants.
        With ``sharding="mesh"`` the vmapped finalize runs within each
        shard (shard-mapped, no collectives); outputs stay tenant-sharded.
        """
        self._check_capacity(state)
        if self.sharding == "mesh":
            return self._mesh_finalize_fn(state)(state)
        return self._finalize_vmapped(state)

    def _finalize_vmapped(self, state):
        """The vmapped whole-fleet finalize — the shard_map body reuses it
        verbatim, which is what keeps sharded finalize bitwise."""
        if self.quantized:
            fin = (
                eng_mod._finalize_decayed_quantized
                if isinstance(state, DecayedQuantizedSketchEngineState)
                else eng_mod._finalize_quantized
            )
            return jax.vmap(functools.partial(fin, bits=self.bits))(
                state, self.dither
            )
        return jax.vmap(eng_mod._finalize_state)(state)

    def _mesh_finalize_fn(self, state):
        if self._mesh_finalize_jit is not None:
            return self._mesh_finalize_jit
        from repro.utils import compat

        quantized = self.quantized

        def body(st, *dither):
            if quantized:
                fin = (
                    eng_mod._finalize_decayed_quantized
                    if isinstance(st, DecayedQuantizedSketchEngineState)
                    else eng_mod._finalize_quantized
                )
                z, lo, hi = jax.vmap(
                    functools.partial(fin, bits=self.bits)
                )(st, dither[0])
            else:
                z, lo, hi = jax.vmap(eng_mod._finalize_state)(st)
            return z, lo, hi

        row = jax.sharding.PartitionSpec(self.tenant_shard_axis)
        in_specs = (self._row_specs(state),) + (
            (row,) if quantized else ()
        )
        fn = compat.shard_map(
            body,
            self.mesh,
            in_specs=in_specs,
            out_specs=(row, row, row),
            check_vma=False,
        )
        jitted = jax.jit(fn)
        if quantized:
            dither = self.dither
            self._mesh_finalize_jit = lambda st: jitted(st, dither)
        else:
            self._mesh_finalize_jit = jitted
        return self._mesh_finalize_jit

    def _check_capacity(self, state):
        if not self.quantized:
            return
        cap = qz.accumulator_capacity(self.bits)
        if not isinstance(state.count, jax.core.Tracer) and float(
            jnp.max(state.count)
        ) > cap:
            raise ValueError(
                f"quantized fleet accumulators overflow: a tenant folded "
                f"{float(jnp.max(state.count)):.0f} points at {self.bits} "
                f"bits, over the int32 capacity of {cap}"
            )

    # -- request routing: segment-scatter -----------------------------------

    def ingest(self, state, tenant_ids, batches, weights=None, *, t=None):
        """Fold interleaved requests ``(tenant_ids (R,), batches (R, B, n))``
        into the stacked state.

        Partials are computed in ONE vmapped pass over per-request operators
        gathered by tenant id.  The fold into the state is a segment-scatter:
        unique ids within a call use one scatter-add/min/max per leaf; calls
        carrying duplicate ids (several requests for one tenant in a flush)
        take an ordered ``lax.scan`` fold so the tenant's float partials
        combine in arrival order — the same association its isolated engine
        uses, preserving bitwise tenant isolation.

        Under ``decay``, ``t`` is the requests' tick — a scalar or ``(R,)``
        per request — and the fold ALWAYS takes the ordered scan path: the
        decay factor each merge applies depends on the row's current stamp,
        which a scatter-add cannot express.  ``t=None`` stamps each request
        with its tenant row's current stamp (empty rows -> tick 0), resolved
        per-request inside the scan.
        """
        if t is not None and self.decay is None:
            raise ValueError(
                "ingest(t=...) requires a decay-enabled fleet "
                "(FleetEngine(..., decay=gamma))"
            )
        ids = jnp.asarray(tenant_ids, jnp.int32)
        if ids.ndim != 1 or ids.shape[0] != jnp.asarray(batches).shape[0]:
            raise ValueError(
                f"tenant_ids {ids.shape} must be (R,) matching batches "
                f"{jnp.asarray(batches).shape}"
            )
        gathered = jax.tree_util.tree_map(
            lambda l: l[ids], self._stacked_op
        )
        if self.quantized:
            x = jnp.asarray(batches, jnp.float32)
            if weights is not None:
                raise ValueError(
                    "quantized fleet states accumulate unit-weight integer "
                    "counts; per-point weights are not representable"
                )
            parts = jax.vmap(self._tenant_qpart)(
                gathered, self.dither[ids], x
            )
        else:
            parts = self._parts(gathered, batches, weights)

        if self.decay is not None:
            # nan = "stamp me with my row's clock" — resolved per request in
            # the scan fold.  (-inf cannot be the sentinel: a non-empty
            # partial stamped -inf would decay to nothing on merge.)
            if t is None:
                stamps = jnp.full((ids.shape[0],), jnp.nan, jnp.float32)
            else:
                stamps = jnp.broadcast_to(
                    jnp.asarray(t, jnp.float32), (ids.shape[0],)
                )
            parts = self._lift_parts(parts, stamps)
            return self._scan_parts(state, ids, parts)

        unique = not isinstance(ids, jax.core.Tracer) and (
            len(set(int(i) for i in ids)) == ids.shape[0]
        )
        if unique:
            return self._scatter_parts(state, ids, parts)
        return self._scan_parts(state, ids, parts)

    @staticmethod
    def _scatter_parts(state, ids, parts):
        """One scatter per leaf.  Sum leaves scatter-add; bounds scatter
        min/max — with unique ids each row sees exactly one partial, so this
        is the per-tenant merge with no association ambiguity."""
        add = lambda l, p: l.at[ids].add(p)  # noqa: E731
        if isinstance(state, QuantizedSketchEngineState):
            return QuantizedSketchEngineState(
                qcos_acc=add(state.qcos_acc, parts.qcos_acc),
                qsin_acc=add(state.qsin_acc, parts.qsin_acc),
                weight_sum=add(state.weight_sum, parts.weight_sum),
                lower=state.lower.at[ids].min(parts.lower),
                upper=state.upper.at[ids].max(parts.upper),
                count=add(state.count, parts.count),
            )
        return SketchEngineState(
            cos_acc=add(state.cos_acc, parts.cos_acc),
            sin_acc=add(state.sin_acc, parts.sin_acc),
            weight_sum=add(state.weight_sum, parts.weight_sum),
            lower=state.lower.at[ids].min(parts.lower),
            upper=state.upper.at[ids].max(parts.upper),
            count=add(state.count, parts.count),
        )

    @staticmethod
    def _scan_parts(state, ids, parts):
        """Arrival-order fold for duplicate ids: request r merges into its
        tenant's row before request r+1 — float association matches the
        isolated engine's sequential update exactly."""

        def fold(st, inp):
            tid, part = inp
            row = jax.tree_util.tree_map(lambda l: l[tid], st)
            if isinstance(part, eng_mod.DECAYED_STATE_TYPES):
                stamp = jnp.where(
                    jnp.isnan(part.stamp),
                    jnp.where(jnp.isfinite(row.stamp), row.stamp, 0.0),
                    part.stamp,
                )
                part = part._replace(stamp=stamp)
            merged = eng_mod._merge_states(row, part)
            st = jax.tree_util.tree_map(
                lambda l, r: l.at[tid].set(r), st, merged
            )
            return st, None

        state, _ = jax.lax.scan(fold, state, (ids, parts))
        return state

    def decay_to(self, state, t):
        """Advance every tenant's clock to tick ``t`` (scalar or ``(T,)``)
        without folding data — one vmapped merge with stamped identities,
        matching ``SketchEngine.decay_to`` row for row."""
        if self.decay is None:
            raise ValueError(
                "decay_to requires a decay-enabled fleet "
                "(FleetEngine(..., decay=gamma))"
            )
        empty = self.init_state()
        stamp = jnp.broadcast_to(
            jnp.asarray(t, jnp.float32), (self.n_tenants,)
        )
        return eng_mod._merge_states(state, empty._replace(stamp=stamp))

    # -- tenant state surgery (evict / restore build on these) --------------

    def tenant_state(self, state, tenant: int):
        """Tenant ``tenant``'s row as a plain single-engine state."""
        return jax.tree_util.tree_map(lambda l: l[tenant], state)

    def set_tenant(self, state, tenant: int, row):
        """Stacked state with tenant's row replaced by ``row``."""
        return jax.tree_util.tree_map(
            lambda l, r: l.at[tenant].set(jnp.asarray(r, l.dtype)), state, row
        )

    def reset_tenant(self, state, tenant: int):
        """Tenant's row back to the monoid identity (post-eviction hole)."""
        identity = self.tenant_engine(tenant).init_state()
        return self.set_tenant(state, tenant, identity)

    def merge_tenant(self, state, tenant: int, partial):
        """Fold an externally produced partial (edge sketcher, restored
        checkpoint) into one tenant's row: ``row <- merge(row, partial)``."""
        row = self.tenant_state(state, tenant)
        return self.set_tenant(
            state, tenant, eng_mod._merge_states(row, partial)
        )

    def finalize_tenant(self, state, tenant: int):
        """Finalize ONE tenant — O(m), the decode-on-demand hot path (the
        full-fleet :meth:`finalize` is O(T·m))."""
        row = self.tenant_state(state, tenant)
        if self.quantized:
            self._check_capacity(state)
            fin = (
                eng_mod._finalize_decayed_quantized
                if isinstance(row, DecayedQuantizedSketchEngineState)
                else eng_mod._finalize_quantized
            )
            return fin(row, self.dither[tenant], self.bits)
        return eng_mod._finalize_state(row)

    def state_bytes(self) -> int:
        """Resident bytes of the stacked fleet state (all T tenants)."""
        state = self.init_state()
        return int(
            sum(
                l.size * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(state)
            )
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        q = f", bits={self.bits}" if self.quantized else ""
        s = (
            f", shards={self.tenant_shards}x{self.shard_rows}rows"
            f"(axis={self.tenant_shard_axis!r})"
            if self.sharding == "mesh"
            else ""
        )
        return (
            f"FleetEngine(T={self.n_tenants}, n={self.n}, m={self.m}, "
            f"backend={self.backend!r}{q}{s})"
        )
