"""Public API of the compressive K-means core.

The paper's pipeline is sketch -> decode; both halves are pluggable
subsystems (``engine.SketchEngine`` backends/state transforms plus the
``freq_ops`` frequency-operator registry, the ``ingest`` pipeline and the
``topology`` merge-schedule registry on the sketch side, the ``decoders``
registry on the decode side) behind one config:

    from repro.core import CKMConfig, fit, sse, predict

    res = fit(key, x, CKMConfig(k=10, decoder="sketch_shift"))

Submodules (``repro.core.ckm``, ``.engine``, ``.quantize``, ...) remain
importable for internals; examples and docs should use these exports.
"""

from repro.core.ckm import (
    CKMConfig,
    CKMResult,
    compute_sketch,
    compute_sketch_streaming,
    decode_sketch,
    diagnose,
    fit,
    fit_streaming,
    predict,
    sse,
)
from repro.core.decoders import (
    DECODERS,
    Decoder,
    available_decoders,
    get_decoder,
    register_decoder,
)
from repro.core.engine import (
    BACKENDS,
    DecayedQuantizedSketchEngineState,
    DecayedSketchEngineState,
    SketchEngine,
)
from repro.core.fleet import (
    FLEET_BACKENDS,
    FleetEngine,
    fleet_quantizers,
    fleet_specs,
)
from repro.core.freq_ops import (
    FREQ_OPS,
    FreqOpSpec,
    FrequencyOperator,
    as_operator,
    available_freq_ops,
    make_operator,
    register_freq_op,
)
from repro.core.ingest import BatchSource, IngestStats, ingest_stream, prefetched
from repro.core.window import SketchWindow, WindowState
from repro.core.topology import (
    TOPOLOGIES,
    StragglerMerger,
    Topology,
    available_topologies,
    axis_reduce,
    reduce_states,
    register_topology,
    wire_cost_model,
)

__all__ = [
    "CKMConfig",
    "CKMResult",
    "compute_sketch",
    "compute_sketch_streaming",
    "decode_sketch",
    "diagnose",
    "fit",
    "fit_streaming",
    "predict",
    "sse",
    "DECODERS",
    "Decoder",
    "available_decoders",
    "get_decoder",
    "register_decoder",
    "BACKENDS",
    "DecayedQuantizedSketchEngineState",
    "DecayedSketchEngineState",
    "SketchEngine",
    "SketchWindow",
    "WindowState",
    "FLEET_BACKENDS",
    "FleetEngine",
    "fleet_quantizers",
    "fleet_specs",
    "FREQ_OPS",
    "FreqOpSpec",
    "FrequencyOperator",
    "as_operator",
    "available_freq_ops",
    "make_operator",
    "register_freq_op",
    "BatchSource",
    "IngestStats",
    "ingest_stream",
    "prefetched",
    "TOPOLOGIES",
    "Topology",
    "StragglerMerger",
    "available_topologies",
    "axis_reduce",
    "reduce_states",
    "register_topology",
    "wire_cost_model",
]
