"""Pluggable frequency operators (``core.freq_ops``) — see ``base.py``.

Registry + the two built-ins:

- ``"dense"`` — the paper's materialised Ω (bitwise-identical to the
  pre-refactor dense path through the registry);
- ``"structured"`` — stacked HD-Rademacher fast-transform blocks with
  adapted-radius radial rescaling (O(m·sqrt(d)) projections, O(m) state).

Selected end-to-end by ``CKMConfig.freq_op``; docs in
``docs/architecture.md#frequency-operators`` and ``docs/api.md``.
"""

from repro.core.freq_ops.base import (
    FREQ_OPS,
    FreqOpSpec,
    FrequencyOperator,
    as_operator,
    available_freq_ops,
    from_spec,
    get_freq_op,
    make_operator,
    register_freq_op,
    spec_wire_bytes,
)
from repro.core.freq_ops.dense import DenseOperator
from repro.core.freq_ops.structured import StructuredOperator

__all__ = [
    "FREQ_OPS",
    "FreqOpSpec",
    "FrequencyOperator",
    "DenseOperator",
    "StructuredOperator",
    "as_operator",
    "available_freq_ops",
    "from_spec",
    "get_freq_op",
    "make_operator",
    "register_freq_op",
    "spec_wire_bytes",
]
