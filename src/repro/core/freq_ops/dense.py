"""The ``"dense"`` frequency operator — the paper's materialised Ω, wrapped.

``apply`` is exactly the pre-refactor ``x @ w`` (same draw, same dtype, same
XLA graph), so selecting ``freq_op="dense"`` through the registry is bitwise
identical to the historical dense-matrix path on every backend — asserted by
``tests/test_freq_ops.py``.  What changes is the bookkeeping: the operator
knows its ``spec()`` (PRNG key + hyperparams), so checkpoints and cross-host
broadcast can carry O(1) bytes and redraw the matrix instead of shipping it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import frequencies as freq_mod
from repro.core.freq_ops.base import (
    FreqOpSpec,
    FrequencyOperator,
    register_freq_op,
    try_spec,
)


class DenseOperator(FrequencyOperator):
    """Ω held as a materialised ``(n, m)`` matrix (column frequencies)."""

    name = "dense"

    def __init__(self, w: jax.Array, spec: FreqOpSpec | None = None):
        self.w = w
        self._spec = spec

    @property
    def n(self) -> int:
        return self.w.shape[0]

    @property
    def m(self) -> int:
        return self.w.shape[1]

    def apply(self, x: jax.Array) -> jax.Array:
        return x @ self.w

    def adjoint(self, v: jax.Array) -> jax.Array:
        return v @ self.w.T

    def materialize(self) -> jax.Array:
        return self.w

    def col_norms(self) -> jax.Array:
        return jnp.linalg.norm(self.w, axis=0)

    def col_sq_norms(self) -> jax.Array:
        return jnp.sum(self.w * self.w, axis=0)

    def spec(self) -> FreqOpSpec:
        if self._spec is None:
            raise ValueError(
                "this DenseOperator wraps a raw matrix (deprecation shim) and "
                "has no spec; build it with freq_ops.make_operator('dense', "
                "key, m, n, sigma2) to carry one"
            )
        return self._spec


def _flatten(op: DenseOperator):
    return (op.w,), (op._spec,)


def _unflatten(aux, children):
    return DenseOperator(children[0], aux[0])


jax.tree_util.register_pytree_node(DenseOperator, _flatten, _unflatten)


@register_freq_op("dense")
def build_dense(
    key: jax.Array,
    m: int,
    n: int,
    sigma2,
    *,
    dist: str = "adapted_radius",
    dtype=jnp.float32,
) -> DenseOperator:
    """Draw the paper's dense Ω (``frequencies.draw_frequencies``) + its spec."""
    w = freq_mod.draw_frequencies(key, m, n, sigma2, dist, dtype=jnp.dtype(dtype))
    return DenseOperator(w, try_spec("dense", key, m, n, sigma2, dist, dtype))
