"""The ``"structured"`` frequency operator — stacked HD-Rademacher blocks.

Instead of drawing ``m`` dense directions, each block of ``d = 2^ceil(log2 n)``
frequencies uses the SRHT/SORF-style fast transform

    B = c·H D_2 · c·H D_1 · c·H D_0        (c = d^{-1/2}, D_i Rademacher ±1)

— a product of orthogonal factors, so B is *exactly* orthogonal and its rows
are unit-norm quasi-uniform directions; ``ceil(m/d)`` independent blocks are
stacked for ``m > d``.  The radial part is the paper's **adapted-radius**
distribution (``frequencies.draw_radii``), with the rescaling that makes the
radial law exact despite the zero-padding ``n -> d``: a unit row of B
restricted to the first ``n`` coordinates has norm ``< 1``, so each drawn
radius ``rho_j`` is divided by that restricted norm — the realised ``||ω_j||``
then equals ``rho_j`` *exactly* (and ``col_norms()`` is just the stored rho).

Costs per point: ``apply`` is 3 Walsh–Hadamard transforms per block —
``O(m·sqrt(d))`` flops with the Kronecker-factored WHT
(``kernels.freq_transform.fwht``) vs the dense ``O(n·m)`` matvec; the operator
state is ``O(m)`` floats (signs + radii) vs the dense ``O(n·m)`` matrix, and
its ``spec()`` is O(1).  The fused Pallas path is
``kernels.freq_transform.structured_sketch_kernel`` (dispatched by
``kernels/ops.py``); autodiff through ``apply``/``adjoint`` is plain jnp, so
decoders optimise through the fast transform unchanged.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import frequencies as freq_mod
from repro.core.freq_ops.base import (
    FreqOpSpec,
    FrequencyOperator,
    register_freq_op,
    try_spec,
)
from repro.kernels import freq_transform as ft


# Minimum WHT block width.  At small n the HD orbit contains few distinct
# directions (at d = 4 ~a dozen); embedding n into a wider block and
# restricting the rows back to the first n coordinates (with the radial
# rescaling below keeping the radius law exact) recovers the angular
# diversity of dense draws at negligible cost.
_MIN_BLOCK = 32


def block_dim(n: int) -> int:
    """The WHT block width: next power of two >= n, floored at ``_MIN_BLOCK``."""
    return max(1 << max(0, int(n) - 1).bit_length(), _MIN_BLOCK)


def _pad_last(x: jax.Array, size: int) -> jax.Array:
    pad = size - x.shape[-1]
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1
    )


class StructuredOperator(FrequencyOperator):
    """Stacked fast-transform blocks with adapted-radius radial rescaling.

    Leaves: ``diags (nblocks, 3, d)`` Rademacher signs, ``radii (nblocks, d)``
    rescaled step sizes, ``rho (nblocks, d)`` the drawn target magnitudes
    (``col_norms``).  ``n``/``m`` are static (the block tail past ``m`` is
    sliced off).
    """

    name = "structured"

    def __init__(self, diags, radii, rho, n: int, m: int, spec=None):
        self.diags = diags
        self.radii = radii
        self.rho = rho
        self._n = n
        self._m = m
        self._spec = spec

    @property
    def n(self) -> int:
        return self._n

    @property
    def m(self) -> int:
        return self._m

    @property
    def d(self) -> int:
        return self.diags.shape[-1]

    @property
    def nblocks(self) -> int:
        return self.diags.shape[0]

    def apply(self, x: jax.Array) -> jax.Array:
        x = jnp.asarray(x, self.diags.dtype)
        xp = _pad_last(x, self.d)  # zero feature pad shifts no phases
        v = ft.hd_chain(xp[..., None, :], self.diags)  # (..., nblocks, d)
        y = v * self.radii
        return y.reshape(x.shape[:-1] + (self.nblocks * self.d,))[..., : self.m]

    def adjoint(self, v: jax.Array) -> jax.Array:
        v = jnp.asarray(v, self.diags.dtype)
        vp = _pad_last(v, self.nblocks * self.d)
        u = vp.reshape(v.shape[:-1] + (self.nblocks, self.d)) * self.radii
        # Transpose of the hd_chain: same symmetric H stages, diags reversed.
        d = self.d
        c = jnp.asarray(d, u.dtype) ** -0.5
        for s in (2, 1, 0):
            u = ft.fwht(u) * c * self.diags[..., s, :]
        return jnp.sum(u, axis=-2)[..., : self.n]

    def materialize(self) -> jax.Array:
        return self.apply(jnp.eye(self.n, dtype=self.diags.dtype))

    def col_norms(self) -> jax.Array:
        return self.rho.reshape(-1)[: self.m]

    def spec(self) -> FreqOpSpec:
        if self._spec is None:
            raise ValueError(
                "this structured operator has no spec (built under "
                "jit/vmap tracing, where no concrete key exists)"
            )
        return self._spec


def _flatten(op: StructuredOperator):
    return (op.diags, op.radii, op.rho), (op._n, op._m, op._spec)


def _unflatten(aux, children):
    return StructuredOperator(*children, n=aux[0], m=aux[1], spec=aux[2])


jax.tree_util.register_pytree_node(StructuredOperator, _flatten, _unflatten)


@register_freq_op("structured")
def build_structured(
    key: jax.Array,
    m: int,
    n: int,
    sigma2,
    *,
    dist: str = "adapted_radius",
    dtype=jnp.float32,
) -> StructuredOperator:
    """Draw signs + adapted radii and compute the restricted-norm rescaling."""
    dtype = jnp.dtype(dtype)
    d = block_dim(n)
    nblocks = -(-int(m) // d)
    k_diag, k_rad = jax.random.split(key)
    diags = jax.random.rademacher(k_diag, (nblocks, 3, d), dtype)
    rho = freq_mod.draw_radii(
        k_rad, nblocks * d, n, sigma2, dist, dtype=dtype
    ).reshape(nblocks, d)
    # Restricted row norms of B: ||row_j restricted to the first n coords||.
    # One batched chain over the n basis vectors — O(n·m·sqrt(d)), once.
    basis = jnp.eye(d, dtype=dtype)[:n]  # (n, d): e_i zero-padded
    cols = ft.hd_chain(basis[:, None, :], diags)  # (n, nblocks, d)
    restricted = jnp.sqrt(jnp.sum(cols * cols, axis=0))  # (nblocks, d)
    radii = rho / jnp.maximum(restricted, 1e-6)
    spec = try_spec("structured", key, m, n, sigma2, dist, dtype)
    return StructuredOperator(diags, radii, rho, int(n), int(m), spec)
