"""The frequency-operator contract + registry — the sketch's third pluggable axis.

The sketch operator of the paper is "draw Ω ~ Lambda, compute exp(-i Ωᵀx)".
Historically Ω was a materialised dense ``(n, m)`` array threaded *by value*
through the whole stack — every kernel op, every decoder cost, every
cross-device broadcast and checkpoint carried O(n·m) bytes, and the sketch
family was not a degree of freedom.  This package makes Ω an object:

    op.apply(x)      # (..., n) -> (..., m)   Ωᵀx — the projection
    op.adjoint(v)    # (..., m) -> (..., n)   Ωv  — decoder gradients
    op.materialize() # (n, m)                 the dense matrix, on demand
    op.col_norms()   # (m,)                   ||ω_j|| (resolution radii)
    op.spec()        # FreqOpSpec             PRNG key + hyperparams, O(1)

mirroring the decoder registry (``core.decoders``) and the topology registry
(``core.topology``): operators register under a name, ``CKMConfig.freq_op``
selects one end-to-end, and new families (subsampled DFTs, learned
operators, …) are one ``@register_freq_op`` away.

Why ``spec()`` matters: the spec — a NamedTuple of plain Python scalars
(name, PRNG key words, ``m``, ``n``, ``sigma2``, ``dist``, ``dtype``) — fully
determines the operator, so engine state, checkpoints and cross-host
broadcast can carry ~O(1) bytes (``spec_wire_bytes``) and rebuild the
operator with :func:`from_spec` instead of shipping the O(n·m) matrix.

Raw arrays: :func:`as_operator` wraps a raw ``(n, m)`` array in a ``"dense"``
operator (such a wrapper has no spec; ``spec()`` raises).  The sketch/engine
entry points still wrap silently for convenience, but the decoder helpers and
kernel wrappers closed their one-release deprecation window in PR 6 and now
raise ``TypeError`` on raw arrays — wrap explicitly at the boundary.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FreqOpSpec",
    "FrequencyOperator",
    "FREQ_OPS",
    "register_freq_op",
    "get_freq_op",
    "available_freq_ops",
    "make_operator",
    "from_spec",
    "as_operator",
    "spec_wire_bytes",
]


class FreqOpSpec(NamedTuple):
    """Plain-scalar description from which an operator rebuilds exactly.

    ``key_data`` is the PRNG key's raw uint32 words (hashable, serialisable);
    everything else is a Python scalar/string, so a spec fits in a checkpoint
    manifest or a control-plane message at ~O(1) bytes (:func:`spec_wire_bytes`).
    """

    name: str
    key_data: tuple[int, ...]
    m: int
    n: int
    sigma2: float
    dist: str = "adapted_radius"
    dtype: str = "float32"


def spec_wire_bytes(spec: FreqOpSpec) -> int:
    """Serialized size of a spec: strings + 4B/key word + 3 int64 + 1 f64.

    The number the scaling guide compares against the ``4·n·m`` bytes of the
    dense matrix this spec replaces on the wire / in checkpoints.
    """
    return (
        len(spec.name.encode())
        + len(spec.dist.encode())
        + len(spec.dtype.encode())
        + 4 * len(spec.key_data)
        + 3 * 8  # m, n + a length/tag word
        + 8  # sigma2
    )


def key_data_tuple(key: jax.Array) -> tuple[int, ...]:
    """PRNG key (legacy uint32 or new typed) -> hashable uint32 words."""
    try:
        if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
            key = jax.random.key_data(key)
    except (AttributeError, TypeError):  # pragma: no cover - old jax
        pass
    return tuple(int(v) for v in np.asarray(key).reshape(-1).tolist())


def try_spec(
    name: str, key, m: int, n: int, sigma2, dist: str, dtype
) -> FreqOpSpec | None:
    """The spec for a build, or ``None`` when built under tracing.

    Builders run eagerly in the pipeline (concrete key/sigma2 -> full spec),
    but ``ckm.fit`` is also legal inside ``jit``/``vmap`` (e.g. the
    per-head KV-cache compression), where the key and scale are tracers and
    no concrete spec exists — the operator still works; only ``spec()``
    raises.
    """
    if isinstance(key, jax.core.Tracer) or isinstance(sigma2, jax.core.Tracer):
        return None
    return FreqOpSpec(
        name=name,
        key_data=key_data_tuple(key),
        m=int(m),
        n=int(n),
        sigma2=float(sigma2),
        dist=dist,
        dtype=jnp.dtype(dtype).name,
    )


def key_from_data(key_data: tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`key_data_tuple` (as a legacy uint32 key array)."""
    return jnp.asarray(key_data, jnp.uint32)


class FrequencyOperator:
    """Abstract linear frequency operator Ω: apply/adjoint/materialize/spec.

    Subclasses must be registered JAX pytrees (their array leaves flow through
    ``jit`` / ``scan`` / ``shard_map`` transparently; static hyperparameters
    and the spec live in hashable aux data) and define ``name``, ``n``, ``m``.
    """

    name: str = "?"

    # -- shape -------------------------------------------------------------
    @property
    def n(self) -> int:
        raise NotImplementedError

    @property
    def m(self) -> int:
        raise NotImplementedError

    # -- linear algebra ----------------------------------------------------
    def apply(self, x: jax.Array) -> jax.Array:
        """``(..., n) -> (..., m)``: the projection ``Ωᵀx`` (sketch phases)."""
        raise NotImplementedError

    def adjoint(self, v: jax.Array) -> jax.Array:
        """``(..., m) -> (..., n)``: ``Ωv`` — decoder cost/score gradients."""
        raise NotImplementedError

    def materialize(self) -> jax.Array:
        """The dense ``(n, m)`` matrix (on demand — never carried by state)."""
        raise NotImplementedError

    def col_norms(self) -> jax.Array:
        """``(m,)`` frequency magnitudes ``||ω_j||`` (resolution radii)."""
        raise NotImplementedError

    def col_sq_norms(self) -> jax.Array:
        """``(m,)`` squared magnitudes (mean-shift bandwidth h²)."""
        return self.col_norms() ** 2

    # -- bookkeeping -------------------------------------------------------
    def spec(self) -> FreqOpSpec:
        """The O(1) rebuild recipe; raises for shim-wrapped raw matrices."""
        raise NotImplementedError

    def state_bytes(self) -> int:
        """Bytes of the operator's array leaves (what a by-value carry ships)."""
        return int(
            sum(
                np.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize
                for leaf in jax.tree_util.tree_leaves(self)
            )
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{type(self).__name__}(n={self.n}, m={self.m})"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

# name -> builder(key, m, n, sigma2, *, dist, dtype) -> FrequencyOperator
FREQ_OPS: dict[str, Callable] = {}


def register_freq_op(name: str) -> Callable:
    """Decorator: register an operator *builder* under ``name`` (unique)."""

    def deco(builder: Callable) -> Callable:
        if name in FREQ_OPS:
            raise ValueError(f"frequency operator {name!r} already registered")
        FREQ_OPS[name] = builder
        return builder

    return deco


def get_freq_op(name: str) -> Callable:
    """Look up a registered builder; raises with the available names."""
    try:
        return FREQ_OPS[name]
    except KeyError:
        raise KeyError(
            f"unknown frequency operator {name!r}; available: "
            f"{sorted(FREQ_OPS)}"
        ) from None


def available_freq_ops() -> list[str]:
    """Sorted names of all registered frequency operators."""
    return sorted(FREQ_OPS)


def make_operator(
    name: str,
    key: jax.Array,
    m: int,
    n: int,
    sigma2,
    *,
    dist: str = "adapted_radius",
    dtype=jnp.float32,
) -> FrequencyOperator:
    """Build a registered operator for ``m`` frequencies in R^n at scale
    ``sigma2`` (builders run outside ``jit`` — construction draws PRNG bits
    and records a concrete spec)."""
    return get_freq_op(name)(key, m, n, sigma2, dist=dist, dtype=dtype)


def from_spec(spec: FreqOpSpec) -> FrequencyOperator:
    """Rebuild an operator exactly from its spec (same key -> same leaves)."""
    return make_operator(
        spec.name,
        key_from_data(spec.key_data),
        spec.m,
        spec.n,
        spec.sigma2,
        dist=spec.dist,
        dtype=jnp.dtype(spec.dtype),
    )


def as_operator(w) -> FrequencyOperator:
    """Pass operators through; wrap raw ``(n, m)`` arrays in a dense operator.

    A wrapped raw matrix behaves exactly like the dense operator it is
    (``apply`` is the same ``x @ w``) but carries no spec.  This is the
    *explicit* wrapping entry point — the decoder helpers and kernel wrappers
    no longer accept raw matrices themselves (their one-release deprecation
    window closed in PR 6; they raise ``TypeError``), so call this at the
    boundary when you hold a plain array.
    """
    if isinstance(w, FrequencyOperator):
        return w
    from repro.core.freq_ops.dense import DenseOperator

    return DenseOperator(jnp.asarray(w))
