"""Frequency distributions for the sketching operator (paper §3.1).

Frequencies are drawn i.i.d. from a distribution ``Lambda``.  The paper uses the
*Adapted radius* distribution of Keriven et al. (arXiv:1606.02838): a frequency is
``omega = R * phi`` with ``phi`` uniform on the unit sphere and the radius ``R``
drawn from

    p_AR(R)  ∝  sqrt(R^2 sigma^2 + R^4 sigma^4 / 4) * exp(-R^2 sigma^2 / 2)

parametrised by a single scale ``sigma^2``.  A plain Gaussian distribution
``omega ~ N(0, I/sigma^2)`` and a folded-Gaussian radius are provided for
comparison (they appear as baselines in [5]).

``sigma^2`` is chosen by the small-sketch regression heuristic of [5]: sketch a
small fraction of the data at a few candidate scales and fit the decay of the
modulus of the empirical characteristic function.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

FreqDist = Literal["adapted_radius", "gaussian", "folded_gaussian"]

# Number of grid points for inverse-CDF sampling of the radius density.
_GRID = 4096
# The adapted-radius density has negligible mass beyond R*sigma ~ 6.
_RMAX_SIGMA = 6.0


def _adapted_radius_pdf(r: jax.Array, sigma2: jax.Array) -> jax.Array:
    """Unnormalised adapted-radius pdf evaluated at radii ``r`` (sigma = 1 units)."""
    r2 = r * r * sigma2
    return jnp.sqrt(r2 + r2 * r2 / 4.0) * jnp.exp(-r2 / 2.0)


def radius_from_uniform(u: jax.Array, sigma2: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Map uniforms ``u in [0, 1)`` through the adapted-radius inverse CDF.

    The deterministic half of the sampler (grid CDF + linear interpolation),
    split out so the f32/f64 numerics of the grid accumulation can be compared
    on identical uniforms (``dtype`` controls the grid/CDF precision).
    """
    u = jnp.asarray(u, dtype)
    sigma2 = jnp.asarray(sigma2, dtype)
    sigma = jnp.sqrt(sigma2)
    grid = jnp.linspace(
        jnp.asarray(0.0, dtype), _RMAX_SIGMA / jnp.maximum(sigma, 1e-20), _GRID
    )
    pdf = _adapted_radius_pdf(grid, sigma2)
    cdf = jnp.cumsum(pdf)
    cdf = cdf / cdf[-1]
    idx = jnp.searchsorted(cdf, u)
    idx = jnp.clip(idx, 1, _GRID - 1)
    # Linear interpolation between grid points for a smooth sample.
    c0, c1 = cdf[idx - 1], cdf[idx]
    w = (u - c0) / jnp.maximum(c1 - c0, 1e-20)
    return grid[idx - 1] + w * (grid[idx] - grid[idx - 1])


def _inverse_cdf_sample(
    key: jax.Array, m: int, sigma2: jax.Array, dtype=jnp.float32
) -> jax.Array:
    """Draw ``m`` radii from the adapted-radius density by inverse-CDF on a grid."""
    return radius_from_uniform(jax.random.uniform(key, (m,)), sigma2, dtype)


def _uniform_sphere(key: jax.Array, m: int, n: int, dtype=jnp.float32) -> jax.Array:
    v = jax.random.normal(key, (m, n), dtype)
    return v / jnp.linalg.norm(v, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("m", "n", "dist", "dtype"))
def draw_radii(
    key: jax.Array,
    m: int,
    n: int,
    sigma2: jax.Array | float,
    dist: FreqDist = "adapted_radius",
    dtype=jnp.float32,
) -> jax.Array:
    """Draw ``m`` frequency *radii* ``||omega||`` from ``Lambda``'s radial law.

    Used by structured frequency operators (``core.freq_ops``), which pick
    directions by fast orthogonal transforms and only need the radial part of
    the distribution: adapted-radius (inverse CDF), the chi law of an
    isotropic Gaussian, or the folded Gaussian.
    """
    sigma2 = jnp.asarray(sigma2, dtype)
    if dist == "adapted_radius":
        return _inverse_cdf_sample(key, m, sigma2, dtype)
    if dist == "gaussian":
        # ||N(0, I_n / sigma2)||: chi_n scaled by 1/sigma.
        v = jax.random.normal(key, (m, n), dtype)
        return jnp.linalg.norm(v, axis=1) / jnp.sqrt(sigma2)
    if dist == "folded_gaussian":
        return jnp.abs(jax.random.normal(key, (m,), dtype)) / jnp.sqrt(sigma2)
    raise ValueError(f"unknown frequency distribution {dist!r}")


@functools.partial(jax.jit, static_argnames=("m", "n", "dist", "dtype"))
def draw_frequencies(
    key: jax.Array,
    m: int,
    n: int,
    sigma2: jax.Array | float,
    dist: FreqDist = "adapted_radius",
    dtype=jnp.float32,
) -> jax.Array:
    """Draw ``m`` frequency vectors in R^n from ``Lambda``.

    Returns ``W`` with shape ``(n, m)`` (column frequencies), so that the sketch
    inner products are ``X @ W`` for row-major data ``X: (N, n)``.  ``dtype``
    selects the sampling/output precision (default f32; propagated from
    ``CKMConfig.freq_dtype`` by the pipeline — f64 needs ``jax.enable_x64``).
    """
    kr, kd = jax.random.split(key)
    sigma2 = jnp.asarray(sigma2, dtype)
    if dist == "adapted_radius":
        radius = _inverse_cdf_sample(kr, m, sigma2, dtype)
        phi = _uniform_sphere(kd, m, n, dtype)
        w = phi * radius[:, None]
    elif dist == "gaussian":
        w = jax.random.normal(kr, (m, n), dtype) / jnp.sqrt(sigma2)
    elif dist == "folded_gaussian":
        radius = jnp.abs(jax.random.normal(kr, (m,), dtype)) / jnp.sqrt(sigma2)
        phi = _uniform_sphere(kd, m, n, dtype)
        w = phi * radius[:, None]
    else:  # pragma: no cover - static arg
        raise ValueError(f"unknown frequency distribution {dist!r}")
    return w.T.astype(dtype)  # (n, m)


# ---------------------------------------------------------------------------
# Scale (sigma^2) estimation — small-sketch regression of [5], §5.2.
# ---------------------------------------------------------------------------


def estimate_sigma2(
    key: jax.Array,
    x_sample: jax.Array,
    m0: int = 500,
    n_iters: int = 3,
    sigma2_init: float | None = None,
    n_candidates: int = 64,
) -> jax.Array:
    """Estimate the frequency-scale ``sigma^2`` from a small data fraction.

    Implements the iterative small-sketch regression heuristic of [5]: at the
    current scale, draw ``m0`` frequencies, sketch the (small) sample, and fit
    the modulus of the empirical characteristic function with the Gaussian decay
    ``|z(omega)| ≈ exp(-sigma^2 ||omega||^2 / 2)`` over a log-grid of candidate
    scales.  A couple of iterations re-centre the frequency range on the fit.

    ``x_sample`` is a *small* subset (or online head) of the dataset; a few
    thousand points suffice.
    """
    x_sample = jnp.asarray(x_sample, jnp.float32)
    n = x_sample.shape[1]
    if sigma2_init is None:
        # Coarse one-pass initial guess: mean squared distance to the sample mean
        # (an upper bound on within-cluster scale).  Stays one-pass / mergeable.
        mu = jnp.mean(x_sample, axis=0)
        sigma2 = jnp.maximum(jnp.mean(jnp.sum((x_sample - mu) ** 2, axis=1)) / n, 1e-12)
    else:
        sigma2 = jnp.asarray(sigma2_init, jnp.float32)

    for it in range(n_iters):
        key, kf = jax.random.split(key)
        w = draw_frequencies(kf, m0, n, sigma2, dist="adapted_radius")  # (n, m0)
        # Small sketch of the sample (modulus of empirical characteristic fn).
        proj = x_sample @ w  # (S, m0)
        zr = jnp.mean(jnp.cos(proj), axis=0)
        zi = jnp.mean(jnp.sin(proj), axis=0)
        mod = jnp.sqrt(zr**2 + zi**2)  # (m0,)
        r2 = jnp.sum(w * w, axis=0)  # ||omega||^2
        # Fit |z| ≈ exp(-s * r2 / 2) over candidate s on a log grid around the
        # current scale; least squares in log-modulus with a floor to avoid the
        # noise region |z| ~ 1/sqrt(S).
        cands = sigma2 * jnp.logspace(-2.0, 2.0, n_candidates)
        logmod = jnp.log(jnp.maximum(mod, 1e-3))
        weights = (mod > 0.05).astype(jnp.float32)  # trust only the low-noise region

        def loss(s):
            pred = -s * r2 / 2.0
            return jnp.sum(weights * (logmod - pred) ** 2) / jnp.maximum(
                jnp.sum(weights), 1.0
            )

        losses = jax.vmap(loss)(cands)
        sigma2 = cands[jnp.argmin(losses)]
    return jnp.asarray(sigma2, jnp.float32)
