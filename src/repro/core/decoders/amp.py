"""CL-AMP: approximate message passing on the sketched characteristic
function (after Byrne, Chatalic, Gribonval & Schniter, "Sketched clustering
via hybrid approximate message passing", 2017) — the ``"amp"`` registry entry.

The sketch samples the empirical characteristic function, so under a K-mixture
model with point clusters the measurements follow

    y_j  =  sum_k alpha_k e^{i z_jk} + noise,      z_jk = w_j^T c_k,

a *generalized bilinear* model in the centroid matrix ``C``: linear in C
through the frequency operator (``Z = C W``), nonlinear per measurement
through the phase mixture.  Where CLOMPR greedily appends one atom per round
and sketch-and-shift ascends the density mode by mode, CL-AMP estimates **all
K centroids jointly** by a simplified (scalar-variance) hybrid GAMP loop:

- *output channel* (per frequency j, component k): combine the Gaussian
  pseudo-prior ``z_jk ~ N(p_jk, q_p)`` — a von Mises prior of concentration
  ``1/q_p`` on the phase — with the von Mises likelihood induced by the
  leave-one-out residual ``y_j - sum_{k' != k} alpha_k' E[e^{i z_jk'}]``;
  the two concentrations add as complex vectors, giving the posterior phase
  mean (unwrapped to the sheet of ``p_jk``) and variance;
- *input channel* (per coordinate l, component k): the pseudo-data
  ``r_kl ~ N(c_kl, q_r)`` meets the uniform box prior ``[lower, upper]``
  harvested by the engine — a truncated-Gaussian posterior, fused as the
  kernel op ``ops.amp_denoise`` (xla | Pallas, ``AMPConfig.impl``);
- the two channels talk through the operator's ``apply``/``adjoint`` and its
  Frobenius mass ``sum col_sq_norms`` only — no materialized matrix, so the
  structured fast-transform family keeps its O(m sqrt(d)) projections — with
  the standard GAMP Onsager correction and scalar variances, damped for
  stability at small m (the regime this decoder exists for: it reaches
  CLOMPR's accuracy around m = 2-4 K n where CLOMPR needs ~10 K n).

Mixture weights are refreshed by the shared box-constrained solver
(``core.nnls``) on the atom matrix of the current estimates, and the loop is
followed by the same NNLS + joint Adam polish on ``||z - A(C) alpha||^2``
every registry decoder reports — replicate selection and decoder comparison
share one objective.  All shapes are fixed; the decoder is one ``jit``
end-to-end and ``lax.map``-able over replicate keys.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import freq_ops as fo
from repro.core import nnls as nnls_mod
from repro.core import sketch as sk
from repro.core.decoders import common
from repro.core.decoders.registry import register_decoder
from repro.kernels import ops

_TWO_PI = 6.283185307179586


@dataclasses.dataclass(frozen=True)
class AMPConfig:
    """Static hyper-parameters of the decoder (hashable -> jit static arg)."""

    k: int
    iters: int = 300  # GAMP iterations
    damp: float = 0.3  # damping on the S / C updates (1 = undamped)
    inner_nnls_iters: int = 40  # weight refresh inside the loop
    nnls_iters: int = 150  # final weights
    polish_steps: int = 600  # joint Adam on (C, alpha) after the loop
    polish_lr: float = 0.02
    init: str = "range"  # "range" -> uniform in box; "sample"/"kpp" from x_init
    # Components whose weight collapses stop receiving likelihood information
    # (kappa_y ~ alpha_k) and can never recover; the output channel sees
    # weights floored at alpha_floor/K so every component keeps listening.
    alpha_floor: float = 0.05
    noise_floor: float = 1e-8  # floor on the output-channel noise variance
    impl: str = "xla"  # amp_denoise kernel impl: "xla" | "pallas" (ops.py)
    # Convergence tracing: when True the decoder also returns
    # ``{"unexplained_energy": (iters,), "posterior_variance": (iters,)}`` —
    # the output-channel noise level v and the damped input-channel variance
    # q_x per GAMP iteration (the damping trajectory).  Buffers are carried
    # unconditionally (XLA drops them when unused), so the default path is
    # bitwise the untraced decoder.
    trace: bool = False


def _wrap(x):
    """Wrap to (-pi, pi]: the phase posterior lives on the circle and must be
    unwrapped onto the pseudo-prior's sheet before the Gaussian message."""
    return x - _TWO_PI * jnp.round(x / _TWO_PI)


@functools.partial(jax.jit, static_argnames=("cfg",))
def cl_amp(
    key: jax.Array,
    z: jax.Array,
    w,
    lower: jax.Array,
    upper: jax.Array,
    cfg: AMPConfig,
    x_init: jax.Array | None = None,
):
    """Decode K centroids jointly from the sketch ``z`` by simplified hybrid
    GAMP on the sketched characteristic function.

    Returns ``(centroids (K, n), weights (K,), cost)`` with ``cost`` the
    shared sketch-domain objective ``||z - A(C) alpha||^2``.  ``x_init``
    (optional) seeds the estimates with data rows when ``cfg.init !=
    "range"`` — the non-compressive inits of paper §4.2.
    """
    w = fo.as_operator(w)
    n, m = w.n, w.m
    k = cfg.k
    lo = jnp.asarray(lower, jnp.float32)
    hi = jnp.asarray(upper, jnp.float32)
    span = jnp.maximum(hi - lo, 1e-12)
    # Stacked-real convention: z = [sum b cos, -sum b sin], so the sampled
    # characteristic function is y = z1 - i z2.
    y_re, y_im = z[:m], -z[m:]
    # ||A||_F^2 of the linear stage A = W^T — the only operator statistic the
    # scalar-variance GAMP needs beyond apply/adjoint.
    anorm2 = jnp.maximum(jnp.sum(w.col_sq_norms()), 1e-12)

    def estimates_init(k_init):
        if cfg.init == "range" or x_init is None:
            return lo + jax.random.uniform(k_init, (k, n)) * span
        x_data = jnp.clip(jnp.asarray(x_init, jnp.float32), lo, hi)
        if cfg.init != "kpp":  # "sample": uniform data rows
            idx = jax.random.randint(k_init, (k,), 0, x_data.shape[0])
            return x_data[idx]

        # "kpp": sequential D^2 sampling over data rows (paper §4.2).
        def pick(t, carry):
            c_buf, k_loop = carry
            k_loop, k_t = jax.random.split(k_loop)
            d2 = jnp.sum((x_data[:, None, :] - c_buf[None]) ** 2, axis=-1)
            d2 = jnp.where((jnp.arange(k) < t)[None, :], d2, jnp.inf)
            dmin = jnp.min(d2, axis=1)
            dmin = jnp.where(jnp.isfinite(dmin), dmin, 1.0)  # t=0: uniform
            idx = jax.random.categorical(
                k_t, jnp.log(jnp.maximum(dmin, 1e-20))
            )
            return c_buf.at[t].set(x_data[idx]), k_loop

        c0 = jnp.zeros((k, n), jnp.float32)
        c0, _ = jax.lax.fori_loop(0, k, pick, (c0, k_init))
        return c0

    def refresh_alpha(cents, iters):
        a = sk.atoms(cents, w)  # (K, 2m)
        alpha = nnls_mod.nnls(a.T, z, jnp.ones((k,), bool), iters=iters)
        return alpha / jnp.maximum(jnp.sum(alpha), 1e-20)

    def gamp_iter(t, carry):
        cents, s_mat, q_x, alpha, v_trace, qx_trace = carry
        # -- linear stage out: pseudo-measurement means with Onsager term.
        q_p = jnp.maximum(q_x * anorm2 / m, 1e-12)
        p_mat = jnp.asarray(w.apply(cents), jnp.float32) - q_p * s_mat

        # -- output channel: von Mises posterior per (frequency, component).
        al = jnp.maximum(alpha, cfg.alpha_floor / k)[:, None]  # (K, 1)
        rho = jnp.exp(-0.5 * q_p)  # |E e^{i theta}| under N(p, q_p)
        cos_p, sin_p = jnp.cos(p_mat), jnp.sin(p_mat)
        g_re, g_im = rho * cos_p, rho * sin_p  # (K, m)
        yhat_re = jnp.sum(al * g_re, axis=0)  # (m,)
        yhat_im = jnp.sum(al * g_im, axis=0)
        # Output-noise level: the unexplained measurement energy.
        v = (
            jnp.mean((y_re - yhat_re) ** 2 + (y_im - yhat_im) ** 2)
            + cfg.noise_floor
        )
        # Leave-one-out residual: what frequency j says about component k.
        res_re = (y_re - yhat_re)[None, :] + al * g_re  # (K, m)
        res_im = (y_im - yhat_im)[None, :] + al * g_im
        res_abs = jnp.sqrt(res_re**2 + res_im**2)
        kappa_y = 2.0 * al * res_abs / v  # likelihood concentration
        safe = jnp.maximum(res_abs, 1e-20)
        # Prior (concentration 1/q_p at angle p) + likelihood (kappa_y at
        # the residual's angle) add as complex vectors.
        vec_re = cos_p / q_p + kappa_y * res_re / safe
        vec_im = sin_p / q_p + kappa_y * res_im / safe
        kappa = jnp.maximum(jnp.sqrt(vec_re**2 + vec_im**2), 1e-20)
        mu = jnp.arctan2(vec_im, vec_re)
        z_hat = p_mat + _wrap(mu - p_mat)  # unwrap onto the prior's sheet
        # Posterior phase variance ~ 1/kappa (concentrated von Mises); the
        # cap keeps the GAMP precision-difference q_s positive even when the
        # likelihood opposes the prior and |prior + likelihood| < 1/q_p.
        q_z = jnp.clip(jnp.mean(1.0 / kappa), 1e-12, 0.999 * q_p)

        s_new = (z_hat - p_mat) / q_p
        s_mat = cfg.damp * s_new + (1.0 - cfg.damp) * s_mat
        q_s = jnp.maximum((1.0 - q_z / q_p) / q_p, 1e-12)

        # -- linear stage in + input channel: truncated-Gaussian denoiser.
        q_r = n / (anorm2 * q_s)
        r_mat = cents + q_r * jnp.asarray(w.adjoint(s_mat), jnp.float32)
        c_new, v_new = ops.amp_denoise(r_mat, q_r, lo, hi, impl=cfg.impl)
        cents = cfg.damp * c_new + (1.0 - cfg.damp) * cents
        q_x = jnp.maximum(jnp.mean(v_new), 1e-12)

        alpha = refresh_alpha(cents, cfg.inner_nnls_iters)
        v_trace = v_trace.at[t].set(v)
        qx_trace = qx_trace.at[t].set(q_x)
        return cents, s_mat, q_x, alpha, v_trace, qx_trace

    cents0 = estimates_init(key)
    s0 = jnp.zeros((k, m), jnp.float32)
    q_x0 = jnp.mean(span * span) / 12.0  # variance of the box prior
    alpha0 = jnp.full((k,), 1.0 / k, jnp.float32)
    v_trace0 = jnp.zeros((cfg.iters,), jnp.float32)
    qx_trace0 = jnp.zeros((cfg.iters,), jnp.float32)
    cents, _, _, alpha, v_trace, qx_trace = jax.lax.fori_loop(
        0, cfg.iters, gamp_iter, (cents0, s0, q_x0, alpha0, v_trace0, qx_trace0)
    )

    # -- Polish: final weights + short joint descent on the shared objective,
    # in unit-box coordinates like the other registry decoders.
    alpha = nnls_mod.nnls(
        sk.atoms(cents, w).T, z, jnp.ones((k,), bool), iters=cfg.nnls_iters
    )
    if cfg.polish_steps > 0:
        s = (cents - lo) / span

        def joint_loss(params):
            s_, al_ = params
            res = z - al_ @ sk.atoms(lo + s_ * span, w)
            return jnp.sum(res * res)

        s, alpha = common.adam(
            joint_loss,
            (s, alpha),
            cfg.polish_steps,
            cfg.polish_lr,
            lambda params: (
                jnp.clip(params[0], 0.0, 1.0),
                jnp.maximum(params[1], 0.0),
            ),
        )
        cents = lo + s * span

    cost = common.residual_cost(z, cents, alpha, w)
    wsum = jnp.maximum(jnp.sum(alpha), 1e-20)
    if cfg.trace:
        return cents, alpha / wsum, cost, {
            "unexplained_energy": v_trace,
            "posterior_variance": qx_trace,
        }
    return cents, alpha / wsum, cost


# ---------------------------------------------------------------------------
# Registry adapter
# ---------------------------------------------------------------------------


@register_decoder("amp")
def decode_amp(key, z, w, lower, upper, cfg, x_init=None):
    """Registry entry: pull the static ``AMPConfig`` off the pipeline config
    (``cfg.amp_config()``) and run :func:`cl_amp`."""
    return cl_amp(key, z, w, lower, upper, cfg.amp_config(), x_init)
