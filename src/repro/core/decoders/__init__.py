"""Pluggable sketch decoders — the decode half of sketch -> decode.

Mirrors the engine subsystem on the other side of the pipeline: a ``Decoder``
protocol + registry (``registry.py``), with three built-ins registered on
import — ``"clompr"`` (paper Algorithm 1, numerics bitwise-identical to the
pre-registry ``core.clompr``), ``"sketch_shift"`` (mean-shift on the
sketched characteristic function) and ``"amp"`` (CL-AMP: joint approximate
message passing, accurate at sketch sizes where the greedy decoders degrade).
Select end-to-end with ``CKMConfig(decoder=...)``; see the Decoders section
of ``docs/architecture.md`` for the contract and when to pick which.
"""

from repro.core.decoders.registry import (
    DECODERS,
    Decoder,
    available_decoders,
    get_decoder,
    register_decoder,
)

# Importing the built-in decoder modules registers them.
from repro.core.decoders.amp import AMPConfig, cl_amp
from repro.core.decoders.clompr import CLOMPRConfig, clompr
from repro.core.decoders.sketch_shift import SketchShiftConfig, sketch_shift

__all__ = [
    "DECODERS",
    "Decoder",
    "available_decoders",
    "get_decoder",
    "register_decoder",
    "AMPConfig",
    "cl_amp",
    "CLOMPRConfig",
    "clompr",
    "SketchShiftConfig",
    "sketch_shift",
]
