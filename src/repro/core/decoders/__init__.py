"""Pluggable sketch decoders — the decode half of sketch -> decode.

Mirrors the engine subsystem on the other side of the pipeline: a ``Decoder``
protocol + registry (``registry.py``), with two built-ins registered on
import — ``"clompr"`` (paper Algorithm 1, numerics bitwise-identical to the
pre-registry ``core.clompr``) and ``"sketch_shift"`` (mean-shift on the
sketched characteristic function).  Select end-to-end with
``CKMConfig(decoder=...)``; see the Decoders section of
``docs/architecture.md`` for the contract and when to pick which.
"""

from repro.core.decoders.registry import (
    DECODERS,
    Decoder,
    available_decoders,
    get_decoder,
    register_decoder,
)

# Importing the built-in decoder modules registers them.
from repro.core.decoders.clompr import CLOMPRConfig, clompr
from repro.core.decoders.sketch_shift import SketchShiftConfig, sketch_shift

__all__ = [
    "DECODERS",
    "Decoder",
    "available_decoders",
    "get_decoder",
    "register_decoder",
    "CLOMPRConfig",
    "clompr",
    "SketchShiftConfig",
    "sketch_shift",
]
