"""Shared decoder machinery: projected Adam + the sketch-domain objective.

The built-in decoders optimise inside one ``jit`` with fixed shapes, so they
share the same fixed-step projected-Adam loop (moved verbatim from the
original ``core.clompr`` — CLOMPR's numerics are bitwise-unchanged by the
refactor) and report the same cost ``||z - A(C) alpha||^2`` for replicate
selection.

The helpers take ``w`` as a ``core.freq_ops.FrequencyOperator`` (costs and
radii go through ``op.apply``/``op.col_norms``, so structured fast-transform
operators work unchanged).  The raw ``(n, m)`` deprecation window closed in
PR 6: :func:`ensure_operator` now raises ``TypeError`` on a plain array —
wrap with ``freq_ops.as_operator(w)`` at the boundary instead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import freq_ops as fo
from repro.core import sketch as sk


def ensure_operator(w, caller: str = "decoder helper") -> fo.FrequencyOperator:
    """Operator pass-through; raw arrays raise (deprecation window closed)."""
    if not isinstance(w, fo.FrequencyOperator):
        raise TypeError(
            f"{caller} requires a core.freq_ops.FrequencyOperator; raw "
            "(n, m) frequency arrays were removed after their one-release "
            "deprecation window (PR 5) — wrap with freq_ops.as_operator(w)"
        )
    return w


def adam(loss_fn, params, steps: int, lr: float, project):
    """Minimise ``loss_fn`` over pytree ``params`` with projected Adam."""
    b1, b2, eps = 0.9, 0.999, 1e-8
    zeros = jax.tree.map(jnp.zeros_like, params)

    def body(carry, i):
        p, m, v = carry
        _, g = jax.value_and_grad(loss_fn)(p)
        m = jax.tree.map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
        v = jax.tree.map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ * g_, v, g)
        t = i + 1
        mhat_scale = 1.0 / (1.0 - b1**t)
        vhat_scale = 1.0 / (1.0 - b2**t)
        p = jax.tree.map(
            lambda p_, m_, v_: p_
            - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
            p,
            m,
            v,
        )
        p = project(p)
        return (p, m, v), None

    (params, _, _), _ = jax.lax.scan(
        body, (params, zeros, zeros), jnp.arange(1, steps + 1, dtype=jnp.float32)
    )
    return params


def residual_cost(z: jax.Array, centroids: jax.Array, alpha: jax.Array, w) -> jax.Array:
    """The shared selection objective: ``||z - sum_k alpha_k A delta_{c_k}||^2``."""
    op = ensure_operator(w, "residual_cost")
    r = z - alpha @ sk.atoms(centroids, op)
    return jnp.sum(r * r)


def resolution_radius(w, scale: float) -> jax.Array:
    """The sketch's spatial resolution: ``scale / median ||omega_j||``.

    Centroids closer than this are indistinguishable at the sampled
    frequencies — used by both decoders to suppress duplicate atoms/modes.
    """
    op = ensure_operator(w, "resolution_radius")
    return scale / jnp.median(op.col_norms())
