"""CLOMPR for K-means (paper Algorithm 1) — a jit-end-to-end JAX decoder.

This is the ``"clompr"`` entry of the decoder registry (``decoders.registry``);
the implementation moved here verbatim from ``core.clompr`` (which remains as
a thin re-export adapter), so its numerics are bitwise-unchanged.

The Matlab original grows the support ``C`` dynamically and calls fminunc /
lsqnonneg.  For XLA we restructure the decoder into *fixed shapes*:

- the support lives in a padded ``(K+1, n)`` buffer + boolean mask (the support
  never exceeds K+1: it grows by one per iteration and is hard-thresholded back
  to K once ``t > K``),
- gradient ascent/descent (steps 1 and 5) are projected Adam with a fixed step
  count, run in *unit-box coordinates* ``c = l + s (u - l)`` so learning rates
  are scale-free and the paper's box constraint is a clip,
- NNLS (steps 3/4) is FISTA with a fixed iteration budget (see nnls.py),
- hard thresholding is ``top_k`` + a compacting gather.

Everything (the 2K outer iterations included) runs inside one ``jax.jit``; the
decoder is ``vmap``-able over the PRNG key, which is how replicates are run in
parallel (see ckm.py).

Quantized sketches (QCKM).  The decoder consumes the *dequantized* sketch:
when ``CKMConfig.sketch_quantization`` is on, the engine's ``finalize`` has
already applied the E[sign] correction and dither rotation
(``core.quantize.dequantize_sums``), so the ``z`` passed here satisfies the
same ``z ~ A mu`` model with an extra additive noise floor (odd-harmonic
leakage + O(1/sqrt(N)) code noise).  CLOMPR needs no modification — greedy
residual pursuit is robust to this distortion (the QCKM result); only the
absolute value of ``cost`` shifts by the noise floor, which cancels when
comparing replicates of the same quantized sketch.  See ``docs/api.md``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import freq_ops as fo
from repro.core import nnls as nnls_mod
from repro.core import sketch as sk
from repro.core.decoders.common import adam as _adam
from repro.core.decoders.registry import register_decoder

InitStrategy = Literal["range", "sample", "kpp"]


@dataclasses.dataclass(frozen=True)
class CLOMPRConfig:
    """Static hyper-parameters of the decoder (hashable -> jit static arg)."""

    k: int
    atom_steps: int = 300  # step-1 gradient ascent iterations
    joint_steps: int = 200  # step-5 joint gradient descent iterations
    nnls_iters: int = 150
    atom_lr: float = 0.05  # Adam lr in unit-box coordinates
    joint_lr: float = 0.02
    init: InitStrategy = "range"
    # Step-1 ascent restarts: best of R random inits (cheap, vectorised).
    atom_restarts: int = 1
    # Extra step-5 iterations run once after the 2K outer loop: the Matlab
    # reference runs its minimisations to convergence; a final long polish
    # recovers that quality at fixed cost.
    final_steps: int = 1000
    # Beyond-paper: before hard thresholding, atoms closer than
    # ``merge_radius_scale / median||omega||`` (the sketch's resolution) to a
    # higher-beta atom are suppressed.  With IMBALANCED mixtures, two atoms
    # splitting a heavy cluster each out-weigh a light cluster's single atom
    # and the paper's top-K would drop the light cluster; within-resolution
    # duplicates carry no information, so suppressing them is safe.  0 = off
    # (paper-faithful behaviour).  The default 2.5/median||omega|| ~ 2 cluster
    # stds under the adapted-radius scale heuristic: split atoms straddling
    # one Gaussian sit ~2 stds apart, while paper-regime clusters are >=4-6
    # stds apart.
    merge_radius_scale: float = 2.5
    # Convergence tracing: when True the decoder also returns
    # ``{"residual_norm": (2K,)}`` — ||r|| after each outer iteration (one
    # atom added per entry).  The buffer is carried unconditionally and
    # dead-code-eliminated by XLA when False, so the default path's numerics
    # (and its jit graph) are bitwise those of the untraced decoder.
    trace: bool = False


# ---------------------------------------------------------------------------
# Step 1 — find a new centroid: maximise Re< A d_c / ||.||, r > over the box
# ---------------------------------------------------------------------------


def _init_s0(key, t, s_buf, mask, x_unit, cfg: CLOMPRConfig, shape):
    """Initial point(s) for the step-1 ascent, in unit-box coordinates."""
    if cfg.init == "range" or x_unit is None:
        return jax.random.uniform(key, shape)
    if cfg.init == "sample":
        idx = jax.random.randint(key, (shape[0],), 0, x_unit.shape[0])
        return x_unit[idx]
    # "kpp": D^2 sampling against the *current* support (k-means++ style; the
    # paper's wording says "inversely proportional to distance" but k-means++
    # [9] — which it cites as the analog — samples prop. to squared distance).
    d2 = jnp.sum((x_unit[:, None, :] - s_buf[None, :, :]) ** 2, axis=-1)  # (N, K+1)
    d2 = jnp.where(mask[None, :], d2, jnp.inf)
    dmin = jnp.min(d2, axis=1)
    dmin = jnp.where(jnp.isfinite(dmin), dmin, 1.0)  # t=0: uniform
    idx = jax.random.categorical(
        key, jnp.log(jnp.maximum(dmin, 1e-20))[None, :].repeat(shape[0], 0)
    )
    return x_unit[idx]


def _find_atom(key, r, w, lo, span, s_buf, mask, t, x_unit, cfg: CLOMPRConfig):
    """Gradient-ascend the normalised correlation; best of ``atom_restarts``."""
    m = w.m
    inv_norm = 1.0 / jnp.sqrt(jnp.asarray(m, jnp.float32))

    def neg_corr(s):  # s: (R, n) -> scalar (summed; restarts are independent)
        c = lo + s * span
        a = sk.atoms(c, w)  # (R, 2m)
        return -jnp.sum((a @ r) * inv_norm)

    shape = (cfg.atom_restarts, w.n)
    s0 = _init_s0(key, t, s_buf, mask, x_unit, cfg, shape)
    s_opt = _adam(
        neg_corr, s0, cfg.atom_steps, cfg.atom_lr, lambda p: jnp.clip(p, 0.0, 1.0)
    )
    corr = sk.atoms(lo + s_opt * span, w) @ r  # (R,)
    best = jnp.argmax(corr)
    return s_opt[best]


# ---------------------------------------------------------------------------
# The decoder
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def clompr(
    key: jax.Array,
    z: jax.Array,
    w: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    cfg: CLOMPRConfig,
    x_init: jax.Array | None = None,
):
    """Decode K weighted Diracs from the sketch ``z`` (stacked-real, (2m,)).

    Returns ``(centroids (K, n), weights (K,), cost)`` where ``cost`` is the
    final value of the paper's objective (4), used to select among replicates.
    ``x_init`` is only consulted by the non-compressive "sample"/"kpp" init
    strategies (paper §4.2).  ``w`` is a frequency operator (or raw matrix,
    deprecation shim): atoms and gradients go through ``op.apply``, so the
    structured fast-transform family decodes unchanged.
    """
    w = fo.as_operator(w)
    n = w.n
    m = w.m
    kp1 = cfg.k + 1
    lo = jnp.asarray(lower, jnp.float32)
    hi = jnp.asarray(upper, jnp.float32)
    span = jnp.maximum(hi - lo, 1e-12)
    x_unit = None if x_init is None else (jnp.asarray(x_init, jnp.float32) - lo) / span
    inv_norm = 1.0 / jnp.sqrt(jnp.asarray(m, jnp.float32))

    def model(s_buf, alpha, mask):
        """Masked sketch of the current mixture: sum_k alpha_k A delta_{c_k}."""
        a = sk.atoms(lo + s_buf * span, w)  # (K+1, 2m)
        maskf = mask.astype(jnp.float32)
        return (alpha * maskf) @ a

    def outer(t, carry):
        s_buf, alpha, mask, r, key, res_trace = carry
        key, k1 = jax.random.split(key)

        # -- Step 1+2: find a new centroid, expand support into the free slot.
        s_new = _find_atom(k1, r, w, lo, span, s_buf, mask, t, x_unit, cfg)
        count = jnp.sum(mask.astype(jnp.int32))
        s_buf = s_buf.at[count].set(s_new)  # count <= K: one slot always free
        mask = mask.at[count].set(True)

        # -- Step 3: hard thresholding once t >= K (support is then K+1).
        def threshold(args):
            s_buf, mask = args
            a_n = sk.atoms(lo + s_buf * span, w) * inv_norm  # normalised atoms
            beta = nnls_mod.nnls(a_n.T, z, mask, iters=cfg.nnls_iters)
            score = jnp.where(mask, beta, -jnp.inf)
            if cfg.merge_radius_scale > 0:
                # Suppress within-resolution duplicates of higher-beta atoms.
                cents = lo + s_buf * span
                d2 = jnp.sum((cents[:, None] - cents[None]) ** 2, axis=-1)
                radius = cfg.merge_radius_scale / jnp.median(w.col_norms())
                higher = (beta[None, :] > beta[:, None]) | (
                    (beta[None, :] == beta[:, None])
                    & (jnp.arange(kp1)[None, :] < jnp.arange(kp1)[:, None])
                )
                close = d2 < radius * radius
                absorbed = jnp.any(close & higher & mask[None, :], axis=1)
                score = jnp.where(absorbed, -jnp.inf, score)
            order = jnp.argsort(-score, stable=True)  # top-K first
            s_buf = s_buf[order]
            new_mask = jnp.arange(kp1) < cfg.k
            return s_buf, new_mask

        s_buf, mask = jax.lax.cond(
            t >= cfg.k, threshold, lambda args: args, (s_buf, mask)
        )

        # -- Step 4: NNLS projection for alpha on the (unnormalised) atoms.
        a = sk.atoms(lo + s_buf * span, w)
        alpha = nnls_mod.nnls(a.T, z, mask, iters=cfg.nnls_iters)

        # -- Step 5: joint gradient descent on (C, alpha), box + nonneg proj.
        def joint_loss(p):
            s_, al_ = p
            res = z - model(s_, al_, mask)
            return jnp.sum(res * res)

        def joint_project(p):
            s_, al_ = p
            return jnp.clip(s_, 0.0, 1.0), jnp.maximum(al_, 0.0)

        s_buf, alpha = _adam(
            joint_loss, (s_buf, alpha), cfg.joint_steps, cfg.joint_lr, joint_project
        )

        # -- Residual update.
        r = z - model(s_buf, alpha, mask)
        res_trace = res_trace.at[t].set(jnp.linalg.norm(r))
        return s_buf, alpha, mask, r, key, res_trace

    s_buf0 = jnp.zeros((kp1, n), jnp.float32)
    alpha0 = jnp.zeros((kp1,), jnp.float32)
    mask0 = jnp.zeros((kp1,), bool)
    res_trace0 = jnp.zeros((2 * cfg.k,), jnp.float32)
    carry = (s_buf0, alpha0, mask0, z, key, res_trace0)
    s_buf, alpha, mask, r, _, res_trace = jax.lax.fori_loop(
        0, 2 * cfg.k, outer, carry
    )

    # Final polish: one long joint descent (Matlab runs step 5 to convergence).
    if cfg.final_steps > 0:

        def joint_loss(p):
            s_, al_ = p
            a = sk.atoms(lo + s_ * span, w)
            res = z - (al_ * mask.astype(jnp.float32)) @ a
            return jnp.sum(res * res)

        s_buf, alpha = _adam(
            joint_loss,
            (s_buf, alpha),
            cfg.final_steps,
            cfg.joint_lr,
            lambda p: (jnp.clip(p[0], 0.0, 1.0), jnp.maximum(p[1], 0.0)),
        )
        a = sk.atoms(lo + s_buf * span, w)
        r = z - (alpha * mask.astype(jnp.float32)) @ a

    # Compact the K active slots to the front (exactly K are active at exit).
    order = jnp.argsort(~mask, stable=True)
    centroids = (lo + s_buf * span)[order][: cfg.k]
    weights = jnp.where(mask, alpha, 0.0)[order][: cfg.k]
    wsum = jnp.maximum(jnp.sum(weights), 1e-20)
    cost = jnp.sum(r * r)
    if cfg.trace:
        return centroids, weights / wsum, cost, {"residual_norm": res_trace}
    return centroids, weights / wsum, cost


# ---------------------------------------------------------------------------
# Registry adapter
# ---------------------------------------------------------------------------


@register_decoder("clompr")
def decode_clompr(key, z, w, lower, upper, cfg, x_init=None):
    """Registry entry: pull the static ``CLOMPRConfig`` off the pipeline config
    and run :func:`clompr` — the exact call ``ckm.decode_sketch`` used to make
    directly, so the registry path is bitwise-identical to the pre-registry one.
    """
    return clompr(key, z, w, lower, upper, cfg.clompr_config(), x_init)
