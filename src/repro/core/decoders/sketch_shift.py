"""Sketch-and-shift: a mean-shift decoder on the sketched characteristic
function (after Belhadji & Gribonval, "Sketch and shift: a robust decoder for
compressive clustering", 2023) — the ``"sketch_shift"`` registry entry.

The sketch ``z`` samples the empirical characteristic function at the drawn
frequencies, so

    f_r(c) = (1/m) <A delta_c, r>,   with residual r = z - A(C) alpha,

is a kernel-density surrogate of the *not-yet-explained* part of the data
distribution: ``f_z(c) = sum_l beta_l kappa(c - x_l)`` with ``kappa(d) =
(1/m) sum_j cos(w_j^T d)``, evaluable (with its gradient) from the sketch
alone.  Where CLOMPR finds atoms by gradient *ascent with a tuned learning
rate*, this decoder runs scale-free **mean-shift fixed-point iterations**

    c  <-  clip_box( c + h^2 grad f_r(c) / max(f_r(c), floor) )

on a swarm of P candidates (the classical Nadaraya–Watson update;
``h^2 = n / mean_j ||w_j||^2`` matches the curvature of kappa at 0, and the
per-step displacement is clipped to h so flat-region candidates drift uphill
instead of teleporting across basins).

Deflation is what makes the iterations robust.  Under shell-concentrated
frequency distributions (the paper's adapted radius), kappa has oscillatory
side lobes, and the ringing of heavy clusters can erase the density mode of a
light one — ascending the *raw* density provably loses such clusters (the
swarm drains into the dominant basins).  Running K rounds on the *residual*
CF removes each captured mode's ringing along with its mass, so every round's
dominant mode is a real, still-unexplained cluster — the same mechanism that
makes CLOMPR's greedy pursuit work, driven here by mean shift instead of
tuned gradient ascent.  After the K rounds: NNLS for the weights and a short
joint Adam polish on ``||z - A(C) alpha||^2``, the same sketch-domain
objective every registry decoder reports, so replicate selection and decoder
comparison share one scale.

The inner score/shift step is ``kernels.ops.sketch_shift_scores`` — the same
xla / Pallas kernel treatment as the sketch side (``SketchShiftConfig.impl``).
All shapes are fixed; the decoder is one ``jit`` end-to-end and
``lax.map``-able over replicate keys like every registry decoder.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import freq_ops as fo
from repro.core import nnls as nnls_mod
from repro.core import sketch as sk
from repro.core.decoders import common
from repro.core.decoders.registry import register_decoder
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class SketchShiftConfig:
    """Static hyper-parameters of the decoder (hashable -> jit static arg)."""

    k: int
    candidates: int = 40  # P, the mean-shift swarm size per round
    shift_steps: int = 75  # T fixed-point iterations per round
    step_scale: float = 1.0  # multiplier on the natural step h^2
    nnls_iters: int = 150
    polish_steps: int = 400  # joint Adam on (C, alpha) after the K rounds
    polish_lr: float = 0.02
    init: str = "range"  # "range" -> uniform in box; else rows of x_init
    # No new mode is harvested within ``dedup_radius_scale / median||w_j||``
    # of the kept support: its only job is to stop a round from re-picking
    # the *same* mode out of leftover residue, so one kernel std is right —
    # CLOMPR's larger 2.5 split-atom scale would forbid genuinely distinct
    # but overlapping clusters (means ~2 stds apart are still resolvable by
    # the residual, and the joint polish separates them further).
    dedup_radius_scale: float = 1.0
    # Density floor for the mean-shift denominator: the residual surrogate is
    # signed (kappa has negative side lobes), so far from any mode it can be
    # ~0 or negative; flooring keeps the update an uphill step, and the step
    # clip to h bounds its size.  In units of f, which is O(alpha_k) at a
    # mode and <= 1 everywhere.
    density_floor: float = 1e-3
    impl: str = "xla"  # score/shift kernel: "xla" | "pallas" (ops.py)
    # Convergence tracing: when True the decoder also returns
    # ``{"residual_norm": (K,)}`` — ||r|| after each deflation round.  The
    # buffer is carried unconditionally (XLA drops it when unused), so the
    # default path is bitwise the untraced decoder.
    trace: bool = False


@functools.partial(jax.jit, static_argnames=("cfg",))
def sketch_shift(
    key: jax.Array,
    z: jax.Array,
    w: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    cfg: SketchShiftConfig,
    x_init: jax.Array | None = None,
):
    """Decode K centroids from the sketch ``z`` by K rounds of mean shift on
    the residual sketched density.

    Returns ``(centroids (K, n), weights (K,), cost)`` with ``cost`` the
    shared sketch-domain objective ``||z - A(C) alpha||^2``.  ``x_init``
    (optional) seeds the swarm with data rows when ``cfg.init != "range"`` —
    the non-compressive inits of paper §4.2.
    """
    w = fo.as_operator(w)
    n, m = w.n, w.m
    k = cfg.k
    lo = jnp.asarray(lower, jnp.float32)
    hi = jnp.asarray(upper, jnp.float32)
    span = jnp.maximum(hi - lo, 1e-12)

    # Natural mean-shift step: kappa(d) ~ 1 - ||d||^2 mean||w||^2 / (2n) near
    # 0, i.e. a Gaussian-like kernel of bandwidth h^2 = n / mean_j ||w_j||^2.
    h2 = cfg.step_scale * n / jnp.maximum(jnp.mean(w.col_sq_norms()), 1e-12)
    h = jnp.sqrt(h2)
    radius = common.resolution_radius(w, cfg.dedup_radius_scale)
    x_data = (
        None
        if (cfg.init == "range" or x_init is None)
        else jnp.clip(jnp.asarray(x_init, jnp.float32), lo, hi)
    )

    def swarm_init(k_round, s_buf, t):
        if x_data is None:
            return lo + jax.random.uniform(k_round, (cfg.candidates, n)) * span
        if cfg.init != "kpp":  # "sample": uniform data rows
            idx = jax.random.randint(
                k_round, (cfg.candidates,), 0, x_data.shape[0]
            )
            return x_data[idx]
        # "kpp": D^2 sampling against the already-kept modes (k-means++
        # style, paper §4.2) — same rule as CLOMPR's step-1 init.
        kept = jnp.arange(k) < t
        d2 = jnp.sum((x_data[:, None, :] - s_buf[None, :, :]) ** 2, axis=-1)
        d2 = jnp.where(kept[None, :], d2, jnp.inf)
        dmin = jnp.min(d2, axis=1)
        dmin = jnp.where(jnp.isfinite(dmin), dmin, 1.0)  # t=0: uniform
        idx = jax.random.categorical(
            k_round,
            jnp.log(jnp.maximum(dmin, 1e-20))[None, :].repeat(
                cfg.candidates, 0
            ),
        )
        return x_data[idx]

    def shift(r):
        """One mean-shift fixed-point step of the whole swarm on residual r."""

        def body(c, _):
            f, g = ops.sketch_shift_scores(c, w, r, impl=cfg.impl)
            delta = h2 * g / jnp.maximum(f, cfg.density_floor)[:, None]
            norm = jnp.linalg.norm(delta, axis=1, keepdims=True)
            delta = delta * jnp.minimum(1.0, h / jnp.maximum(norm, 1e-20))
            return jnp.clip(c + delta, lo, hi), None

        return body

    def round_(t, carry):
        s_buf, alpha, r, key, res_trace = carry
        key, k_round = jax.random.split(key)

        # -- Mean-shift swarm on the residual density: collapse onto the
        # dominant not-yet-explained mode.
        cands, _ = jax.lax.scan(
            shift(r), swarm_init(k_round, s_buf, t), None,
            length=cfg.shift_steps,
        )

        # -- Harvest: densest candidate not within the sketch's resolution of
        # an already-kept mode (a duplicate carries no new information).
        f, _ = ops.sketch_shift_scores(cands, w, r, impl=cfg.impl)
        mask = jnp.arange(k) < t  # currently-kept support slots
        d2 = jnp.sum((cands[:, None] - s_buf[None]) ** 2, axis=-1)  # (P, K)
        dup = jnp.any((d2 < radius * radius) & mask[None, :], axis=1)
        score = jnp.where(dup, -jnp.inf, f)
        s_buf = s_buf.at[t].set(cands[jnp.argmax(score)])

        # -- Reweight the support and deflate the residual.
        mask = jnp.arange(k) <= t
        a = sk.atoms(s_buf, w)  # (K, 2m)
        alpha = nnls_mod.nnls(a.T, z, mask, iters=cfg.nnls_iters)
        r = z - (alpha * mask.astype(jnp.float32)) @ a
        res_trace = res_trace.at[t].set(jnp.linalg.norm(r))
        return s_buf, alpha, r, key, res_trace

    s_buf0 = jnp.zeros((k, n), jnp.float32)
    alpha0 = jnp.zeros((k,), jnp.float32)
    res_trace0 = jnp.zeros((k,), jnp.float32)
    s_buf, alpha, _, _, res_trace = jax.lax.fori_loop(
        0, k, round_, (s_buf0, alpha0, z, key, res_trace0)
    )
    cents = s_buf

    # -- Polish: short joint descent on the shared objective, in unit-box
    # coordinates like CLOMPR's step 5 (lr is scale-free, box is a clip).
    if cfg.polish_steps > 0:
        s = (cents - lo) / span

        def joint_loss(params):
            s_, al_ = params
            res = z - al_ @ sk.atoms(lo + s_ * span, w)
            return jnp.sum(res * res)

        s, alpha = common.adam(
            joint_loss,
            (s, alpha),
            cfg.polish_steps,
            cfg.polish_lr,
            lambda params: (
                jnp.clip(params[0], 0.0, 1.0),
                jnp.maximum(params[1], 0.0),
            ),
        )
        cents = lo + s * span

    cost = common.residual_cost(z, cents, alpha, w)
    wsum = jnp.maximum(jnp.sum(alpha), 1e-20)
    if cfg.trace:
        return cents, alpha / wsum, cost, {"residual_norm": res_trace}
    return cents, alpha / wsum, cost


# ---------------------------------------------------------------------------
# Registry adapter
# ---------------------------------------------------------------------------


@register_decoder("sketch_shift")
def decode_sketch_shift(key, z, w, lower, upper, cfg, x_init=None):
    """Registry entry: pull the static ``SketchShiftConfig`` off the pipeline
    config (``cfg.sketch_shift_config()``) and run :func:`sketch_shift`."""
    return sketch_shift(key, z, w, lower, upper, cfg.sketch_shift_config(), x_init)
