"""The decoder registry — the sketch-to-centroids half of the pipeline.

The paper's pipeline is *sketch -> decode*.  The sketch half is a pluggable
subsystem (``core.engine.SketchEngine``: backends + state transforms); this
package mirrors that architecture on the decode half.  A **decoder** turns a
finalized sketch into centroids:

    decode(key, z, w, lower, upper, cfg, x_init=None)
        -> (centroids (K, n), alphas (K,), cost scalar)

where ``z`` is the stacked-real ``(2m,)`` sketch, ``w`` the frequency
operator (``core.freq_ops.FrequencyOperator`` — atoms/costs go through
``op.apply``/``op.adjoint``, so fast-transform families decode unchanged;
wrap a raw ``(n, m)`` matrix with ``freq_ops.as_operator`` first),
``(lower, upper)`` the box bounds harvested by the engine, ``cfg`` the
pipeline config (a ``ckm.CKMConfig``-shaped object — each decoder extracts its
own static sub-config from it), and ``x_init`` an optional data sample for the
non-compressive init strategies.  ``cost`` is the sketch-domain objective
``||z - A(C) alpha||^2`` — every decoder reports the *same* objective so
replicate selection (and decoder comparison) is apples-to-apples.

Contract: a decoder must be a pure jit-able function of its array arguments
(``cfg`` static), and ``lax.map``-able over PRNG keys — that is how
``ckm.decode_sketch`` runs best-of-R replicates.

Registering a decoder::

    @register_decoder("my_decoder")
    def my_decoder(key, z, w, lower, upper, cfg, x_init=None):
        ...

Built-ins: ``"clompr"`` (the paper's Algorithm 1, moved here unchanged),
``"sketch_shift"`` (mean-shift iterations on the sketched characteristic
function, Belhadji & Gribonval 2023) and ``"amp"`` (CL-AMP: joint hybrid
approximate message passing, Byrne et al. 2017 — accurate down to
m ~ 2-4 K n where the greedy decoders need ~10 K n).  Selection is a config
flag: ``CKMConfig(decoder="amp")``.
"""

from __future__ import annotations

from typing import Callable, Protocol

import jax


class Decoder(Protocol):
    """A sketch decoder: ``(key, z, w, lower, upper, cfg[, x_init])`` ->
    ``(centroids, alphas, cost)``."""

    def __call__(
        self,
        key: jax.Array,
        z: jax.Array,
        w: jax.Array,
        lower: jax.Array,
        upper: jax.Array,
        cfg,
        x_init: jax.Array | None = None,
    ) -> tuple[jax.Array, jax.Array, jax.Array]: ...


DECODERS: dict[str, Decoder] = {}


def register_decoder(name: str) -> Callable[[Decoder], Decoder]:
    """Decorator: register ``fn`` under ``name`` (unique, lowercase)."""

    def deco(fn: Decoder) -> Decoder:
        if name in DECODERS:
            raise ValueError(f"decoder {name!r} already registered")
        DECODERS[name] = fn
        return fn

    return deco


def get_decoder(name: str) -> Decoder:
    """Look up a registered decoder; raises with the available names."""
    try:
        return DECODERS[name]
    except KeyError:
        raise KeyError(
            f"unknown decoder {name!r}; available: {sorted(DECODERS)}"
        ) from None


def available_decoders() -> list[str]:
    """Sorted names of all registered decoders."""
    return sorted(DECODERS)
