"""Universal (dithered) quantization of the sketch — the QCKM subsystem.

Quantized Compressive K-Means (Schellekens & Jacques, 2018) observes that the
sketch survives heavy per-sample quantization: instead of accumulating the
float contribution ``(cos(w_j^T x + xi_j), sin(w_j^T x + xi_j))`` per point,
accumulate only its *universal 1-bit quantization* — the sign of the dithered
phase through the square wave — or a coarse ``b``-bit uniform code.  The
partial sums become **integer** accumulators, which

- keeps the mergeable-monoid contract of ``core.engine`` intact (sum of
  integer codes is associative + commutative, identity = zeros),
- is exactly split-invariant (the code of a point is a deterministic function
  of the point and the per-frequency dither — no per-sample randomness — so
  any batching of the same points yields the *same* integer state),
- shrinks merge traffic: a partial state over ``B`` points needs only
  ``ceil(log2(2*B*S + 1))`` bits per accumulator entry instead of an f32
  (see :func:`state_wire_bytes`), the bandwidth-aware path for the sharded
  backend's ``psum``.

Encoding (per point ``x``, frequency ``w_j``, dither ``xi_j ~ U[0, 2pi)``)::

    theta_j = w_j^T x + xi_j
    1-bit:   q_c = sign(cos theta_j),            q_s = sign(sin theta_j)
    b-bit:   q_c = round(S * cos theta_j),       q_s = round(S * sin theta_j)
             with S = 2**(b-1) - 1 levels per sign

Decoding (the known E[sign] correction).  The square wave has the Fourier
series ``sign(cos t) = (4/pi) sum_k (-1)^k cos((2k+1) t) / (2k+1)``, so the
mean of signs over the data is, per frequency,

    mean_i sign(cos(theta_ij)) = (4/pi) [ Re(e^{i xi_j} phi(w_j))
                                          - Re(e^{3 i xi_j} phi(3 w_j))/3 + … ]

where ``phi`` is the empirical characteristic function.  Multiplying by
``pi/4`` and rotating the (cos, sin) pair back by the dither ``-xi`` recovers
``phi(w_j)`` — the paper's sketch entry — up to the odd-harmonic leakage
``|phi(3w)|/3 + |phi(5w)|/5 + …``.  For the adapted-radius frequency scale the
characteristic function at ``3w`` is deep in its tail, so the leakage is small;
the uniformly-random dither makes the k>=3 phases incoherent across
frequencies, so what leakage remains behaves as noise rather than bias in the
decoder.  For the ``b``-bit code the correction is ``1/S`` (no square-wave
factor) and the rounding error is bounded by ``1/(2S)`` per entry.

``CLOMPR`` then runs unchanged on the dequantized sketch — the QCKM result is
precisely that the decoder is robust to this residual distortion.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = [
    "SketchQuantizer",
    "parse_bits",
    "draw_dither",
    "make_quantizer",
    "quantization_scale",
    "accumulator_capacity",
    "quantize_codes",
    "dequantize_sums",
    "state_wire_bytes",
]


def parse_bits(spec: str) -> int | None:
    """Parse a ``CKMConfig.sketch_quantization`` string.

    ``"none"`` -> ``None``; ``"1bit"`` -> 1; ``"4bit"`` -> 4; … up to 16 bits
    (beyond 16 the codes stop being "heavily compressed" and an f32 sketch is
    simpler).  Raises ``ValueError`` on anything else.
    """
    s = spec.strip().lower()
    if s in ("none", "", "float", "off"):
        return None
    if s.endswith("bit"):
        try:
            bits = int(s[:-3].rstrip("-_ "))
        except ValueError:
            bits = -1
        if 1 <= bits <= 16:
            return bits
    raise ValueError(
        f"sketch_quantization must be 'none', '1bit', or '<b>bit' (b<=16); "
        f"got {spec!r}"
    )


def quantization_scale(bits: int) -> int:
    """Integer levels per sign: 1 for the 1-bit sign code, ``2**(b-1)-1`` else."""
    return 1 if bits == 1 else (1 << (bits - 1)) - 1


def accumulator_capacity(bits: int) -> int:
    """Max number of points an int32 accumulator holds without overflow.

    Worst case every point contributes a full-scale code, so the capacity is
    ``(2**31 - 1) // scale``: the whole int32 range at 1 bit (~2.1e9 points),
    ~16.9M points at 8 bits, ~65k at 16.  The engine's ``finalize`` checks
    the folded count against this bound — beyond it the integer sums would
    wrap silently and the dequantized sketch would be garbage.
    """
    return (2**31 - 1) // quantization_scale(bits)


def draw_dither(key: jax.Array, m: int) -> jax.Array:
    """Per-frequency dither ``xi ~ U[0, 2pi)^m``, shared encoder/decoder."""
    return jax.random.uniform(key, (m,), jnp.float32, 0.0, 2.0 * math.pi)


@dataclasses.dataclass(frozen=True)
class SketchQuantizer:
    """Universal quantizer for one frequency matrix: ``bits`` + fixed dither.

    Holds everything the decoder needs to undo the encoding: the bit depth
    (static) and the per-frequency dither (an ``(m,)`` array drawn once with
    :func:`draw_dither` and reused by every update and by ``dequantize``).
    Pass instances to ``SketchEngine(..., quantizer=...)`` — do **not** mark
    them as jit-static (the dither is a traced array).
    """

    bits: int
    dither: jax.Array  # (m,) f32, xi ~ U[0, 2pi)

    @property
    def scale(self) -> int:
        return quantization_scale(self.bits)


def make_quantizer(key: jax.Array, m: int, spec: str) -> SketchQuantizer | None:
    """``spec`` string -> quantizer (or ``None`` for the float path)."""
    bits = parse_bits(spec)
    if bits is None:
        return None
    return SketchQuantizer(bits=bits, dither=draw_dither(key, m))


def quantize_codes(
    proj: jax.Array, dither: jax.Array, bits: int, valid: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Integer codes of one projection block.

    ``proj``: (..., m) raw phases ``x @ W``; ``dither``: (m,).  Returns int32
    ``(q_cos, q_sin)`` of the same shape.  ``valid`` (broadcastable 0/1 mask)
    zeroes padding rows so they cannot shift the integer sums.
    """
    theta = proj + dither
    c, s = jnp.cos(theta), jnp.sin(theta)
    if bits == 1:
        qc = jnp.where(c >= 0, 1, -1)
        qs = jnp.where(s >= 0, 1, -1)
    else:
        scale = float(quantization_scale(bits))
        qc = jnp.round(c * scale).astype(jnp.int32)
        qs = jnp.round(s * scale).astype(jnp.int32)
    qc = qc.astype(jnp.int32)
    qs = qs.astype(jnp.int32)
    if valid is not None:
        v = valid.astype(jnp.int32)
        qc = qc * v
        qs = qs * v
    return qc, qs


def dequantize_sums(
    qcos: jax.Array,
    qsin: jax.Array,
    dither: jax.Array,
    bits: int,
) -> tuple[jax.Array, jax.Array]:
    """E[sign] correction: integer sums -> float ``(cos_acc, sin_acc)`` sums.

    Returns unnormalised float accumulators equivalent to the unquantized
    state's ``(sum cos(w^T x), sum sin(w^T x))`` so the engine's ``finalize``
    is shared (it divides by ``weight_sum`` as for float states): correction
    factor (``pi/4`` for 1-bit, ``1/S`` for b-bit), then a joint rotation by
    ``-xi`` undoes the dither exactly.
    """
    corr = math.pi / 4.0 if bits == 1 else 1.0 / quantization_scale(bits)
    sc = corr * qcos.astype(jnp.float32)  # ~ sum cos(theta + xi)
    ss = corr * qsin.astype(jnp.float32)  # ~ sum sin(theta + xi)
    cd, sd = jnp.cos(dither), jnp.sin(dither)
    cos_sum = cd * sc + sd * ss  # cos(t) = cos(t+xi)cos(xi) + sin(t+xi)sin(xi)
    sin_sum = cd * ss - sd * sc
    return cos_sum, sin_sum


def state_wire_bytes(m: int, count: int, bits: int | None) -> int:
    """Bytes-on-the-wire of one partial state's accumulators.

    The merge traffic of the sharded backend is dominated by the two ``(m,)``
    accumulators.  Float states ship ``2*m`` f32s.  A quantized partial over
    ``count`` points has entries in ``[-count*S, count*S]``, so the minimal
    integer width is ``ceil(log2(2*count*S + 1))`` bits, rounded up to the
    nearest {1, 2, 4}-byte lane type actually available on the interconnect.
    """
    if bits is None:
        return 2 * m * 4
    span = 2 * max(int(count), 1) * quantization_scale(bits) + 1
    needed_bits = max(8, math.ceil(math.log2(span)))
    width = next((w for w in (1, 2, 4) if 8 * w >= needed_bits), 8)
    return 2 * m * width
