"""Bucketed ring-of-sketches windows: "cluster the last hour of events".

Exponential decay (``SketchEngine(decay=...)``) down-weights the past but
never forgets it; a **window** forgets exactly.  :class:`SketchWindow` keeps
``W`` rotating *bucket* states — bucket ``b`` holds the sketch of everything
that arrived in tick-interval ``[b·bucket_ticks, (b+1)·bucket_ticks)`` — and
answers a query by merging the live buckets **on read**.  Memory is
O(W · m) per tenant and an update touches exactly one bucket, so windowing
costs one extra ring lookup over the lifetime engine (pinned ≤ 1.3x by
``benchmarks/kernels.py run_window``).

The ring reuses slots modulo ``W``: when a new tick claims the slot of an
expired bucket, the stale state is reset to the monoid identity first, and
``read`` filters slots to the exact ``(read_tick - W, read_tick]`` tick range
— a reused slot can never leak expired data into a query
(``tests/test_window.py`` fuzzes this).

Everything here is plain monoid algebra over the wrapped engine — a
:class:`~repro.core.engine.SketchEngine` **or** a
:class:`~repro.core.fleet.FleetEngine` (the whole fleet windows in the same
W-slot ring; per-slot states are the stacked ``(T, …)`` states, so one
bucket update is still one vmapped dispatch).  Combining ``decay`` with a
window gives exponential weighting *inside* the window and a hard cutoff at
its edge; ``read`` then advances the merged state's clock to the query time.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np

__all__ = ["SketchWindow", "WindowState"]


@dataclasses.dataclass(frozen=True)
class WindowState:
    """Ring of ``W`` bucket states plus host-side slot bookkeeping.

    ``buckets`` is a tuple of W *separate* engine states (not one stacked
    array) so an update rewrites exactly one bucket's leaves — stacking the
    ring would make every ``.at[slot].set`` copy all W buckets.  ``slot_tick``
    records which absolute tick each slot currently holds (-1 = identity /
    never used); ``head`` is the newest tick ever claimed (-1 = empty).
    Bookkeeping is host-side numpy, like ``FleetService``'s version counters.
    """

    buckets: tuple[Any, ...]
    slot_tick: np.ndarray  # (W,) int64, -1 = empty slot
    head: int  # newest claimed tick, -1 = empty window


class SketchWindow:
    """A W-bucket sliding window over any sketch engine.

    Parameters
    ----------
    engine : the wrapped :class:`~repro.core.engine.SketchEngine` or
        :class:`~repro.core.fleet.FleetEngine` — the window is pure monoid
        plumbing and inherits the engine's backend/quantizer/decay transform.
    buckets : W, the window length in buckets.  A read at tick ``c`` merges
        buckets ``(c - W, c]`` — "the last W buckets including the current".
    bucket_ticks : width of one bucket on the ``t`` axis (tick ``floor(t /
        bucket_ticks)``).  With ``decay`` on the engine, ``t`` must share the
        unit the engine's gamma is defined per.
    """

    def __init__(self, engine, buckets: int, *, bucket_ticks: float = 1.0):
        if buckets < 1:
            raise ValueError(f"buckets must be >= 1, got {buckets}")
        if not bucket_ticks > 0:
            raise ValueError(
                f"bucket_ticks must be positive, got {bucket_ticks}"
            )
        self.engine = engine
        self.buckets = int(buckets)
        self.bucket_ticks = float(bucket_ticks)

    # -- ring bookkeeping ----------------------------------------------------

    def tick(self, t) -> int:
        """Absolute bucket index of time ``t``."""
        return int(math.floor(float(t) / self.bucket_ticks))

    def init_state(self) -> WindowState:
        """W identity buckets, nothing claimed."""
        return WindowState(
            buckets=tuple(
                self.engine.init_state() for _ in range(self.buckets)
            ),
            slot_tick=np.full((self.buckets,), -1, np.int64),
            head=-1,
        )

    def _claim(self, ws: WindowState, tick: int):
        """Slot for ``tick``, resetting a stale occupant; None = too late.

        Returns ``(ws, slot)``.  A tick already outside the newest possible
        read window (``tick <= head - W``) is dropped — its slot now belongs
        to a newer bucket and folding into it would corrupt that bucket.
        """
        if ws.head >= 0 and tick <= ws.head - self.buckets:
            return ws, None
        slot = tick % self.buckets
        if int(ws.slot_tick[slot]) != tick:
            # Rotate: the slot's previous occupant (an expired bucket, or
            # nothing) is discarded and the slot restarts from identity.
            bks = list(ws.buckets)
            bks[slot] = self.engine.init_state()
            st = ws.slot_tick.copy()
            st[slot] = tick
            ws = WindowState(
                buckets=tuple(bks),
                slot_tick=st,
                head=max(ws.head, tick),
            )
        elif tick > ws.head:
            ws = dataclasses.replace(ws, head=tick)
        return ws, slot

    def _fold(self, ws: WindowState, t, fold_fn):
        """Shared claim-then-fold body of update/ingest."""
        tick = self.tick(t)
        ws, slot = self._claim(ws, tick)
        if slot is None:  # older than the whole ring: drop, don't corrupt
            return ws
        bks = list(ws.buckets)
        bks[slot] = fold_fn(bks[slot])
        return dataclasses.replace(ws, buckets=tuple(bks))

    # -- monoid ops ----------------------------------------------------------

    def update(self, ws: WindowState, batch, weights=None, *, t):
        """Fold ``batch`` at time ``t`` into its bucket (single engine:
        ``batch (B, n)``; fleet engine: aligned block ``(T, B, n)``)."""
        if self.engine.decay is not None:
            fold = lambda b: self.engine.update(  # noqa: E731
                b, batch, weights, t=float(t)
            )
        else:
            fold = lambda b: self.engine.update(b, batch, weights)  # noqa: E731
        return self._fold(ws, t, fold)

    def ingest(self, ws: WindowState, tenant_ids, batches, weights=None, *, t):
        """Fleet request routing at time ``t`` (see ``FleetEngine.ingest``).
        All requests of one call share ``t`` — they land in one bucket."""
        if self.engine.decay is not None:
            fold = lambda b: self.engine.ingest(  # noqa: E731
                b, tenant_ids, batches, weights, t=float(t)
            )
        else:
            fold = lambda b: self.engine.ingest(  # noqa: E731
                b, tenant_ids, batches, weights
            )
        return self._fold(ws, t, fold)

    def read(self, ws: WindowState, t=None):
        """Merge-on-read: the engine state of the last W buckets at ``t``.

        ``t=None`` reads at the newest claimed tick.  Buckets with tick in
        ``(read_tick - W, read_tick]`` merge in increasing-tick order from
        the engine identity (a fixed association, so repeated reads are
        bitwise reproducible); every other slot — empty, expired, or claimed
        by a tick later than ``t`` — is excluded, which is what makes slot
        reuse safe.  With ``decay`` on the engine and an explicit ``t``, the
        merged state's clock is then advanced to ``t``.
        """
        read_tick = ws.head if t is None else self.tick(t)
        live = sorted(
            (int(tk), slot)
            for slot, tk in enumerate(ws.slot_tick)
            if tk >= 0 and read_tick - self.buckets < tk <= read_tick
        )
        out = self.engine.init_state()
        for _, slot in live:
            out = self.engine.merge(out, ws.buckets[slot])
        if self.engine.decay is not None and t is not None:
            out = self.engine.decay_to(out, float(t))
        return out

    def finalize(self, ws: WindowState, t=None):
        """``read`` + engine finalize: the windowed ``(z, lower, upper)``."""
        return self.engine.finalize(self.read(ws, t))

    # -- fleet tenant surgery ------------------------------------------------

    def tenant_column(self, ws: WindowState, tenant: int):
        """Tenant's per-slot rows (tuple of W single-engine states) — what
        evict checkpoints alongside the lifetime row."""
        return tuple(
            self.engine.tenant_state(b, tenant) for b in ws.buckets
        )

    def set_tenant_column(self, ws: WindowState, tenant: int, column):
        """Write a tenant's W per-slot rows back (restore path)."""
        if len(column) != self.buckets:
            raise ValueError(
                f"column has {len(column)} rows for {self.buckets} buckets"
            )
        bks = tuple(
            self.engine.set_tenant(b, tenant, row)
            for b, row in zip(ws.buckets, column)
        )
        return dataclasses.replace(ws, buckets=bks)

    def reset_tenant(self, ws: WindowState, tenant: int):
        """Tenant's rows to identity in every bucket (post-eviction hole).
        Slot bookkeeping is fleet-global and unchanged — other tenants keep
        their buckets."""
        bks = tuple(
            self.engine.reset_tenant(b, tenant) for b in ws.buckets
        )
        return dataclasses.replace(ws, buckets=bks)

    def state_bytes(self, ws: WindowState) -> int:
        """Resident bytes of the whole ring (W buckets)."""
        import jax

        return int(
            sum(
                leaf.size * leaf.dtype.itemsize
                for b in ws.buckets
                for leaf in jax.tree_util.tree_leaves(b)
            )
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"SketchWindow(W={self.buckets}, bucket_ticks={self.bucket_ticks}"
            f", engine={self.engine!r})"
        )
