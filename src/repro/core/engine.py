"""Unified streaming SketchEngine — one mergeable-sketch API, three backends.

The paper's central object is the sketch ``z = Sk(X, 1/N)``: a one-pass,
*linear* summary of the empirical distribution.  Linearity makes the partial
sums a **commutative monoid**: any way of splitting the data over batches,
devices, or hosts and any order of combining the partials yields the same
sketch.  This module is the single implementation of that contract; every
producer (in-memory, streaming, distributed) and every consumer (CLOMPR,
monitors, the data balancer) goes through it.

Mergeable-state contract
------------------------
``SketchEngineState(cos_acc, sin_acc, weight_sum, lower, upper, count)`` with

- identity:      ``init_state()`` (zero sums, ``+inf/-inf`` bounds),
- ``update``:    fold one weighted batch into a state (one pass, O(m) memory),
- ``merge``:     elementwise combine — **associative and commutative**, so
                 states may be combined across batches/devices/hosts in any
                 order (tree reductions, psum, delayed stragglers all legal),
- ``finalize``:  normalise to the paper's sketch:  ``z = sums / weight_sum``
                 (stacked-real ``[sum b cos, -sum b sin] / sum b``), plus the
                 CLOMPR box bounds ``(lower, upper)`` harvested in the same
                 pass.

Backend matrix
--------------
=========  ==================================================================
backend    update path
=========  ==================================================================
xla        ``core.sketch.sketch`` — chunked ``lax.scan``; the (N, m)
           projection never materialises.  Runs everywhere; the default.
pallas     ``kernels.ops.fourier_sketch_sums`` — fused MXU+VPU TPU kernel
           (projection tile stays in VMEM).  Inputs are auto-padded to tile
           alignment (N→block_n, n→8, m→block_m); off-TPU the kernel body
           runs in ``interpret=True`` mode for correctness.
sharded    ``shard_map`` over a device mesh: every device sketches its local
           shard, one ``psum/pmin/pmax`` merges — O(m) cross-device traffic,
           independent of N.  Requires ``mesh=``; uses the version-compat
           shim in ``utils.compat`` (old and new ``shard_map`` APIs).
=========  ==================================================================

All three backends produce identical sketches (within float tolerance) — the
tier-1 suite asserts pairwise parity at 1e-4 on CPU.

State transforms
----------------
Passing ``quantizer=`` (a ``core.quantize.SketchQuantizer``) swaps the state
for its universally-quantized twin ``QuantizedSketchEngineState``: per-point
contributions are quantized to 1-bit signs or ``b``-bit integer codes of the
dithered phase, and the accumulators become **int32** sums — still a
commutative monoid (integer addition), still exactly split-invariant (codes
are deterministic per point), but 2-4x cheaper on the wire at minimal integer
width when partials are merged across devices (the sharded backend psums the
integer accumulators; the 32x factor applies to the raw per-sample codes).
``finalize`` dequantizes via the known E[sign] correction and returns the same
``(z, lower, upper)`` contract, so consumers — CLOMPR included — are unchanged.
See ``docs/architecture.md`` for the full contract and ``core.quantize`` for
the encoding/decoding math.

Passing ``decay=gamma`` (0 < gamma <= 1) switches the state to its
**time-decayed** twin: every accumulator entry carries the timestamp of the
newest contribution folded in, and merging two states first scales the older
operand's trig/weight sums by ``gamma**dt`` (dt = stamp difference) before the
elementwise combine.  The decayed merge is still commutative with the same
identity (``stamp=-inf``); associativity holds exactly in the algebra (each
batch contribution ends scaled by ``gamma**(t_newest - t_batch)`` under any
association) and bitwise whenever the operands share a stamp — cross-stamp
regroupings agree to float rounding, like any float re-association.  The
finalized sketch becomes the exponentially-reweighted average
``z = sum_i gamma**(T - t_i) part_i / sum_i gamma**(T - t_i) w_i`` — a live
estimate of the *recent* distribution on non-stationary streams.  On the
quantized transform the int32 code accumulators are never scaled (a decayed
integer is not an integer): the newest-stamp segment stays an exact int32
sum, and decay moves older segments into a float32 side-channel
(``dcos_acc``/``dsin_acc``) carrying the accumulated ``gamma`` powers, so
same-stamp merges remain bitwise split-invariant.  Bounds ``lower/upper`` and
``count`` are lifetime (min/max and counts cannot be decayed).  Composes with
every backend and with ``quantizer=``; see ``core.window`` for the bucketed
ring window built on top.

Scaling hooks
-------------
Batch *production* and cross-device *merging* are pluggable too.
``core.ingest`` overlaps host-side batch generation/transfer with ``update``
(double-buffered producer thread behind the ``BatchSource`` protocol —
``sketch_stream(..., async_ingest=True)`` or ``CKMConfig.ingest="async"``),
and ``core.topology`` makes the merge *schedule* a registry choice:
``reduce_topology="allreduce" | "tree" | "ring"`` selects how the sharded
backend combines per-device partials (and how :meth:`SketchEngine.reduce_partials`
folds host-level partials).  Every schedule yields the same sketch — bitwise
on the quantized path — by the monoid laws above.  See ``docs/scaling.md``.
"""

from __future__ import annotations

import functools
from typing import Iterable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import freq_ops as fo
from repro.core import quantize as qz
from repro.core import sketch as sk
from repro.core import topology as topo
from repro.obs import runtime as obs_rt
from repro.parallel.sharding import axis_extent
from repro.utils import compat

__all__ = [
    "SketchEngineState",
    "QuantizedSketchEngineState",
    "DecayedSketchEngineState",
    "DecayedQuantizedSketchEngineState",
    "SketchEngine",
    "BACKENDS",
]

BACKENDS = ("xla", "pallas", "sharded")


class SketchEngineState(NamedTuple):
    """Commutative-monoid accumulator of the one-pass sketch statistics."""

    cos_acc: jax.Array  # (m,) f32 — sum_l beta_l cos(w^T y_l), unnormalised
    sin_acc: jax.Array  # (m,) f32 — sum_l beta_l sin(w^T y_l), unnormalised
    weight_sum: jax.Array  # () f32 — sum of weights folded in so far
    lower: jax.Array  # (n,) f32 — running per-coordinate min
    upper: jax.Array  # (n,) f32 — running per-coordinate max
    count: jax.Array  # () f32 — number of points folded in


class QuantizedSketchEngineState(NamedTuple):
    """QCKM twin of :class:`SketchEngineState`: integer code accumulators.

    Same monoid (identity = zeros, merge = elementwise add/min/max), but the
    trig accumulators hold **int32 sums of universal-quantization codes** of
    the dithered phases, so a partial state is 2-4x smaller at minimal
    integer width and exactly split-invariant (codes deterministic per point).  Only unit
    weights are representable — quantized states count points, not masses.
    Capacity: int32 sums hold ``accumulator_capacity(bits)`` points before
    wrapping (~2.1e9 at 1 bit); ``finalize`` checks the folded count.
    """

    qcos_acc: jax.Array  # (m,) i32 — sum_l Q(cos(w^T y_l + xi))
    qsin_acc: jax.Array  # (m,) i32 — sum_l Q(sin(w^T y_l + xi))
    weight_sum: jax.Array  # () f32 — == count (unit weights only)
    lower: jax.Array  # (n,) f32 — running per-coordinate min
    upper: jax.Array  # (n,) f32 — running per-coordinate max
    count: jax.Array  # () f32 — number of points folded in


class DecayedSketchEngineState(NamedTuple):
    """Time-decayed twin of :class:`SketchEngineState`.

    ``cos_acc/sin_acc/weight_sum`` are held *in the units of* ``stamp`` (the
    tick of the newest contribution): merging decays the older operand by
    ``gamma**dt`` first, so at any moment the sums equal
    ``sum_i gamma**(stamp - t_i) * contribution_i``.  ``lower/upper`` stay
    the lifetime envelope and ``count`` the raw folded-point total (bounds
    and counts have no meaningful decay).  ``gamma`` rides the state so the
    merge is self-describing (checkpoints, stacked fleets, vmap).
    """

    cos_acc: jax.Array  # (m,) f32 — decayed sum of beta_l cos(w^T y_l)
    sin_acc: jax.Array  # (m,) f32 — decayed sum of beta_l sin(w^T y_l)
    weight_sum: jax.Array  # () f32 — decayed mass sum_i gamma^dt_i * w_i
    lower: jax.Array  # (n,) f32 — lifetime per-coordinate min
    upper: jax.Array  # (n,) f32 — lifetime per-coordinate max
    count: jax.Array  # () f32 — raw number of points folded (undecayed)
    stamp: jax.Array  # () f32 — tick of the newest fold; -inf = identity
    gamma: jax.Array  # () f32 — decay base per tick (static per engine)


class DecayedQuantizedSketchEngineState(NamedTuple):
    """Decay + quantization: exact int32 codes, decay in a float side-scale.

    An int32 code sum cannot be scaled by ``gamma**dt`` and stay an integer,
    so the decayed quantized state is segmented by stamp: ``qcos/qsin_acc``
    hold the **exact int32 code sums of the newest-stamp segment** (same-tick
    merges add integers — bitwise split-invariant, exactly as the lifetime
    quantized state), while ``dcos/dsin_acc`` carry every older segment as
    float32 code mass with its accumulated decay factors applied.  When a
    merge advances the stamp, the older operand's whole content (ints +
    side-channel) folds into the side-channel through one ``gamma**dt``
    multiply; ``finalize`` dequantizes the sum of both segments (the E[sign]
    correction is linear, so it applies to the combined code mass).
    """

    qcos_acc: jax.Array  # (m,) i32 — exact code sums of the newest segment
    qsin_acc: jax.Array  # (m,) i32
    dcos_acc: jax.Array  # (m,) f32 — decayed older code mass (side-scale)
    dsin_acc: jax.Array  # (m,) f32
    weight_sum: jax.Array  # () f32 — decayed effective count
    lower: jax.Array  # (n,) f32 — lifetime per-coordinate min
    upper: jax.Array  # (n,) f32 — lifetime per-coordinate max
    count: jax.Array  # () f32 — raw number of points folded (undecayed)
    stamp: jax.Array  # () f32 — tick of the newest fold; -inf = identity
    gamma: jax.Array  # () f32 — decay base per tick


DECAYED_STATE_TYPES = (DecayedSketchEngineState, DecayedQuantizedSketchEngineState)


class _EngineInstruments(NamedTuple):
    """Per-engine cached metric handles (resolved once per registry
    generation, so the enabled steady state is plain ``float +=``)."""

    gen: int
    update_calls: object
    update_rows: object
    merge_calls: object
    finalize_calls: object
    state_bytes: object


def _state_nbytes(state) -> int:
    """Bytes of a state's array leaves — what a partial ships on merge."""
    return int(
        sum(
            int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
            for leaf in state
        )
    )


def _decay_factor(gamma, dt):
    """``gamma**dt`` with the identity edge cases pinned.

    ``dt`` can be ``nan`` (both operands are the ``stamp=-inf`` identity:
    ``(-inf) - (-inf)``) or ``inf`` (identity folding into a stamped state);
    both must behave as "no decay of nothing".  The double ``where`` keeps
    ``nan`` out of the power's gradient-free forward value and pins
    ``dt <= 0`` (the newest operand, or identity-identity) to exactly 1.0 so
    same-stamp merges stay bitwise equal to the undecayed merge.
    """
    safe = jnp.where(dt > 0, dt, 0.0)
    return jnp.where(dt > 0, gamma**safe, 1.0)


@jax.jit
def _merge_states(a, b):
    """Merge for either state flavour (dispatch happens at trace time)."""
    if type(a) is not type(b):
        raise TypeError(
            f"cannot merge mismatched state flavours: "
            f"{type(a).__name__} vs {type(b).__name__}"
        )
    if isinstance(a, DecayedSketchEngineState):
        t = jnp.maximum(a.stamp, b.stamp)
        fa = _decay_factor(a.gamma, t - a.stamp)
        fb = _decay_factor(b.gamma, t - b.stamp)
        return DecayedSketchEngineState(
            cos_acc=fa[..., None] * a.cos_acc + fb[..., None] * b.cos_acc,
            sin_acc=fa[..., None] * a.sin_acc + fb[..., None] * b.sin_acc,
            weight_sum=fa * a.weight_sum + fb * b.weight_sum,
            lower=jnp.minimum(a.lower, b.lower),
            upper=jnp.maximum(a.upper, b.upper),
            count=a.count + b.count,
            stamp=t,
            gamma=jnp.maximum(a.gamma, b.gamma),
        )
    if isinstance(a, DecayedQuantizedSketchEngineState):
        t = jnp.maximum(a.stamp, b.stamp)
        fa = _decay_factor(a.gamma, t - a.stamp)
        fb = _decay_factor(b.gamma, t - b.stamp)
        # Segment by stamp: the operand(s) at the new stamp keep their int32
        # codes exact (same-tick merge = integer add, bitwise); an older
        # operand folds *entirely* (ints + side-channel) into the float
        # side-channel through one gamma**dt multiply.
        a_new = a.stamp >= t
        b_new = b.stamp >= t

        def _i(new, q):
            return jnp.where(new[..., None], q, 0)

        def _d(new, f, q, d):
            qf = q.astype(jnp.float32)
            return jnp.where(new[..., None], d, f[..., None] * (d + qf))

        return DecayedQuantizedSketchEngineState(
            qcos_acc=_i(a_new, a.qcos_acc) + _i(b_new, b.qcos_acc),
            qsin_acc=_i(a_new, a.qsin_acc) + _i(b_new, b.qsin_acc),
            dcos_acc=_d(a_new, fa, a.qcos_acc, a.dcos_acc)
            + _d(b_new, fb, b.qcos_acc, b.dcos_acc),
            dsin_acc=_d(a_new, fa, a.qsin_acc, a.dsin_acc)
            + _d(b_new, fb, b.qsin_acc, b.dsin_acc),
            weight_sum=fa * a.weight_sum + fb * b.weight_sum,
            lower=jnp.minimum(a.lower, b.lower),
            upper=jnp.maximum(a.upper, b.upper),
            count=a.count + b.count,
            stamp=t,
            gamma=jnp.maximum(a.gamma, b.gamma),
        )
    if isinstance(a, QuantizedSketchEngineState):
        return QuantizedSketchEngineState(
            qcos_acc=a.qcos_acc + b.qcos_acc,
            qsin_acc=a.qsin_acc + b.qsin_acc,
            weight_sum=a.weight_sum + b.weight_sum,
            lower=jnp.minimum(a.lower, b.lower),
            upper=jnp.maximum(a.upper, b.upper),
            count=a.count + b.count,
        )
    return SketchEngineState(
        cos_acc=a.cos_acc + b.cos_acc,
        sin_acc=a.sin_acc + b.sin_acc,
        weight_sum=a.weight_sum + b.weight_sum,
        lower=jnp.minimum(a.lower, b.lower),
        upper=jnp.maximum(a.upper, b.upper),
        count=a.count + b.count,
    )


@jax.jit
def _finalize_state(state: SketchEngineState):
    # An empty stream (or an all-zero-weight shard) has nothing to average:
    # return the zero sketch rather than accumulator/denom garbage.  The tiny
    # denom floor alone is not enough — cos_acc can be exactly 0 while a
    # negative-weight cancellation leaves weight_sum at -0.0 or ~1e-38.
    denom = jnp.maximum(state.weight_sum, 1e-30)
    z = jnp.concatenate([state.cos_acc, -state.sin_acc]) / denom
    z = jnp.where(state.weight_sum > 0, z, jnp.zeros_like(z))
    return z, state.lower, state.upper


@functools.partial(jax.jit, static_argnames=("bits",))
def _finalize_quantized(state: QuantizedSketchEngineState, dither, bits: int):
    cos_acc, sin_acc = qz.dequantize_sums(
        state.qcos_acc, state.qsin_acc, dither, bits
    )
    denom = jnp.maximum(state.weight_sum, 1e-30)
    z = jnp.concatenate([cos_acc, -sin_acc]) / denom
    # Same zero-weight guard as the float path: an empty quantized stream
    # must finalize to the zero sketch, never to code-sum / denom garbage.
    z = jnp.where(state.weight_sum > 0, z, jnp.zeros_like(z))
    return z, state.lower, state.upper


@functools.partial(jax.jit, static_argnames=("bits",))
def _finalize_decayed_quantized(
    state: DecayedQuantizedSketchEngineState, dither, bits: int
):
    # The dequantization correction is linear in the code sums, so it applies
    # to the combined (exact int newest segment + decayed float older mass)
    # code total directly.  With an empty side-channel this is bitwise equal
    # to ``_finalize_quantized``: ``q.astype(f32) + 0.0`` and the int path's
    # internal ``astype(f32)`` produce the same float.
    cos_acc, sin_acc = qz.dequantize_sums(
        state.qcos_acc.astype(jnp.float32) + state.dcos_acc,
        state.qsin_acc.astype(jnp.float32) + state.dsin_acc,
        dither,
        bits,
    )
    denom = jnp.maximum(state.weight_sum, 1e-30)
    z = jnp.concatenate([cos_acc, -sin_acc]) / denom
    z = jnp.where(state.weight_sum > 0, z, jnp.zeros_like(z))
    return z, state.lower, state.upper


class SketchEngine:
    """Streaming/mergeable sketch computation over pluggable backends.

    Parameters
    ----------
    w : the frequency operator — a ``core.freq_ops.FrequencyOperator``
        (``freq_ops.make_operator("dense" | "structured", ...)``); a raw
        ``(n, m)`` matrix is also accepted here for convenience (wrapped in a
        spec-less dense operator).  The engine carries the operator's O(m)
        leaves (dense: the matrix; structured: signs + radii) and exposes
        ``spec()`` so checkpoints/broadcast can carry the O(1) rebuild recipe
        instead of any materialised state.
    backend : one of ``BACKENDS`` — see the backend matrix in the module doc.
    chunk : scan chunk for the xla/sharded backends.
    block_n, block_m : Pallas tile sizes (pallas backend).
    interpret : force Pallas interpret mode (None = auto: interpret off-TPU).
    mesh, data_axes : device mesh + data axes (sharded backend only).  Batches
        passed to ``update`` must be shardable along their leading axis.
    quantizer : optional ``core.quantize.SketchQuantizer`` — switches the
        engine to the quantized state transform (int32 code accumulators,
        unit weights only; see the module doc's "State transforms").
    reduce_topology : merge schedule for the sharded backend's cross-device
        combine and for :meth:`reduce_partials` — any name registered in
        ``core.topology`` (``"allreduce"`` | ``"tree"`` | ``"ring"``).  The
        monoid laws make every schedule produce the same sketch (bitwise on
        the quantized path); the choice trades wire bytes against hop count
        (``core.topology.wire_cost_model``, ``docs/scaling.md``).
    decay : optional per-tick exponential decay base ``gamma`` in (0, 1].
        Switches the engine to the time-decayed state transform: states gain
        a ``stamp`` (tick of the newest contribution), ``update`` accepts a
        keyword ``t``, and merging scales the older operand's
        ``cos_acc/sin_acc/weight_sum`` by ``gamma**dt`` first, so the sketch
        is always an exponentially weighted average favouring recent data.
        ``decay=1.0`` keeps timestamps but decays nothing.  Composes with
        every backend and with ``quantizer`` (see "State transforms").
    """

    def __init__(
        self,
        w: jax.Array,
        backend: str = "xla",
        *,
        chunk: int = 8192,
        block_n: int = 1024,
        block_m: int = 512,
        interpret: bool | None = None,
        mesh: Mesh | None = None,
        data_axes: Sequence[str] = ("data",),
        quantizer: qz.SketchQuantizer | None = None,
        reduce_topology: str = "allreduce",
        decay: float | None = None,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        if backend == "sharded" and mesh is None:
            raise ValueError("backend='sharded' requires a mesh")
        if decay is not None and not 0.0 < float(decay) <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay!r}")
        topo.get_topology(reduce_topology)  # fail fast on unknown names
        self.freq_op = fo.as_operator(w)
        self.n, self.m = self.freq_op.n, self.freq_op.m
        self.backend = backend
        self.chunk = chunk
        self.block_n = block_n
        self.block_m = block_m
        self.interpret = interpret
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.reduce_topology = reduce_topology
        if quantizer is not None and quantizer.dither.shape != (self.m,):
            raise ValueError(
                f"quantizer dither shape {quantizer.dither.shape} != (m,)="
                f"{(self.m,)}"
            )
        self.quantizer = quantizer
        self.decay = None if decay is None else float(decay)
        self._obs_h: _EngineInstruments | None = None

    def _obs(self) -> _EngineInstruments:
        """Resolve (or re-resolve after a registry reset) the engine's
        cached instrument handles.  Only reached when telemetry is on."""
        from repro.obs import metrics as obs_metrics

        h = self._obs_h
        gen = obs_metrics.REGISTRY.generation
        if h is None or h.gen != gen:
            bits = (
                str(self.quantizer.bits) if self.quantizer is not None else "none"
            )
            labels = dict(backend=self.backend, bits=bits)
            h = self._obs_h = _EngineInstruments(
                gen=gen,
                update_calls=obs_metrics.counter("engine.update.calls", **labels),
                update_rows=obs_metrics.counter("engine.update.rows", **labels),
                merge_calls=obs_metrics.counter("engine.merge.calls", **labels),
                finalize_calls=obs_metrics.counter(
                    "engine.finalize.calls", **labels
                ),
                state_bytes=obs_metrics.gauge("engine.state.bytes", **labels),
            )
        return h

    @property
    def w(self) -> jax.Array:
        """Materialised ``(n, m)`` frequency matrix (back-compat; on demand —
        the engine itself never carries it for non-dense operators)."""
        return self.freq_op.materialize()

    def spec(self) -> fo.FreqOpSpec:
        """The operator's O(1) rebuild recipe (``core.freq_ops.FreqOpSpec``)
        — what checkpoints and cross-host broadcast should carry instead of
        the O(n·m) matrix; raises for shim-wrapped raw matrices."""
        return self.freq_op.spec()

    # -- monoid ops ---------------------------------------------------------

    def init_state(self) -> SketchEngineState | QuantizedSketchEngineState:
        """The monoid identity: merge(init_state(), s) == s for any s."""
        if self.decay is not None:
            stamp = jnp.full((), -jnp.inf, jnp.float32)
            gamma = jnp.full((), self.decay, jnp.float32)
            if self.quantizer is not None:
                return DecayedQuantizedSketchEngineState(
                    qcos_acc=jnp.zeros((self.m,), jnp.int32),
                    qsin_acc=jnp.zeros((self.m,), jnp.int32),
                    dcos_acc=jnp.zeros((self.m,), jnp.float32),
                    dsin_acc=jnp.zeros((self.m,), jnp.float32),
                    weight_sum=jnp.zeros((), jnp.float32),
                    lower=jnp.full((self.n,), jnp.inf, jnp.float32),
                    upper=jnp.full((self.n,), -jnp.inf, jnp.float32),
                    count=jnp.zeros((), jnp.float32),
                    stamp=stamp,
                    gamma=gamma,
                )
            return DecayedSketchEngineState(
                cos_acc=jnp.zeros((self.m,), jnp.float32),
                sin_acc=jnp.zeros((self.m,), jnp.float32),
                weight_sum=jnp.zeros((), jnp.float32),
                lower=jnp.full((self.n,), jnp.inf, jnp.float32),
                upper=jnp.full((self.n,), -jnp.inf, jnp.float32),
                count=jnp.zeros((), jnp.float32),
                stamp=stamp,
                gamma=gamma,
            )
        if self.quantizer is not None:
            return QuantizedSketchEngineState(
                qcos_acc=jnp.zeros((self.m,), jnp.int32),
                qsin_acc=jnp.zeros((self.m,), jnp.int32),
                weight_sum=jnp.zeros((), jnp.float32),
                lower=jnp.full((self.n,), jnp.inf, jnp.float32),
                upper=jnp.full((self.n,), -jnp.inf, jnp.float32),
                count=jnp.zeros((), jnp.float32),
            )
        return SketchEngineState(
            cos_acc=jnp.zeros((self.m,), jnp.float32),
            sin_acc=jnp.zeros((self.m,), jnp.float32),
            weight_sum=jnp.zeros((), jnp.float32),
            lower=jnp.full((self.n,), jnp.inf, jnp.float32),
            upper=jnp.full((self.n,), -jnp.inf, jnp.float32),
            count=jnp.zeros((), jnp.float32),
        )

    def _lift_partial(self, part, t):
        """Wrap a base (undecayed) batch partial as a decayed state at tick
        ``t`` — the bridge between the backend batch kernels (which know
        nothing about time) and the timestamped merge."""
        stamp = jnp.asarray(t, jnp.float32)
        gamma = jnp.full(jnp.shape(stamp), self.decay, jnp.float32)
        if isinstance(part, QuantizedSketchEngineState):
            return DecayedQuantizedSketchEngineState(
                qcos_acc=part.qcos_acc,
                qsin_acc=part.qsin_acc,
                dcos_acc=jnp.zeros_like(part.qcos_acc, jnp.float32),
                dsin_acc=jnp.zeros_like(part.qsin_acc, jnp.float32),
                weight_sum=part.weight_sum,
                lower=part.lower,
                upper=part.upper,
                count=part.count,
                stamp=stamp,
                gamma=gamma,
            )
        return DecayedSketchEngineState(
            cos_acc=part.cos_acc,
            sin_acc=part.sin_acc,
            weight_sum=part.weight_sum,
            lower=part.lower,
            upper=part.upper,
            count=part.count,
            stamp=stamp,
            gamma=gamma,
        )

    def _partial_state(self, batch: jax.Array, weights: jax.Array | None):
        """One batch -> one partial state (the pre-merge half of update)."""
        x = jnp.asarray(batch, jnp.float32)
        b = x.shape[0]
        if self.quantizer is not None:
            if weights is not None:
                raise ValueError(
                    "quantized sketch states accumulate unit-weight integer "
                    "counts; per-point weights are not representable"
                )
            return self._quantized_batch_state(x)
        if weights is None:
            weights = jnp.ones((b,), jnp.float32)
        else:
            weights = jnp.asarray(weights, jnp.float32)
        return self._batch_state(x, weights)

    def update(
        self,
        state,
        batch: jax.Array,
        weights: jax.Array | None = None,
        *,
        t: float | jax.Array | None = None,
    ):
        """Fold ``batch: (B, n)`` into ``state``.  ``weights`` default to 1
        per point, so streaming batches of any size weight points equally.
        The quantized state transform only represents unit weights (integer
        code counts) and rejects explicit ``weights``.

        Under ``decay``, ``t`` is the batch's tick: older state content is
        scaled by ``gamma**(t - state.stamp)`` as it merges.  ``t=None``
        reuses the state's current stamp (fold with no time advance — the
        empty state resolves to tick 0).  Passing ``t`` without ``decay``
        is an error.
        """
        if t is not None and self.decay is None:
            raise ValueError(
                "update(t=...) requires a decay-enabled engine "
                "(SketchEngine(decay=gamma))"
            )
        if not obs_rt.ENABLED:
            part = self._partial_state(batch, weights)
            if self.decay is not None:
                part = self._lift_partial(part, self._resolve_t(state, t))
            return _merge_states(state, part)
        from repro.obs import trace as obs_trace

        h = self._obs()
        with obs_trace.span("engine.update", backend=self.backend):
            part = self._partial_state(batch, weights)
            if self.decay is not None:
                part = self._lift_partial(part, self._resolve_t(state, t))
            with obs_trace.span("engine.merge", backend=self.backend):
                out = _merge_states(state, part)
        h.update_calls.inc()
        h.update_rows.inc(float(np.shape(batch)[0]))
        h.merge_calls.inc()
        h.state_bytes.set(_state_nbytes(out))
        return out

    @staticmethod
    def _resolve_t(state, t):
        """``t=None`` -> the state's own stamp (no time advance), with the
        identity's ``-inf`` stamp resolving to tick 0.  A partial must never
        carry ``-inf`` itself: a non-empty contribution stamped -inf would be
        decayed to nothing by any later merge."""
        if t is not None:
            return t
        return jnp.where(jnp.isfinite(state.stamp), state.stamp, 0.0)

    def decay_to(self, state, t: float | jax.Array):
        """Advance a decayed state's clock to tick ``t`` without folding data:
        ``cos_acc/sin_acc/weight_sum`` scale by ``gamma**(t - stamp)``.

        Expressed inside the merge algebra — merging with an empty state
        stamped ``t`` — so it commutes with every other monoid op.  A ``t``
        at or before the current stamp is a bitwise no-op (states never move
        backwards in time).
        """
        if self.decay is None:
            raise ValueError(
                "decay_to requires a decay-enabled engine "
                "(SketchEngine(decay=gamma))"
            )
        empty = self.init_state()
        stamp = jnp.broadcast_to(
            jnp.asarray(t, jnp.float32), jnp.shape(empty.stamp)
        )
        return _merge_states(state, empty._replace(stamp=stamp))

    def merge(self, a, b):
        """Associative + commutative combine of two partial states."""
        if not obs_rt.ENABLED:
            return _merge_states(a, b)
        from repro.obs import trace as obs_trace

        h = self._obs()
        with obs_trace.span("engine.merge", backend=self.backend):
            out = _merge_states(a, b)
        h.merge_calls.inc()
        return out

    def reduce_partials(self, states, topology: str | None = None):
        """Reduce many partial states through a named merge schedule.

        Host-level counterpart of the sharded backend's in-mesh collective:
        partials built anywhere (other hosts, edge sketchers, delayed
        stragglers) are folded with ``merge`` following the engine's
        ``reduce_topology`` (or an override).  Any schedule and any arrival
        order give the same state — bitwise for quantized int32 partials.
        """
        return topo.reduce_states(
            self.merge, states, topology or self.reduce_topology
        )

    def finalize(self, state):
        """-> ``(z stacked-real (2m,), lower (n,), upper (n,))``.

        Quantized states are dequantized here (E[sign] correction + dither
        rotation, ``core.quantize.dequantize_sums``) so every consumer sees
        the same float-sketch contract regardless of the state transform.
        """
        if not obs_rt.ENABLED:
            return self._finalize_impl(state)
        from repro.obs import trace as obs_trace

        h = self._obs()
        with obs_trace.span("engine.finalize", backend=self.backend):
            out = self._finalize_impl(state)
        h.finalize_calls.inc()
        return out

    def _finalize_impl(self, state):
        if self.quantizer is not None:
            # int32 code sums wrap silently once count * scale exceeds the
            # int32 range — detect post-hoc from the (non-wrapping) f32 count
            # rather than garbage-decode.  Skipped under tracing.
            cap = qz.accumulator_capacity(self.quantizer.bits)
            if not isinstance(state.count, jax.core.Tracer) and float(
                state.count
            ) > cap:
                raise ValueError(
                    f"quantized accumulators overflow: {float(state.count):.0f} "
                    f"points folded at {self.quantizer.bits} bits exceeds the "
                    f"int32 capacity of {cap} points "
                    "(core.quantize.accumulator_capacity)"
                )
            if isinstance(state, DecayedQuantizedSketchEngineState):
                return _finalize_decayed_quantized(
                    state, self.quantizer.dither, self.quantizer.bits
                )
            return _finalize_quantized(
                state, self.quantizer.dither, self.quantizer.bits
            )
        # ``_finalize_state`` duck-types over the float flavours — the decayed
        # state has the same accumulator fields (jit retraces per pytree).
        return _finalize_state(state)

    # -- conveniences -------------------------------------------------------

    def sketch(self, x: jax.Array, weights: jax.Array | None = None):
        """One-shot ``(z, lower, upper)`` — init/update/finalize in one call."""
        return self.finalize(self.update(self.init_state(), x, weights))

    def sketch_stream(
        self,
        batches: Iterable[jax.Array],
        *,
        async_ingest: bool = False,
        prefetch: int = 2,
    ):
        """One pass over an iterator of ``(B_i, n)`` batches -> (z, lo, hi).

        ``async_ingest=True`` routes the pass through
        ``core.ingest.ingest_stream``: a producer thread keeps ``prefetch``
        batches staged on device so batch production overlaps sketch compute.
        Same batches, same order, identical result.
        """
        if async_ingest:
            from repro.core import ingest as ingest_mod

            state, _ = ingest_mod.ingest_stream(self, batches, prefetch=prefetch)
            return self.finalize(state)
        state = self.init_state()
        for batch in batches:
            state = self.update(state, batch)
        return self.finalize(state)

    # -- backend dispatch ---------------------------------------------------

    def _check_vma(self) -> bool | None:
        """Replication-checker setting for the sharded backend's shard_map.

        tree/ring reductions return ppermute-derived values the VMA checker
        cannot see as replicated (they are — exactly for integers, to
        association-order ulps for floats), so newer-JAX checking must be
        off for them; the default allreduce (psum) keeps the checker at its
        default as a safety net for future body edits.
        """
        return False if self.reduce_topology != "allreduce" else None

    def _batch_state(self, x: jax.Array, weights: jax.Array) -> SketchEngineState:
        if self.backend == "sharded":
            return self._sharded_batch_state(x, weights)
        if self.backend == "pallas":
            from repro.kernels import ops

            cos_s, sin_s = ops.fourier_sketch_sums(
                x,
                self.freq_op,
                weights,
                block_n=self.block_n,
                block_m=self.block_m,
                interpret=self.interpret,
            )
        else:  # xla
            part = sk.sketch(
                x,
                self.freq_op,
                weights=weights,
                chunk=min(self.chunk, max(x.shape[0], 1)),
            )
            cos_s, sin_s = part[: self.m], -part[self.m :]
        return SketchEngineState(
            cos_acc=cos_s,
            sin_acc=sin_s,
            weight_sum=jnp.sum(weights),
            lower=jnp.min(x, axis=0),
            upper=jnp.max(x, axis=0),
            count=jnp.asarray(x.shape[0], jnp.float32),
        )

    def _quantized_batch_state(self, x: jax.Array) -> QuantizedSketchEngineState:
        q = self.quantizer
        if self.backend == "sharded":
            return self._sharded_quantized_batch_state(x)
        if self.backend == "pallas":
            from repro.kernels import ops

            qcos, qsin = ops.quantized_fourier_sketch_sums(
                x,
                self.freq_op,
                q.dither,
                bits=q.bits,
                block_n=self.block_n,
                block_m=self.block_m,
                interpret=self.interpret,
            )
        else:  # xla
            qcos, qsin = sk.sketch_quantized(
                x,
                self.freq_op,
                q.dither,
                bits=q.bits,
                chunk=min(self.chunk, max(x.shape[0], 1)),
            )
        n_pts = jnp.asarray(x.shape[0], jnp.float32)
        return QuantizedSketchEngineState(
            qcos_acc=qcos,
            qsin_acc=qsin,
            weight_sum=n_pts,
            lower=jnp.min(x, axis=0),
            upper=jnp.max(x, axis=0),
            count=n_pts,
        )

    def _sharded_quantized_batch_state(self, x: jax.Array) -> QuantizedSketchEngineState:
        """Bandwidth-aware sharded path: psum **integer** accumulators.

        Same ragged-batch strategy as the float path (pad with copies of the
        first row, masked out), but the cross-device merge moves int32 code
        sums instead of float sketches — the O(m) traffic the quantized
        subsystem exists to shrink.
        """
        q = self.quantizer
        axes = self.data_axes
        chunk = self.chunk
        topology = self.reduce_topology
        b = x.shape[0]
        pad = (-b) % axis_extent(self.mesh, axes)
        valid = jnp.ones((b,), jnp.float32)
        if pad:
            x = jnp.concatenate(
                [x, jnp.broadcast_to(x[:1], (pad, x.shape[1]))], axis=0
            )
            valid = jnp.concatenate([valid, jnp.zeros((pad,), jnp.float32)], axis=0)

        def local(x_shard, op_rep, dither_rep, valid_shard):
            qcos, qsin = sk.sketch_quantized(
                x_shard,
                op_rep,
                dither_rep,
                valid=valid_shard,
                bits=q.bits,
                chunk=min(chunk, max(x_shard.shape[0], 1)),
                vary_axes=axes,
            )
            # Cross-device merge of the int32 code sums through the selected
            # topology — the engine's monoid `merge` expressed as a
            # collective schedule (bitwise identical for every topology).
            qcos = topo.axis_reduce(qcos, axes, topology)
            qsin = topo.axis_reduce(qsin, axes, topology)
            cnt = topo.axis_reduce(jnp.sum(valid_shard), axes, topology)
            lo = topo.axis_reduce(jnp.min(x_shard, axis=0), axes, topology, op="min")
            hi = topo.axis_reduce(jnp.max(x_shard, axis=0), axes, topology, op="max")
            return qcos, qsin, cnt, lo, hi

        # The operator rides shard_map as a replicated pytree: its leaves are
        # what the broadcast ships — O(m) signs/radii for the structured
        # family instead of the O(n·m) dense matrix.
        fn = compat.shard_map(
            local,
            mesh=self.mesh,
            in_specs=(P(axes), P(), P(), P(axes)),
            out_specs=(P(), P(), P(), P(), P()),
            check_vma=self._check_vma(),
        )
        qcos, qsin, cnt, lo, hi = fn(x, self.freq_op, q.dither, valid)
        return QuantizedSketchEngineState(
            qcos, qsin, cnt, lo, hi, jnp.asarray(b, jnp.float32)
        )

    def _sharded_batch_state(self, x: jax.Array, weights: jax.Array) -> SketchEngineState:
        axes = self.data_axes
        chunk = self.chunk
        topology = self.reduce_topology
        b = x.shape[0]
        # shard_map needs the leading axis divisible by the data-axis extent;
        # streaming batches (ragged tail chunks) generally aren't.  Pad with
        # zero-weight copies of the first row: weight 0 keeps the sums exact
        # and a copied point cannot move the min/max bounds.  True count is
        # taken from the unpadded batch below.
        pad = (-b) % axis_extent(self.mesh, axes)
        if pad:
            x = jnp.concatenate(
                [x, jnp.broadcast_to(x[:1], (pad, x.shape[1]))], axis=0
            )
            weights = jnp.concatenate(
                [weights, jnp.zeros((pad,), jnp.float32)], axis=0
            )

        def local(x_shard, op_rep, wt_shard):
            part = sk.sketch(
                x_shard,
                op_rep,
                weights=wt_shard,
                chunk=min(chunk, max(x_shard.shape[0], 1)),
                vary_axes=axes,
            )
            m = op_rep.m
            cos_s = topo.axis_reduce(part[:m], axes, topology)
            sin_s = topo.axis_reduce(-part[m:], axes, topology)
            wsum = topo.axis_reduce(jnp.sum(wt_shard), axes, topology)
            lo = topo.axis_reduce(jnp.min(x_shard, axis=0), axes, topology, op="min")
            hi = topo.axis_reduce(jnp.max(x_shard, axis=0), axes, topology, op="max")
            return cos_s, sin_s, wsum, lo, hi

        fn = compat.shard_map(
            local,
            mesh=self.mesh,
            in_specs=(P(axes), P(), P(axes)),
            out_specs=(P(), P(), P(), P(), P()),
            check_vma=self._check_vma(),
        )
        cos_s, sin_s, wsum, lo, hi = fn(x, self.freq_op, weights)
        return SketchEngineState(
            cos_s, sin_s, wsum, lo, hi, jnp.asarray(b, jnp.float32)
        )

    def shard_points(self, x: jax.Array) -> jax.Array:
        """Place ``x`` with its leading axis sharded over the data axes.

        When N is not divisible by the data-axis extent the array is left
        where it is — ``update`` zero-weight pads and reshards internally,
        so placement here is a locality optimisation, not a requirement.
        """
        assert self.mesh is not None
        from jax.sharding import NamedSharding

        if x.shape[0] % axis_extent(self.mesh, self.data_axes):
            return x
        return jax.device_put(x, NamedSharding(self.mesh, P(self.data_axes)))
