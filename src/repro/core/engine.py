"""Unified streaming SketchEngine — one mergeable-sketch API, three backends.

The paper's central object is the sketch ``z = Sk(X, 1/N)``: a one-pass,
*linear* summary of the empirical distribution.  Linearity makes the partial
sums a **commutative monoid**: any way of splitting the data over batches,
devices, or hosts and any order of combining the partials yields the same
sketch.  This module is the single implementation of that contract; every
producer (in-memory, streaming, distributed) and every consumer (CLOMPR,
monitors, the data balancer) goes through it.

Mergeable-state contract
------------------------
``SketchEngineState(cos_acc, sin_acc, weight_sum, lower, upper, count)`` with

- identity:      ``init_state()`` (zero sums, ``+inf/-inf`` bounds),
- ``update``:    fold one weighted batch into a state (one pass, O(m) memory),
- ``merge``:     elementwise combine — **associative and commutative**, so
                 states may be combined across batches/devices/hosts in any
                 order (tree reductions, psum, delayed stragglers all legal),
- ``finalize``:  normalise to the paper's sketch:  ``z = sums / weight_sum``
                 (stacked-real ``[sum b cos, -sum b sin] / sum b``), plus the
                 CLOMPR box bounds ``(lower, upper)`` harvested in the same
                 pass.

Backend matrix
--------------
=========  ==================================================================
backend    update path
=========  ==================================================================
xla        ``core.sketch.sketch`` — chunked ``lax.scan``; the (N, m)
           projection never materialises.  Runs everywhere; the default.
pallas     ``kernels.ops.fourier_sketch_sums`` — fused MXU+VPU TPU kernel
           (projection tile stays in VMEM).  Inputs are auto-padded to tile
           alignment (N→block_n, n→8, m→block_m); off-TPU the kernel body
           runs in ``interpret=True`` mode for correctness.
sharded    ``shard_map`` over a device mesh: every device sketches its local
           shard, one ``psum/pmin/pmax`` merges — O(m) cross-device traffic,
           independent of N.  Requires ``mesh=``; uses the version-compat
           shim in ``utils.compat`` (old and new ``shard_map`` APIs).
=========  ==================================================================

All three backends produce identical sketches (within float tolerance) — the
tier-1 suite asserts pairwise parity at 1e-4 on CPU.
"""

from __future__ import annotations

import functools
from typing import Iterable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import sketch as sk
from repro.utils import compat

__all__ = ["SketchEngineState", "SketchEngine", "BACKENDS"]

BACKENDS = ("xla", "pallas", "sharded")


class SketchEngineState(NamedTuple):
    """Commutative-monoid accumulator of the one-pass sketch statistics."""

    cos_acc: jax.Array  # (m,) f32 — sum_l beta_l cos(w^T y_l), unnormalised
    sin_acc: jax.Array  # (m,) f32 — sum_l beta_l sin(w^T y_l), unnormalised
    weight_sum: jax.Array  # () f32 — sum of weights folded in so far
    lower: jax.Array  # (n,) f32 — running per-coordinate min
    upper: jax.Array  # (n,) f32 — running per-coordinate max
    count: jax.Array  # () f32 — number of points folded in


@jax.jit
def _merge_states(a: SketchEngineState, b: SketchEngineState) -> SketchEngineState:
    return SketchEngineState(
        cos_acc=a.cos_acc + b.cos_acc,
        sin_acc=a.sin_acc + b.sin_acc,
        weight_sum=a.weight_sum + b.weight_sum,
        lower=jnp.minimum(a.lower, b.lower),
        upper=jnp.maximum(a.upper, b.upper),
        count=a.count + b.count,
    )


@jax.jit
def _finalize_state(state: SketchEngineState):
    denom = jnp.maximum(state.weight_sum, 1e-30)
    z = jnp.concatenate([state.cos_acc, -state.sin_acc]) / denom
    return z, state.lower, state.upper


class SketchEngine:
    """Streaming/mergeable sketch computation over pluggable backends.

    Parameters
    ----------
    w : (n, m) frequency matrix (``core.frequencies.draw_frequencies``).
    backend : one of ``BACKENDS`` — see the backend matrix in the module doc.
    chunk : scan chunk for the xla/sharded backends.
    block_n, block_m : Pallas tile sizes (pallas backend).
    interpret : force Pallas interpret mode (None = auto: interpret off-TPU).
    mesh, data_axes : device mesh + data axes (sharded backend only).  Batches
        passed to ``update`` must be shardable along their leading axis.
    """

    def __init__(
        self,
        w: jax.Array,
        backend: str = "xla",
        *,
        chunk: int = 8192,
        block_n: int = 1024,
        block_m: int = 512,
        interpret: bool | None = None,
        mesh: Mesh | None = None,
        data_axes: Sequence[str] = ("data",),
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        if backend == "sharded" and mesh is None:
            raise ValueError("backend='sharded' requires a mesh")
        self.w = jnp.asarray(w, jnp.float32)
        self.n, self.m = self.w.shape
        self.backend = backend
        self.chunk = chunk
        self.block_n = block_n
        self.block_m = block_m
        self.interpret = interpret
        self.mesh = mesh
        self.data_axes = tuple(data_axes)

    # -- monoid ops ---------------------------------------------------------

    def init_state(self) -> SketchEngineState:
        """The monoid identity: merge(init_state(), s) == s for any s."""
        return SketchEngineState(
            cos_acc=jnp.zeros((self.m,), jnp.float32),
            sin_acc=jnp.zeros((self.m,), jnp.float32),
            weight_sum=jnp.zeros((), jnp.float32),
            lower=jnp.full((self.n,), jnp.inf, jnp.float32),
            upper=jnp.full((self.n,), -jnp.inf, jnp.float32),
            count=jnp.zeros((), jnp.float32),
        )

    def update(
        self,
        state: SketchEngineState,
        batch: jax.Array,
        weights: jax.Array | None = None,
    ) -> SketchEngineState:
        """Fold ``batch: (B, n)`` into ``state``.  ``weights`` default to 1
        per point, so streaming batches of any size weight points equally."""
        x = jnp.asarray(batch, jnp.float32)
        b = x.shape[0]
        if weights is None:
            weights = jnp.ones((b,), jnp.float32)
        else:
            weights = jnp.asarray(weights, jnp.float32)
        part = self._batch_state(x, weights)
        return _merge_states(state, part)

    def merge(self, a: SketchEngineState, b: SketchEngineState) -> SketchEngineState:
        """Associative + commutative combine of two partial states."""
        return _merge_states(a, b)

    def finalize(self, state: SketchEngineState):
        """-> ``(z stacked-real (2m,), lower (n,), upper (n,))``."""
        return _finalize_state(state)

    # -- conveniences -------------------------------------------------------

    def sketch(self, x: jax.Array, weights: jax.Array | None = None):
        """One-shot ``(z, lower, upper)`` — init/update/finalize in one call."""
        return self.finalize(self.update(self.init_state(), x, weights))

    def sketch_stream(self, batches: Iterable[jax.Array]):
        """One pass over an iterator of ``(B_i, n)`` batches -> (z, lo, hi)."""
        state = self.init_state()
        for batch in batches:
            state = self.update(state, batch)
        return self.finalize(state)

    # -- backend dispatch ---------------------------------------------------

    def _batch_state(self, x: jax.Array, weights: jax.Array) -> SketchEngineState:
        if self.backend == "sharded":
            return self._sharded_batch_state(x, weights)
        if self.backend == "pallas":
            from repro.kernels import ops

            cos_s, sin_s = ops.fourier_sketch_sums(
                x,
                self.w,
                weights,
                block_n=self.block_n,
                block_m=self.block_m,
                interpret=self.interpret,
            )
        else:  # xla
            part = sk.sketch(
                x, self.w, weights=weights, chunk=min(self.chunk, max(x.shape[0], 1))
            )
            cos_s, sin_s = part[: self.m], -part[self.m :]
        return SketchEngineState(
            cos_acc=cos_s,
            sin_acc=sin_s,
            weight_sum=jnp.sum(weights),
            lower=jnp.min(x, axis=0),
            upper=jnp.max(x, axis=0),
            count=jnp.asarray(x.shape[0], jnp.float32),
        )

    def _sharded_batch_state(self, x: jax.Array, weights: jax.Array) -> SketchEngineState:
        axes = self.data_axes
        chunk = self.chunk
        b = x.shape[0]
        # shard_map needs the leading axis divisible by the data-axis extent;
        # streaming batches (ragged tail chunks) generally aren't.  Pad with
        # zero-weight copies of the first row: weight 0 keeps the sums exact
        # and a copied point cannot move the min/max bounds.  True count is
        # taken from the unpadded batch below.
        extent = 1
        for a in axes:
            extent *= self.mesh.shape[a]
        pad = (-b) % extent
        if pad:
            x = jnp.concatenate(
                [x, jnp.broadcast_to(x[:1], (pad, x.shape[1]))], axis=0
            )
            weights = jnp.concatenate(
                [weights, jnp.zeros((pad,), jnp.float32)], axis=0
            )

        def local(x_shard, w_rep, wt_shard):
            part = sk.sketch(
                x_shard,
                w_rep,
                weights=wt_shard,
                chunk=min(chunk, max(x_shard.shape[0], 1)),
                vary_axes=axes,
            )
            m = w_rep.shape[1]
            cos_s = jax.lax.psum(part[:m], axes)
            sin_s = jax.lax.psum(-part[m:], axes)
            wsum = jax.lax.psum(jnp.sum(wt_shard), axes)
            lo = jax.lax.pmin(jnp.min(x_shard, axis=0), axes)
            hi = jax.lax.pmax(jnp.max(x_shard, axis=0), axes)
            return cos_s, sin_s, wsum, lo, hi

        fn = compat.shard_map(
            local,
            mesh=self.mesh,
            in_specs=(P(axes), P(), P(axes)),
            out_specs=(P(), P(), P(), P(), P()),
        )
        cos_s, sin_s, wsum, lo, hi = fn(x, self.w, weights)
        return SketchEngineState(
            cos_s, sin_s, wsum, lo, hi, jnp.asarray(b, jnp.float32)
        )

    def shard_points(self, x: jax.Array) -> jax.Array:
        """Place ``x`` with its leading axis sharded over the data axes.

        When N is not divisible by the data-axis extent the array is left
        where it is — ``update`` zero-weight pads and reshards internally,
        so placement here is a locality optimisation, not a requirement.
        """
        assert self.mesh is not None
        from jax.sharding import NamedSharding

        extent = 1
        for a in self.data_axes:
            extent *= self.mesh.shape[a]
        if x.shape[0] % extent:
            return x
        return jax.device_put(x, NamedSharding(self.mesh, P(self.data_axes)))
