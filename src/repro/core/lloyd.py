"""Lloyd-Max K-means + k-means++ — the paper's baseline, in JAX.

Matches Matlab's ``kmeans`` semantics closely enough for the paper's
comparisons: random ("range"/"sample") or k-means++ seeding, Lloyd iterations
to convergence (fixed max iteration budget + movement tolerance), empty
clusters keep their previous centroid.  Replicates are ``vmap``-ed over keys
and selected by SSE — which the baseline *can* evaluate, unlike CKM.

A ``shard_map`` distributed variant lives in ``core.distributed_sketch`` /
``data.clustering``; the assignment hot loop has a fused Pallas kernel in
``kernels/assign_argmin.py`` (used on TPU; jnp fallback here).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LloydConfig:
    k: int
    max_iters: int = 100
    tol: float = 1e-4
    init: str = "range"  # "range" | "sample" | "kpp"
    replicates: int = 1
    use_kernel: bool = False  # fused Pallas assignment (interpret mode on CPU)


class LloydResult(NamedTuple):
    centroids: jax.Array
    sse: jax.Array
    iters: jax.Array


def _init_centroids(key, x, lo, hi, cfg: LloydConfig):
    n_pts, n = x.shape
    if cfg.init == "range":
        return jax.random.uniform(key, (cfg.k, n), minval=lo, maxval=hi)
    if cfg.init == "sample":
        idx = jax.random.choice(key, n_pts, (cfg.k,), replace=False)
        return x[idx]
    # k-means++ (D^2 seeding), exactly [9].
    k0, key = jax.random.split(key)
    first = x[jax.random.randint(k0, (), 0, n_pts)]
    cents = jnp.zeros((cfg.k, n), x.dtype).at[0].set(first)
    d2 = jnp.sum((x - first) ** 2, axis=1)

    def body(i, carry):
        cents, d2, key = carry
        key, kc = jax.random.split(key)
        idx = jax.random.categorical(kc, jnp.log(jnp.maximum(d2, 1e-30)))
        c = x[idx]
        cents = cents.at[i].set(c)
        d2 = jnp.minimum(d2, jnp.sum((x - c) ** 2, axis=1))
        return cents, d2, key

    cents, _, _ = jax.lax.fori_loop(1, cfg.k, body, (cents, d2, key))
    return cents


def _assign(x, cents):
    """Nearest-centroid assignment (jnp fallback of the Pallas kernel)."""
    d2 = (
        jnp.sum(x * x, axis=1, keepdims=True)
        - 2.0 * x @ cents.T
        + jnp.sum(cents * cents, axis=1)[None, :]
    )
    return jnp.argmin(d2, axis=1), jnp.min(d2, axis=1)


@functools.partial(jax.jit, static_argnames=("cfg",))
def lloyd(key: jax.Array, x: jax.Array, cfg: LloydConfig) -> LloydResult:
    """One replicate of Lloyd-Max (``kmeans`` in the paper's figures)."""
    x = jnp.asarray(x, jnp.float32)
    lo = jnp.min(x, axis=0)
    hi = jnp.max(x, axis=0)
    cents0 = _init_centroids(key, x, lo, hi, cfg)

    def cond(carry):
        _, it, moved = carry
        return jnp.logical_and(it < cfg.max_iters, moved > cfg.tol)

    if cfg.use_kernel:
        from repro.kernels import ops as kops

        assign_fn = kops.assign_argmin
    else:
        assign_fn = _assign

    def body(carry):
        cents, it, _ = carry
        assign, _ = assign_fn(x, cents)
        one_hot = jax.nn.one_hot(assign, cfg.k, dtype=x.dtype)  # (N, K)
        counts = jnp.sum(one_hot, axis=0)  # (K,)
        sums = one_hot.T @ x  # (K, n)
        new = jnp.where(
            counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), cents
        )
        moved = jnp.max(jnp.abs(new - cents))
        return new, it + 1, moved

    cents, iters, _ = jax.lax.while_loop(
        cond, body, (cents0, jnp.asarray(0), jnp.asarray(jnp.inf, jnp.float32))
    )
    _, mind2 = assign_fn(x, cents)
    return LloydResult(cents, jnp.sum(mind2), iters)


def kmeans(key: jax.Array, x: jax.Array, cfg: LloydConfig) -> LloydResult:
    """Lloyd-Max with replicates; the best-SSE replicate is returned."""
    if cfg.replicates == 1:
        return lloyd(key, x, cfg)
    keys = jax.random.split(key, cfg.replicates)
    res = jax.vmap(lambda k_: lloyd(k_, x, cfg))(keys)
    best = jnp.argmin(res.sse)
    return LloydResult(res.centroids[best], res.sse[best], res.iters[best])
