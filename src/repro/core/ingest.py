"""Async sketch ingest: overlap batch production with sketch computation.

``ckm.fit_streaming`` is one pass of ``engine.update`` over a batch iterator.
Fed synchronously, the wall-clock is the *sum* of host-side batch production
(decode / synthesis / disk / network) and device-side sketch compute — the
host sits idle while the device sketches and vice versa.  Since the sketch is
a fold over a commutative monoid, nothing about the result depends on when a
batch was produced, so the two stages pipeline freely:

    producer thread:  source -> jnp.float32 -> device_put ->  bounded queue
    consumer (caller):          queue -> engine.update (monoid fold)

Both ingest modes enforce **bounded resident batches** — that is the point
of streaming.  The sync path (``ckm.compute_sketch_streaming``) applies
strict per-batch backpressure: fold, block, discard, so exactly one batch is
ever alive (letting JAX's async dispatch queue pending updates instead would
keep every queued batch buffer alive whenever the source outruns compute —
an unbounded working set wearing a streaming API).  The async path relaxes
that to ``prefetch + 2`` resident batches: ``prefetch`` staged in the
queue, one being folded by the consumer, and at most one already produced
but blocked on a full queue, and ``device_put`` in the
producer starts the host-to-device copy before the consumer needs the
batch, so transfer also rides under compute.  Optionally the carried state's
buffers are donated back to the update step (``donate=True``, opt-in), so
the O(m) accumulators are updated in place instead of reallocated per batch
— see :func:`ingest_stream` for the float-identity caveat that keeps
donation off by default.

The async path folds the *same batches in the same order* with the same ops
as the sync path — results are identical, not merely close
(``tests/test_ingest.py`` pins equality).  Overlap won is reported in
:class:`IngestStats`; ``benchmarks/kernels.py`` records it (and the
sync-vs-async speedup) into ``experiments/paper/kernels.json``.

Anything iterable that yields ``(B_i, n)`` arrays is a valid source — the
:class:`BatchSource` protocol below is what ``data/pipeline.py``'s
``chunked`` and ``SyntheticLM.embedding_stream`` already satisfy.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Iterator, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.obs import runtime as obs_rt

__all__ = [
    "BatchSource",
    "IngestStats",
    "prefetched",
    "ingest_stream",
]


@runtime_checkable
class BatchSource(Protocol):
    """Anything that can be iterated into ``(B_i, n)`` point batches.

    Batch sizes may be ragged; each batch must share the feature dimension.
    Plain generators, ``data.pipeline.chunked(x, size)`` views, and
    ``SyntheticLM.embedding_stream`` all conform.
    """

    def __iter__(self) -> Iterator[Any]: ...


@dataclasses.dataclass
class IngestStats:
    """Timing breakdown of one ingest run.

    ``produce_s`` is time spent inside the source + transfer (producer
    thread), ``compute_s`` time inside ``engine.update`` (consumer),
    ``consumer_wait_s`` time the consumer starved on an empty queue,
    ``producer_wait_s`` time the producer blocked on a full one.
    """

    batches: int = 0
    points: int = 0
    produce_s: float = 0.0
    compute_s: float = 0.0
    consumer_wait_s: float = 0.0
    producer_wait_s: float = 0.0
    wall_s: float = 0.0

    @property
    def overlap_efficiency(self) -> float:
        """Fraction of the maximum hideable time actually hidden, in [0, 1].

        A serial loop takes ``produce_s + compute_s``; perfect overlap takes
        ``max(produce_s, compute_s)`` — the difference that *could* be hidden
        is ``min(produce_s, compute_s)``, and what *was* hidden is the serial
        total minus the measured wall clock.
        """
        hideable = min(self.produce_s, self.compute_s)
        if hideable <= 0.0 or self.wall_s <= 0.0:
            return 0.0
        hidden = self.produce_s + self.compute_s - self.wall_s
        return max(0.0, min(1.0, hidden / hideable))

    def emit_metrics(self, *, resident_batches: int | None = None) -> None:
        """Publish this run's accounting through ``repro.obs.metrics``.

        Called by :func:`ingest_stream` when telemetry is enabled, so
        async-ingest regressions (overlap collapsing, stall time growing)
        show up on the ``ingest.*`` instruments without a benchmark run.
        Counters accumulate across runs; the gauges describe the last run.
        """
        from repro.obs import metrics as obs_metrics

        obs_metrics.counter("ingest.batches").inc(self.batches)
        obs_metrics.counter("ingest.points").inc(self.points)
        obs_metrics.counter("ingest.produce_s").inc(self.produce_s)
        obs_metrics.counter("ingest.compute_s").inc(self.compute_s)
        obs_metrics.counter("ingest.consumer_wait_s").inc(self.consumer_wait_s)
        obs_metrics.counter("ingest.producer_wait_s").inc(self.producer_wait_s)
        obs_metrics.counter("ingest.wall_s").inc(self.wall_s)
        obs_metrics.gauge("ingest.overlap_efficiency").set(
            self.overlap_efficiency
        )
        if resident_batches is not None:
            obs_metrics.gauge("ingest.resident_batches").set(resident_batches)


_DONE = object()


def _put_until_stopped(q: "queue.Queue", item, stop: threading.Event):
    """Enqueue ``item`` unless the consumer has already walked away."""
    while not stop.is_set():
        try:
            q.put(item, timeout=0.1)
            return
        except queue.Full:
            continue


def prefetched(
    source: BatchSource,
    prefetch: int = 2,
    *,
    place=None,
    stats: IngestStats | None = None,
) -> Iterator[Any]:
    """Iterate ``source`` through a producer thread + bounded queue.

    ``prefetch`` is the queue depth (2 = classic double buffering: one batch
    in flight while the previous is consumed).  ``place`` optionally maps
    each raw batch onto its device layout inside the producer (e.g.
    ``jax.device_put`` or the engine's ``shard_points``) so the transfer
    overlaps consumer compute.  Exceptions raised by the source are re-raised
    at the consumer's next pull, with the producer shut down cleanly.
    """
    if prefetch < 1:
        raise ValueError(f"prefetch depth must be >= 1, got {prefetch}")
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def produce():
        try:
            it = iter(source)
            while True:
                t0 = time.perf_counter()
                try:
                    batch = next(it)  # source generation / I-O happens here
                except StopIteration:
                    break
                if place is not None:
                    batch = place(batch)
                if stats is not None:
                    stats.produce_s += time.perf_counter() - t0
                while not stop.is_set():
                    t0 = time.perf_counter()
                    try:
                        q.put(batch, timeout=0.1)
                        if stats is not None:
                            stats.producer_wait_s += time.perf_counter() - t0
                        break
                    except queue.Full:
                        if stats is not None:
                            stats.producer_wait_s += time.perf_counter() - t0
                if stop.is_set():
                    return
            _put_until_stopped(q, _DONE, stop)
        except BaseException as e:  # noqa: BLE001 — relayed to the consumer
            _put_until_stopped(q, e, stop)

    worker = threading.Thread(target=produce, name="sketch-ingest", daemon=True)
    worker.start()
    try:
        while True:
            t0 = time.perf_counter()
            item = q.get()
            if stats is not None:
                stats.consumer_wait_s += time.perf_counter() - t0
            if item is _DONE:
                return
            if isinstance(item, BaseException):
                raise item
            yield item
    finally:
        stop.set()
        worker.join(timeout=5.0)


def ingest_stream(
    engine,
    source: BatchSource,
    *,
    state=None,
    prefetch: int = 2,
    donate: bool | None = None,
) -> tuple[Any, IngestStats]:
    """Fold ``source`` into an engine state with production/compute overlap.

    Drives ``engine.update`` exactly like a sync loop would — same batches,
    same order, identical result — while a producer thread keeps ``prefetch``
    batches staged (converted to f32 and placed on device).  Returns the
    final *unfinalized* state (callers may keep merging partials into it —
    e.g. through ``core.topology.reduce_states`` — before ``finalize``) and
    the :class:`IngestStats` describing the overlap achieved.

    ``donate=True`` (default off) wraps the fold step in one jit with the
    carried state donated, letting XLA update the O(m) accumulators in
    place on accelerators.  Opt-in because it trades away the bitwise
    sync-equality guarantee on the float path: fusing update into a single
    jit may reassociate float ops (results stay within normal float
    tolerance, ~1e-6).  The incoming ``state`` is copied first, so the
    caller's buffers survive donation.
    """
    stats = IngestStats()
    if state is None:
        state = engine.init_state()

    def place(batch):
        x = jnp.asarray(batch, jnp.float32)
        if engine.backend == "sharded":
            return engine.shard_points(x)
        return jax.device_put(x)

    if donate is None:
        donate = False
    update = engine.update
    if donate:
        # Donating the carried state lets XLA update the O(m) accumulators in
        # place.  jit retraces per batch shape (streams have at most one
        # ragged tail shape, so two traces).  The first donated call would
        # invalidate the caller's `state` buffers, so carry a private copy.
        state = jax.tree_util.tree_map(jnp.array, state)
        update = jax.jit(
            lambda s, b: engine.update(s, b), donate_argnums=(0,)
        )

    # The span wraps the whole overlapped pass (the per-batch engine.update
    # spans nest inside it); the stall/overlap numbers land on the ingest.*
    # instruments via stats.emit_metrics below.
    from repro.obs import trace as obs_trace

    with obs_trace.span("ingest.stream", prefetch=prefetch, donate=donate):
        t_start = time.perf_counter()
        for batch in prefetched(source, prefetch, place=place, stats=stats):
            t0 = time.perf_counter()
            state = update(state, batch)
            # Block per batch: streaming means a batch is *discarded* once
            # folded in — without this, JAX's async dispatch would queue
            # arbitrarily many pending updates (and keep their batch buffers
            # alive) whenever production outruns compute, silently unbounding
            # the O(m) working set.  Resident batches stay bounded at
            # prefetch + 2 (queue + this one + the producer's in-hand batch),
            # and the produce/compute split in the stats is truthful.
            jax.block_until_ready(state)
            stats.compute_s += time.perf_counter() - t0
            stats.batches += 1
            stats.points += int(batch.shape[0])
        stats.wall_s = time.perf_counter() - t_start
    if obs_rt.ENABLED:
        stats.emit_metrics(resident_batches=prefetch + 2)
    return state, stats
