"""Compressive K-means — the user-facing API (paper §3.3).

The pipeline is exactly the paper's four steps:

1. choose a frequency scale sigma^2 on a small fraction of the data
   (``frequencies.estimate_sigma2``),
2. build the frequency operator for ``m`` frequencies from the adapted-radius
   distribution (``core.freq_ops``; ``CKMConfig.freq_op`` selects the paper's
   dense matrix or the structured fast-transform family),
3. compute the sketch ``z = Sk(X, 1/N)`` (one pass, through the unified
   ``core.engine.SketchEngine`` — xla / pallas / sharded backends; streaming
   via ``fit_streaming``) together with the box bounds ``l, u``,
4. decode K centroids from the sketch with a registered decoder
   (``core.decoders``): ``CKMConfig.decoder`` selects ``"clompr"`` (paper
   Algorithm 1, the default) or ``"sketch_shift"`` (mean-shift on the
   sketched characteristic function — more robust modes from the same
   sketch).

Beyond the paper, ``CKMConfig.sketch_quantization`` switches step 3 to the
QCKM universally-quantized sketch (``core.quantize``): per-point 1-bit/b-bit
integer codes, dequantized via the E[sign] correction before step 4 — the
decoders are unchanged (see ``docs/architecture.md``).  Step 3's scaling
knobs: ``CKMConfig.ingest="async"`` overlaps batch production with sketch
compute in ``fit_streaming`` (``core.ingest``), and
``CKMConfig.reduce_topology`` picks the sharded backend's cross-device merge
schedule (``core.topology``; see ``docs/scaling.md``).

Replicates are ``lax.map``-ed over PRNG keys and selected by the value of the
sketch-domain cost (4) — the SSE is *not* available once data is discarded.
Every registered decoder reports that same cost, so selection (and decoder
comparison) is apples-to-apples.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import decoders as dec_mod
from repro.core import freq_ops as fo
from repro.core import frequencies as freq_mod
from repro.core import quantize as qz
from repro.core import sketch as sk
from repro.core.decoders import AMPConfig, CLOMPRConfig, SketchShiftConfig
from repro.core.engine import SketchEngine


@dataclasses.dataclass(frozen=True)
class CKMConfig:
    k: int
    m: int | None = None  # sketch size; default m = 10*K*n (paper Fig. 1 uses
    # m = 1000 at K = n = 10; Fig. 2 shows relSSE hits 2.0 already at 5*K*n)
    freq_dist: freq_mod.FreqDist = "adapted_radius"
    # Frequency operator family (core.freq_ops registry): "dense" draws the
    # paper's materialized (n, m) matrix; "structured" uses stacked
    # HD-Rademacher fast-transform blocks with adapted-radius radial
    # rescaling — O(m·sqrt(d)) projections, O(m) operator state, O(1) spec on
    # the wire/in checkpoints.  Any registered name is valid end-to-end
    # (engine backends, decoders, quantization, streaming).
    freq_op: str = "dense"
    # Sampling/projection dtype of the frequency operator ("float64" needs
    # jax.enable_x64); propagated to frequencies.draw_frequencies.
    freq_dtype: str = "float32"
    replicates: int = 1
    sigma2: float | None = None  # None -> estimate from a data fraction
    sigma2_sample: int = 2048
    init: str = "range"
    atom_steps: int = 300
    joint_steps: int = 200
    nnls_iters: int = 150
    atom_lr: float = 0.05
    joint_lr: float = 0.02
    atom_restarts: int = 1
    final_steps: int = 1000
    merge_radius_scale: float = 2.5
    sketch_chunk: int = 8192
    # Sketch-computation backend: "xla" | "pallas" | "sharded" (see
    # core.engine.SketchEngine's backend matrix).  "sharded" needs a mesh
    # passed to fit()/compute_sketch().
    sketch_backend: str = "xla"
    # Cross-device merge schedule of the sharded backend (and of host-level
    # reduce_partials): any name registered in core.topology — "allreduce"
    # (native psum), "tree" (butterfly, log2 p hops), "ring" (token passing).
    # Every topology produces the same sketch (bitwise when quantized); the
    # choice trades wire bytes vs hop count — see docs/scaling.md.
    reduce_topology: str = "allreduce"
    # Streaming ingest mode for fit_streaming: "sync" feeds the engine batch
    # by batch; "async" overlaps batch production/transfer with sketch
    # compute through core.ingest (double-buffered producer thread,
    # ingest_prefetch batches staged).  Results are identical either way.
    ingest: str = "sync"
    ingest_prefetch: int = 2
    # Universal quantization of the sketch (QCKM): "none" | "1bit" | "<b>bit".
    # Per-point contributions are quantized to integer codes of the dithered
    # phase and accumulated in int32; finalize dequantizes via the E[sign]
    # correction before decoding (see core.quantize).  Works on every
    # backend; on "sharded" the cross-device merge psums integer accumulators.
    sketch_quantization: str = "none"
    # Exponential time decay of the sketch state (None = lifetime average).
    # A gamma in (0, 1] switches the engine to the timestamped state
    # transform: update/merge scale older accumulator content by gamma**dt,
    # so the sketch tracks non-stationary streams ("cluster recent traffic").
    # Composes with every backend and with sketch_quantization; see
    # core.engine ("State transforms") and core.window for bucketed windows.
    decay: float | None = None
    # Sketch decoder: any name in the registry (core.decoders) — "clompr"
    # (paper Algorithm 1), "sketch_shift" (mean-shift on the sketched
    # characteristic function) or "amp" (CL-AMP joint message passing,
    # accurate at small m).  Replicate selection, quantized sketches and
    # fit/fit_streaming work identically for every decoder.
    decoder: str = "clompr"
    # sketch_shift decoder knobs (ignored by "clompr"); nnls_iters and init
    # above are shared by both decoders.  merge_radius_scale is clompr-only:
    # the sketch_shift dedup radius is the (deliberately tighter)
    # shift_dedup_scale below.
    shift_candidates: int = 8  # mean-shift swarm size, per cluster (P = 8*K)
    shift_steps: int = 150  # fixed-point iterations
    shift_step_scale: float = 1.0  # multiplier on the natural step h^2
    shift_polish_steps: int = 400  # joint (C, alpha) Adam after mode selection
    shift_impl: str = "xla"  # score/shift step impl: "xla" | "pallas"
    # Mode-harvest dedup radius, in units of 1/median||omega|| (one kernel
    # std).  Deliberately tighter than merge_radius_scale: it only guards
    # against re-picking leftover residue of an already-kept mode, and a
    # larger radius would forbid genuinely overlapping clusters.
    shift_dedup_scale: float = 1.0
    # amp (CL-AMP) decoder knobs (ignored by the other decoders); nnls_iters,
    # joint_lr and init above are shared.
    amp_iters: int = 300  # GAMP iterations
    amp_damp: float = 0.3  # damping on the message updates (1 = undamped)
    amp_polish_steps: int = 600  # joint (C, alpha) Adam after the loop
    amp_impl: str = "xla"  # amp_denoise kernel impl: "xla" | "pallas"
    # Decoder convergence tracing: thread ``trace=True`` into the decoder
    # config, so the decode also returns its per-iteration trajectory
    # (CLOMPR/sketch_shift: residual norms; amp: unexplained energy +
    # posterior variance).  ``decode_sketch`` emits the selected replicate's
    # series through ``repro.obs.trace`` — and flips this flag on by itself
    # when telemetry is enabled (host-side calls only; the traced buffers
    # are dead-code-eliminated whenever the flag is off).
    trace_convergence: bool = False

    def sketch_size(self, n: int) -> int:
        return self.m if self.m is not None else 10 * self.k * n

    def sketch_shift_config(self) -> SketchShiftConfig:
        return SketchShiftConfig(
            k=self.k,
            candidates=max(self.shift_candidates * self.k, self.k),
            shift_steps=self.shift_steps,
            step_scale=self.shift_step_scale,
            nnls_iters=self.nnls_iters,
            polish_steps=self.shift_polish_steps,
            polish_lr=self.joint_lr,
            init=self.init,
            dedup_radius_scale=self.shift_dedup_scale,
            impl=self.shift_impl,
            trace=self.trace_convergence,
        )

    def amp_config(self) -> AMPConfig:
        return AMPConfig(
            k=self.k,
            iters=self.amp_iters,
            damp=self.amp_damp,
            nnls_iters=self.nnls_iters,
            polish_steps=self.amp_polish_steps,
            polish_lr=self.joint_lr,
            init=self.init,
            impl=self.amp_impl,
            trace=self.trace_convergence,
        )

    def clompr_config(self) -> CLOMPRConfig:
        return CLOMPRConfig(
            k=self.k,
            atom_steps=self.atom_steps,
            joint_steps=self.joint_steps,
            nnls_iters=self.nnls_iters,
            atom_lr=self.atom_lr,
            joint_lr=self.joint_lr,
            init=self.init,  # type: ignore[arg-type]
            atom_restarts=self.atom_restarts,
            final_steps=self.final_steps,
            merge_radius_scale=self.merge_radius_scale,
            trace=self.trace_convergence,
        )


class CKMResult(NamedTuple):
    centroids: jax.Array  # (K, n)
    weights: jax.Array  # (K,) — mixture weights alpha, sum to 1
    cost: jax.Array  # sketch-domain objective (4) of the selected replicate
    sigma2: jax.Array
    freq_op: "fo.FrequencyOperator"  # the operator (O(m) state, O(1) spec)
    sketch: jax.Array  # stacked-real (2m,)
    bounds: tuple[jax.Array, jax.Array]

    @property
    def frequencies(self) -> jax.Array:
        """Materialised ``(n, m)`` frequency matrix (back-compat, on demand —
        the result itself carries the operator, not the matrix)."""
        return self.freq_op.materialize()


def stream_keys(key: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The sketch pass's three PRNG streams: ``(sigma2, frequencies, dither)``.

    One ``split`` fan-out from the parent key — the single derivation point
    shared by :func:`_draw_freqs` and :func:`make_quantizer`.  (The dither
    stream used to be ``fold_in(key, 0x51)`` while sigma2/frequencies came
    from ``split(key)`` of the *same* parent — two derivation schemes applied
    to one key, with no independence guarantee between them.)  Because every
    stream has its own branch, enabling quantization still does not perturb
    the frequency/sigma2 draws: a quantized run sees the same frequencies as
    its float twin under the same key.
    """
    k_sig, k_freq, k_dither = jax.random.split(key, 3)
    return k_sig, k_freq, k_dither


def make_quantizer(key: jax.Array, cfg: CKMConfig, m: int):
    """The sketch quantizer for ``cfg`` (or None for the float path).

    Draws only from the dither branch of :func:`stream_keys`, so the float
    and quantized pipelines share frequencies under the same parent key.
    """
    if cfg.sketch_quantization == "none":
        return None
    _, _, k_dither = stream_keys(key)
    return qz.make_quantizer(k_dither, m, cfg.sketch_quantization)


def make_engine(
    w, cfg: CKMConfig, mesh=None, quantizer=None
) -> SketchEngine:
    """The SketchEngine for ``cfg`` — backend, quantization and the merge
    topology are config flags.  ``w``: a frequency operator (or raw matrix)."""
    return SketchEngine(
        w, cfg.sketch_backend, chunk=cfg.sketch_chunk, mesh=mesh,
        quantizer=quantizer, reduce_topology=cfg.reduce_topology,
        decay=cfg.decay,
    )


def _draw_freqs(key, sample: jax.Array, n: int, cfg: CKMConfig):
    """Steps 1–2 on a data sample: scale estimation + operator construction.

    Returns the registered frequency operator ``cfg.freq_op`` (the ``"dense"``
    builder calls ``frequencies.draw_frequencies`` with the same key — the
    registry path is bitwise-identical to the historical direct draw).  The
    sigma2/frequency keys come from the shared :func:`stream_keys` fan-out.
    """
    k_sig, k_freq, _ = stream_keys(key)
    if cfg.sigma2 is None:
        take = min(cfg.sigma2_sample, sample.shape[0])
        sigma2 = freq_mod.estimate_sigma2(k_sig, sample[:take])
    else:
        sigma2 = jnp.asarray(cfg.sigma2, jnp.float32)
    op = fo.make_operator(
        cfg.freq_op, k_freq, cfg.sketch_size(n), n, sigma2,
        dist=cfg.freq_dist, dtype=jnp.dtype(cfg.freq_dtype),
    )
    return op, sigma2


def compute_sketch(
    key: jax.Array, x: jax.Array, cfg: CKMConfig, mesh=None
) -> tuple[jax.Array, jax.Array, jax.Array, tuple[jax.Array, jax.Array]]:
    """Steps 1–3: scale estimation, operator construction, one-pass sketch.

    The sketch pass runs through the unified engine; ``cfg.sketch_backend``
    selects xla / pallas / sharded (``mesh`` required for sharded).  The
    second return value is the frequency *operator* (``core.freq_ops``) —
    ``op.materialize()`` recovers the dense matrix when needed.
    """
    x = jnp.asarray(x, jnp.float32)
    op, sigma2 = _draw_freqs(key, x, x.shape[1], cfg)
    quantizer = make_quantizer(key, cfg, op.m)
    z, lo, hi = make_engine(op, cfg, mesh, quantizer).sketch(x)
    return z, op, sigma2, (lo, hi)


def compute_sketch_streaming(
    key: jax.Array, batches: Iterable[jax.Array], cfg: CKMConfig, mesh=None
) -> tuple[jax.Array, jax.Array, jax.Array, tuple[jax.Array, jax.Array], jax.Array]:
    """One-pass sketch of an out-of-core batch iterator.

    The first batch doubles as the sigma^2-estimation sample (paper step 1
    uses "a small fraction of the data"); every batch — the first included —
    is then folded into the engine state.  Returns the first batch as the
    last element so callers may reuse it for sample/kpp decoder inits.
    """
    if cfg.ingest not in ("sync", "async"):
        raise ValueError(
            f"CKMConfig.ingest must be 'sync' or 'async', got {cfg.ingest!r}"
        )
    it = iter(batches)
    try:
        first = jnp.asarray(next(it), jnp.float32)
    except StopIteration:
        raise ValueError("compute_sketch_streaming needs at least one batch")
    op, sigma2 = _draw_freqs(key, first, first.shape[1], cfg)
    quantizer = make_quantizer(key, cfg, op.m)
    eng = make_engine(op, cfg, mesh, quantizer)
    state = eng.update(eng.init_state(), first)
    if cfg.ingest == "async":
        # Overlap production/transfer of the remaining batches with sketch
        # compute (core.ingest).  Same batches, same order -> same result.
        from repro.core import ingest as ingest_mod

        state, _ = ingest_mod.ingest_stream(
            eng, it, state=state, prefetch=cfg.ingest_prefetch
        )
    else:
        for batch in it:
            state = eng.update(state, batch)
            # Strict streaming backpressure: the batch may be discarded the
            # moment it is folded in (the O(m)-memory contract).  Without
            # this, async dispatch would buffer every pending batch whenever
            # the source outruns compute.  ingest="async" relaxes it to a
            # bounded double buffer (core.ingest) to overlap the two.
            jax.block_until_ready(state)
    z, lo, hi = eng.finalize(state)
    return z, op, sigma2, (lo, hi), first


def decode_sketch(
    key: jax.Array,
    z: jax.Array,
    w,
    lower: jax.Array,
    upper: jax.Array,
    cfg: CKMConfig,
    x_init: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Step 4: decoding via the registered decoder ``cfg.decoder``, with
    replicates selected by the cost (4).

    ``w`` is the frequency operator (raw ``(n, m)`` arrays are still accepted
    through the deprecation shim).  Replicate r uses ``fold_in(key, r)``, so
    the replicate-key sequence for R replicates is a prefix of the sequence
    for R' > R, and replicates run sequentially via ``lax.map`` (the
    *unbatched* decoder trace — identical numerics to a single run).
    Together these make replicate selection monotone for every decoder: more
    replicates can never return a higher cost (all registry decoders report
    the same objective (4)).

    Convergence tracing: when ``cfg.trace_convergence`` is set — or telemetry
    is enabled (``repro.obs``) and this is a host-side call (``z`` not a
    tracer) — the decoder runs with its ``trace`` flag on and the selected
    replicate's trajectory is emitted as ``decoder.<name>.<series>`` events
    on the default tracer.  The return contract stays ``(centroids, weights,
    cost)`` either way.
    """
    from repro.obs import runtime as obs_rt

    w = fo.as_operator(w)
    trace_on = cfg.trace_convergence
    if not trace_on and obs_rt.ENABLED and not isinstance(z, jax.core.Tracer):
        trace_on = True
    run_cfg = (
        cfg
        if trace_on == cfg.trace_convergence
        else dataclasses.replace(cfg, trace_convergence=trace_on)
    )
    decode = dec_mod.get_decoder(run_cfg.decoder)
    keys = jnp.stack(
        [jax.random.fold_in(key, r) for r in range(run_cfg.replicates)]
    )
    if run_cfg.replicates == 1:
        out = decode(keys[0], z, w, lower, upper, run_cfg, x_init)
    elif x_init is None:
        out = jax.lax.map(
            lambda k_: decode(k_, z, w, lower, upper, run_cfg), keys
        )
    else:
        out = jax.lax.map(
            lambda k_: decode(k_, z, w, lower, upper, run_cfg, x_init), keys
        )
    # A tracing decoder returns (cents, alphas, cost, {series}); one with no
    # trace support (or trace off) returns the plain 3-tuple.
    traces = out[3] if len(out) == 4 else None
    cents, alphas, costs = out[0], out[1], out[2]
    if run_cfg.replicates > 1:
        best = jnp.argmin(costs)
        cents, alphas, costs = cents[best], alphas[best], costs[best]
        if traces is not None:
            traces = {name: vals[best] for name, vals in traces.items()}
    if traces is not None and not isinstance(costs, jax.core.Tracer):
        from repro.obs import trace as obs_trace

        for name, vals in traces.items():
            obs_trace.series(
                f"decoder.{run_cfg.decoder}.{name}",
                jnp.asarray(vals),
                decoder=run_cfg.decoder,
            )
    return cents, alphas, costs


def fit(key: jax.Array, x: jax.Array, cfg: CKMConfig, mesh=None) -> CKMResult:
    """End-to-end compressive K-means on an in-memory dataset."""
    k_sketch, k_dec = jax.random.split(key)
    z, op, sigma2, (lo, hi) = compute_sketch(k_sketch, x, cfg, mesh)
    x_init = x if cfg.init in ("sample", "kpp") else None
    cents, alphas, cost = decode_sketch(k_dec, z, op, lo, hi, cfg, x_init)
    return CKMResult(cents, alphas, cost, sigma2, op, z, (lo, hi))


def fit_streaming(
    key: jax.Array, batches: Iterable[jax.Array], cfg: CKMConfig, mesh=None
) -> CKMResult:
    """End-to-end CKM over an out-of-core iterator of ``(B_i, n)`` batches.

    One pass, O(m) memory: each batch is folded into the engine state and may
    be discarded immediately — the dataset never has to fit in memory, which
    is the paper's whole point (cost after sketching is N-independent).  The
    "sample"/"kpp" decoder inits draw from the *first* batch only (the rest
    of the stream is gone by decode time).
    """
    k_sketch, k_dec = jax.random.split(key)
    z, op, sigma2, (lo, hi), first = compute_sketch_streaming(
        k_sketch, batches, cfg, mesh
    )
    x_init = first if cfg.init in ("sample", "kpp") else None
    cents, alphas, cost = decode_sketch(k_dec, z, op, lo, hi, cfg, x_init)
    return CKMResult(cents, alphas, cost, sigma2, op, z, (lo, hi))


def diagnose(result: CKMResult, **kwargs):
    """Attribute a (possibly bad) fit to sketch size m, frequency scale
    sigma, or the decoder — ``repro.obs.diagnose.diagnose`` re-exported at
    the pipeline API (``ckm.diagnose(ckm.fit(...))``).  Data-free: the probe
    decodes run on the result's own sketch; see the full parameter list and
    the verdict semantics in :mod:`repro.obs.diagnose`.
    """
    from repro.obs.diagnose import diagnose as obs_diagnose

    return obs_diagnose(result, **kwargs)


# ---------------------------------------------------------------------------
# Evaluation helpers (need data access — used for experiments only)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk",))
def sse(x: jax.Array, centroids: jax.Array, chunk: int = 16384) -> jax.Array:
    """Sum of squared errors (1):  sum_i min_k ||x_i - c_k||^2 (chunked over N)."""
    x = jnp.asarray(x, jnp.float32)
    n_pts = x.shape[0]
    pad = (-n_pts) % chunk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
    valid = jnp.arange(x.shape[0]) < n_pts
    xs = x.reshape(-1, chunk, x.shape[1])
    vs = valid.reshape(-1, chunk)
    c2 = jnp.sum(centroids * centroids, axis=1)  # (K,)

    def body(acc, inp):
        xc, vc = inp
        d2 = (
            jnp.sum(xc * xc, axis=1, keepdims=True)
            - 2.0 * xc @ centroids.T
            + c2[None, :]
        )
        return acc + jnp.sum(jnp.where(vc, jnp.min(d2, axis=1), 0.0)), None

    total, _ = jax.lax.scan(body, jnp.asarray(0.0, jnp.float32), (xs, vs))
    return total


@functools.partial(jax.jit, static_argnames=("chunk",))
def predict(
    x: jax.Array, centroids: jax.Array, chunk: int = 16384
) -> jax.Array:
    """Hard assignment of each point to its nearest centroid (chunked over N).

    Same pad+scan scheme as :func:`sse`: the ``(N, K)`` distance matrix never
    materialises — only one ``(chunk, K)`` block lives at a time, so the
    assignment pass works at the paper's N = 10^7 scale in O(chunk·K) memory.
    """
    x = jnp.asarray(x, jnp.float32)
    n_pts = x.shape[0]
    # N is a trace-time constant: shrink the chunk to it so small inputs
    # (e.g. per-head KV caches on the serving path) don't pad up to 16384
    # rows of wasted distance work.  jit retraces per shape anyway.
    chunk = min(chunk, max(n_pts, 1))
    pad = (-n_pts) % chunk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
    xs = x.reshape(-1, chunk, x.shape[1])
    c2 = jnp.sum(centroids * centroids, axis=1)  # (K,)

    def body(_, xc):
        d2 = (
            jnp.sum(xc * xc, axis=1, keepdims=True)
            - 2.0 * xc @ centroids.T
            + c2[None, :]
        )
        return None, jnp.argmin(d2, axis=1)

    _, labels = jax.lax.scan(body, None, xs)
    return labels.reshape(-1)[:n_pts]
