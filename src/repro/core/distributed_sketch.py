"""Distributed / streaming sketch computation.

NOTE: the canonical mergeable-sketch API is now
:class:`repro.core.engine.SketchEngine` (init/update/merge/finalize over a
commutative-monoid state, with xla/pallas/sharded backends).  This module
keeps the original ``SketchState`` pytree because it rides train-loop
checkpoints (train/monitor.py, data/clustering.py) — its layout is frozen —
and ``sharded_sketch`` here delegates to the engine's sharded backend.

The sketch is *linear in the empirical distribution*: sketches of dataset
shards simply average (weighted by shard sizes).  This file provides

- ``SketchState`` — a mergeable accumulator pytree (sketch sums + count + box
  bounds), the "one pass over X" object of paper §3.1.  The same pass also
  harvests the CLOMPR box constraints ``l, u``.
- ``sharded_sketch`` — a ``shard_map`` computation over a (pod, data, ...)
  mesh: every device sketches its local shard, then a single
  ``psum``/``pmin``/``pmax`` over the data axes merges the statistics.  This is
  the paper's "split the dataset over computing units and average", expressed
  as the native collective — the cross-pod traffic is O(m), independent of N.
- ``streaming`` updates for use inside a training step (activation monitors):
  the accumulator can ride the existing gradient all-reduce schedule.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import sketch as sk


class SketchState(NamedTuple):
    """Mergeable one-pass statistics: merge(a, b) = elementwise combine."""

    sums: jax.Array  # (2m,) un-normalised stacked-real sketch sums
    count: jax.Array  # () f32 — number of points seen
    lo: jax.Array  # (n,) running per-coordinate min
    hi: jax.Array  # (n,) running per-coordinate max


def init_state(m: int, n: int) -> SketchState:
    return SketchState(
        sums=jnp.zeros((2 * m,), jnp.float32),
        count=jnp.zeros((), jnp.float32),
        lo=jnp.full((n,), jnp.inf, jnp.float32),
        hi=jnp.full((n,), -jnp.inf, jnp.float32),
    )


@jax.jit
def update(state: SketchState, x: jax.Array, w) -> SketchState:
    """Fold a batch ``x: (B, n)`` into the accumulator (streaming use).

    ``w``: a ``core.freq_ops.FrequencyOperator`` or a raw ``(n, m)`` matrix
    (deprecation shim) — forwarded to ``core.sketch.sketch``.
    """
    x = jnp.asarray(x, jnp.float32)
    b = x.shape[0]
    # Unnormalised sums: sketch() with unit weights.
    part = sk.sketch(x, w, weights=jnp.ones((b,), jnp.float32), chunk=min(b, 8192))
    return SketchState(
        sums=state.sums + part,
        count=state.count + b,
        lo=jnp.minimum(state.lo, jnp.min(x, axis=0)),
        hi=jnp.maximum(state.hi, jnp.max(x, axis=0)),
    )


@jax.jit
def merge(a: SketchState, b: SketchState) -> SketchState:
    return SketchState(
        sums=a.sums + b.sums,
        count=a.count + b.count,
        lo=jnp.minimum(a.lo, b.lo),
        hi=jnp.maximum(a.hi, b.hi),
    )


@jax.jit
def finalize(state: SketchState) -> tuple[jax.Array, jax.Array, jax.Array]:
    """-> (z stacked-real (2m,), lower (n,), upper (n,))."""
    z = state.sums / jnp.maximum(state.count, 1.0)
    return z, state.lo, state.hi


# ---------------------------------------------------------------------------
# shard_map distributed sketch
# ---------------------------------------------------------------------------


def sharded_sketch(
    x: jax.Array,
    w: jax.Array,
    mesh: Mesh,
    data_axes: Sequence[str] = ("data",),
    chunk: int = 8192,
    reduce_topology: str = "allreduce",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-pass distributed sketch + bounds over a device mesh.

    ``x: (N, n)`` is sharded along N over ``data_axes`` (any other mesh axes
    hold replicas).  Returns the *replicated* ``(z, lo, hi)``.

    Thin wrapper over the unified :class:`repro.core.engine.SketchEngine`
    (backend="sharded") — the cross-device merge IS the engine's ``merge``
    expressed as a collective, and ``reduce_topology`` picks its schedule
    ("allreduce" | "tree" | "ring", see ``core.topology``).
    """
    from repro.core.engine import SketchEngine

    eng = SketchEngine(
        w, "sharded", chunk=chunk, mesh=mesh, data_axes=tuple(data_axes),
        reduce_topology=reduce_topology,
    )
    return eng.sketch(x)


def shard_points(x: jax.Array, mesh: Mesh, data_axes: Sequence[str] = ("data",)):
    """Place ``x`` with its leading axis sharded over ``data_axes``."""
    return jax.device_put(x, NamedSharding(mesh, P(tuple(data_axes))))
