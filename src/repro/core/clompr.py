"""Back-compat adapter: CLOMPR now lives in the decoder subsystem.

The implementation moved verbatim to ``repro.core.decoders.clompr`` (the
``"clompr"`` entry of the decoder registry); this module re-exports it so
existing imports — ``from repro.core.clompr import CLOMPRConfig, clompr`` —
keep working with bitwise-identical numerics.  New code should go through the
registry (``repro.core.decoders.get_decoder``) or the ``CKMConfig.decoder``
flag; see ``docs/architecture.md``.
"""

from repro.core.decoders.clompr import CLOMPRConfig, InitStrategy, clompr

__all__ = ["CLOMPRConfig", "InitStrategy", "clompr"]
