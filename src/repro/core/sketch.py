"""The sketching operator ``Sk`` / ``A`` (paper §3.1), in JAX.

The sketch of weighted points ``(Y, beta)`` at frequencies ``W = [w_1..w_m]`` is

    Sk(Y, beta)_j = sum_l beta_l * exp(-i w_j^T y_l)          (complex, length m)

Internally everything uses the *stacked-real* representation

    z = [ sum_l beta_l cos(Y W) ,  -sum_l beta_l sin(Y W) ]   (real, length 2m)

because (a) TPUs have no complex MXU path, (b) autodiff and Pallas kernels are
simpler on reals, and (c) the l2 norm is preserved:  |z_complex|^2 == |z_real|^2.

Every atom ``A delta_c`` has constant modulus 1 per frequency, hence constant
norm ``||A delta_c||_2 = sqrt(m)`` — used by CLOMPR's normalised correlation step.

Frequency-operator contract: every function here takes ``w`` as either a
``core.freq_ops.FrequencyOperator`` (the registry object — projections via
``op.apply``, which is a fast transform for the structured family) or a raw
``(n, m)`` array, wrapped silently in a ``"dense"`` operator for convenience
(``x @ w`` numerics are bitwise-unchanged).  The decoder helpers and kernel
wrappers are stricter — they raise ``TypeError`` on raw arrays (PR 6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import freq_ops as fo
from repro.utils import compat

__all__ = [
    "sketch",
    "sketch_quantized",
    "sketch_complex",
    "to_complex",
    "from_complex",
    "atom",
    "atoms",
    "atom_norm",
    "data_bounds",
]


def _stacked(cos_part: jax.Array, sin_part: jax.Array) -> jax.Array:
    return jnp.concatenate([cos_part, -sin_part], axis=-1)


def to_complex(z: jax.Array) -> jax.Array:
    """Stacked-real (…, 2m) -> complex (…, m)."""
    m = z.shape[-1] // 2
    return jax.lax.complex(z[..., :m], z[..., m:])


def from_complex(zc: jax.Array) -> jax.Array:
    """Complex (…, m) -> stacked-real (…, 2m)."""
    return jnp.concatenate([jnp.real(zc), jnp.imag(zc)], axis=-1)


@functools.partial(jax.jit, static_argnames=("chunk", "vary_axes"))
def sketch(
    x: jax.Array,
    w: jax.Array,
    weights: jax.Array | None = None,
    chunk: int = 8192,
    vary_axes: tuple[str, ...] = (),
) -> jax.Array:
    """Sketch of points ``x: (N, n)`` at frequencies ``w: (n, m)``.

    Returns the stacked-real sketch ``(2m,)``.  ``weights`` defaults to uniform
    ``1/N``.  Computation is chunked over N with an f32 accumulator so the
    ``(N, m)`` projection matrix never fully materialises.

    ``vary_axes``: when called inside ``shard_map`` on per-device shards, the
    scan carry must be marked as varying over the manual mesh axes.
    """
    op = fo.as_operator(w)
    x = jnp.asarray(x, jnp.float32)
    n_pts = x.shape[0]
    m = op.m
    if weights is None:
        weights = jnp.full((n_pts,), 1.0 / n_pts, jnp.float32)
    else:
        weights = jnp.asarray(weights, jnp.float32)

    pad = (-n_pts) % chunk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
        weights = jnp.concatenate([weights, jnp.zeros((pad,), weights.dtype)], axis=0)
    n_chunks = x.shape[0] // chunk
    xs = x.reshape(n_chunks, chunk, -1)
    ws_ = weights.reshape(n_chunks, chunk)

    def body(acc, inp):
        xc, bc = inp
        # Accumulators are f32 regardless of the operator's sampling dtype
        # (an f64 operator projects in f64; the cast is a no-op for f32 ops).
        proj = jnp.asarray(op.apply(xc), jnp.float32)  # (chunk, m)
        c = bc @ jnp.cos(proj)  # (m,)
        s = bc @ jnp.sin(proj)
        return (acc[0] + c, acc[1] + s), None

    acc0 = jnp.zeros((m,), jnp.float32)
    if vary_axes:
        acc0 = compat.pvary(acc0, vary_axes)
    (cos_acc, sin_acc), _ = jax.lax.scan(body, (acc0, acc0), (xs, ws_))
    return _stacked(cos_acc, sin_acc)


@functools.partial(jax.jit, static_argnames=("bits", "chunk", "vary_axes"))
def sketch_quantized(
    x: jax.Array,
    w: jax.Array,
    dither: jax.Array,
    valid: jax.Array | None = None,
    bits: int = 1,
    chunk: int = 8192,
    vary_axes: tuple[str, ...] = (),
) -> tuple[jax.Array, jax.Array]:
    """Universally-quantized sketch sums (QCKM) — the XLA fallback path.

    Returns int32 ``(q_cos_sum, q_sin_sum)`` of shape ``(m,)``: the per-point
    codes ``quantize.quantize_codes(x @ w, dither, bits)`` summed over N.
    Deterministic per point (the dither is per-frequency), hence exactly
    split-invariant; chunked over N like :func:`sketch` so the ``(N, m)``
    projection never materialises.  ``valid`` is a 0/1 row mask for padding
    (masked rows contribute zero codes).  ``vary_axes``: see :func:`sketch`.
    """
    from repro.core import quantize as qz

    op = fo.as_operator(w)
    x = jnp.asarray(x, jnp.float32)
    n_pts = x.shape[0]
    m = op.m
    if valid is None:
        valid = jnp.ones((n_pts,), jnp.float32)
    else:
        valid = jnp.asarray(valid, jnp.float32)

    pad = (-n_pts) % chunk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
        valid = jnp.concatenate([valid, jnp.zeros((pad,), valid.dtype)], axis=0)
    n_chunks = x.shape[0] // chunk
    xs = x.reshape(n_chunks, chunk, -1)
    vs = valid.reshape(n_chunks, chunk)

    def body(acc, inp):
        xc, vc = inp
        proj = jnp.asarray(op.apply(xc), jnp.float32)  # f32 phases (see sketch)
        qc, qs = qz.quantize_codes(proj, dither, bits, valid=vc[:, None])
        return (acc[0] + jnp.sum(qc, axis=0), acc[1] + jnp.sum(qs, axis=0)), None

    acc0 = jnp.zeros((m,), jnp.int32)
    if vary_axes:
        acc0 = compat.pvary(acc0, vary_axes)
    (qcos, qsin), _ = jax.lax.scan(body, (acc0, acc0), (xs, vs))
    return qcos, qsin


def sketch_complex(
    x: jax.Array, w: jax.Array, weights: jax.Array | None = None, chunk: int = 8192
) -> jax.Array:
    """Complex view of :func:`sketch` — matches the paper's ``Sk(Y, beta)``."""
    return to_complex(sketch(x, w, weights, chunk))


def atom(c: jax.Array, w: jax.Array) -> jax.Array:
    """``A delta_c`` for a single centroid ``c: (n,)`` -> stacked-real ``(2m,)``."""
    proj = jnp.asarray(fo.as_operator(w).apply(c), jnp.float32)  # (m,)
    return _stacked(jnp.cos(proj), jnp.sin(proj))


def atoms(cs: jax.Array, w: jax.Array) -> jax.Array:
    """``A delta_c`` for centroids ``cs: (S, n)`` -> ``(S, 2m)``."""
    proj = jnp.asarray(fo.as_operator(w).apply(cs), jnp.float32)  # (S, m)
    return _stacked(jnp.cos(proj), jnp.sin(proj))


def atom_norm(m: int) -> float:
    """||A delta_c||_2 — constant: every frequency sample has modulus 1."""
    return float(jnp.sqrt(m))


@jax.jit
def data_bounds(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-coordinate bounds ``l <= x_i <= u`` — same single pass as the sketch."""
    return jnp.min(x, axis=0), jnp.max(x, axis=0)
