"""Pallas TPU kernel: fused nearest-centroid assignment (Lloyd-Max hot loop).

Computes, for each point, ``argmin_k ||x_i - c_k||^2`` and the min distance in
one pass: the ``(bN, n)·(n, K)`` distance tile is produced on the MXU and
immediately reduced (argmin) on the VPU — the ``(N, K)`` distance matrix never
reaches HBM.  This is the assignment step of the paper's Lloyd-Max baseline;
on a v5e it turns the assignment from memory-bound (O(NK) bytes) into
compute-bound (O(N n K) flops at O(K) intensity).

The centroid set (K, n) is small and lives fully in VMEM for every tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(x_ref, c_ref, c2_ref, idx_ref, dist_ref):
    x = x_ref[...]  # (bN, n)
    c = c_ref[...]  # (K, n)
    # d2(i,k) = ||x_i||^2 - 2 x_i.c_k + ||c_k||^2 ; the x^2 term is constant
    # per-row and irrelevant to the argmin, but needed for the min distance.
    xc = jnp.dot(x, c.T, preferred_element_type=jnp.float32)  # (bN, K) on MXU
    d2 = c2_ref[...] - 2.0 * xc  # (bN, K)
    idx_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    x2 = jnp.sum(x * x, axis=1)
    dist_ref[...] = jnp.min(d2, axis=1) + x2


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def assign_argmin_kernel(
    x: jax.Array,
    c: jax.Array,
    block_n: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw kernel launch: inputs must be pre-padded/aligned (see ops.py).

    x: (N, n) f32, c: (K, n) f32 -> (assignment (N,) i32, min_dist (N,) f32)
    """
    n_pts, feat = x.shape
    k = c.shape[0]
    assert n_pts % block_n == 0
    c2 = jnp.sum(c * c, axis=1)[None, :]  # (1, K) precomputed once
    grid = (n_pts // block_n,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, feat), lambda i: (i, 0)),
            pl.BlockSpec((k, feat), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pts,), jnp.int32),
            jax.ShapeDtypeStruct((n_pts,), jnp.float32),
        ],
        interpret=interpret,
    )(x, c, c2)
