"""Pallas TPU kernel: fused random-Fourier-feature sketch.

The sketch hot-spot is  z = sum_i beta_i [cos(x_i W); sin(x_i W)] — a
``(N, n) @ (n, m)`` matmul followed by elementwise trig and a reduction over N.
The naive XLA path materialises the ``(N, m)`` projection in HBM (O(N m) bytes
moved three times: write proj, read for trig, read for reduce).  This kernel
keeps each projection *tile* in VMEM: the MXU computes a ``(bN, n)·(n, bM)``
tile, the VPU applies cos/sin in place, and the weighted batch-reduction
accumulates straight into the output block across the reduction grid axis.
Arithmetic intensity goes from O(1) to O(bN) — the op flips from memory-bound
to compute-bound (see EXPERIMENTS.md §Kernels for the roofline numbers).

Grid: ``(m_blocks, n_blocks_of_N)`` — the N axis is the innermost (fastest)
grid dimension so each output block stays resident in VMEM while the batch
streams through it (Pallas revisiting semantics).

TPU alignment: callers (ops.py) pad m to a multiple of the lane width (128),
N to the block size, and the feature dim n to a multiple of 8; f32 tiles are
(8, 128)-aligned.

``quantized_fourier_sketch_kernel`` is the QCKM (core/quantize.py) variant of
the same tiling: it adds the per-frequency dither to the projection tile,
quantizes cos/sin to integer codes on the VPU, and accumulates **int32** sums
— signs never leave VMEM unaccumulated, so the quantized encoder costs the
same HBM traffic as the float one while its partial state shrinks to integer
accumulators.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sketch_kernel(x_ref, w_ref, b_ref, cos_ref, sin_ref):
    """One (bN, bM) tile: proj = x @ w; accumulate beta-weighted cos/sin."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cos_ref[...] = jnp.zeros_like(cos_ref)
        sin_ref[...] = jnp.zeros_like(sin_ref)

    # MXU: (bN, n) @ (n, bM) in f32.
    proj = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    beta = b_ref[...]  # (bN, 1)
    # VPU: trig + weighted reduce over the batch tile, all in VMEM.
    cos_ref[...] += jnp.sum(jnp.cos(proj) * beta, axis=0, keepdims=True)
    sin_ref[...] += jnp.sum(jnp.sin(proj) * beta, axis=0, keepdims=True)


def _quantized_sketch_kernel(x_ref, w_ref, d_ref, v_ref, qcos_ref, qsin_ref, *, scale):
    """One (bN, bM) tile of the QCKM encoder: dithered phases -> int32 codes.

    ``scale`` is static: 1 -> the 1-bit sign code; S > 1 -> round(S * cos/sin).
    The whole tile stays in VMEM: MXU projection, VPU trig + rounding, and an
    integer batch-reduction straight into the int32 output block.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        qcos_ref[...] = jnp.zeros_like(qcos_ref)
        qsin_ref[...] = jnp.zeros_like(qsin_ref)

    theta = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + d_ref[...]
    )
    c, s = jnp.cos(theta), jnp.sin(theta)
    if scale == 1:
        qc = jnp.where(c >= 0, 1, -1)
        qs = jnp.where(s >= 0, 1, -1)
    else:
        qc = jnp.round(c * float(scale)).astype(jnp.int32)
        qs = jnp.round(s * float(scale)).astype(jnp.int32)
    v = v_ref[...].astype(jnp.int32)  # (bN, 1) 0/1 — zero out padding rows
    qcos_ref[...] += jnp.sum(qc.astype(jnp.int32) * v, axis=0, keepdims=True)
    qsin_ref[...] += jnp.sum(qs.astype(jnp.int32) * v, axis=0, keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_n", "block_m", "interpret")
)
def quantized_fourier_sketch_kernel(
    x: jax.Array,
    w: jax.Array,
    dither: jax.Array,
    valid: jax.Array,
    scale: int = 1,
    block_n: int = 1024,
    block_m: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw QCKM kernel launch: inputs must be pre-padded/aligned (see ops.py).

    x: (N, n) f32, w: (n, m) f32, dither: (1, m) f32, valid: (N, 1) f32
    -> (q_cos_sums (1, m), q_sin_sums (1, m)) int32
    """
    n_pts, feat = x.shape
    m = w.shape[1]
    assert n_pts % block_n == 0 and m % block_m == 0, (n_pts, m)
    grid = (m // block_m, n_pts // block_n)
    return pl.pallas_call(
        functools.partial(_quantized_sketch_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, feat), lambda i, j: (j, 0)),
            pl.BlockSpec((feat, block_m), lambda i, j: (0, i)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, i)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m), lambda i, j: (0, i)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, m), jnp.int32),
            jax.ShapeDtypeStruct((1, m), jnp.int32),
        ],
        interpret=interpret,
    )(x, w, dither, valid)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def fourier_sketch_kernel(
    x: jax.Array,
    w: jax.Array,
    beta: jax.Array,
    block_n: int = 1024,
    block_m: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw kernel launch: inputs must be pre-padded/aligned (see ops.py).

    x: (N, n) f32, w: (n, m) f32, beta: (N, 1) f32
    -> (cos_sums (1, m), sin_sums (1, m)) f32
    """
    n_pts, feat = x.shape
    m = w.shape[1]
    assert n_pts % block_n == 0 and m % block_m == 0, (n_pts, m)
    grid = (m // block_m, n_pts // block_n)
    return pl.pallas_call(
        _sketch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, feat), lambda i, j: (j, 0)),
            pl.BlockSpec((feat, block_m), lambda i, j: (0, i)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m), lambda i, j: (0, i)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, m), jnp.float32),
            jax.ShapeDtypeStruct((1, m), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, beta)
