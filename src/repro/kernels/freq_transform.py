"""Fast structured frequency transform — WHT building block + fused kernels.

The structured frequency operator (``core.freq_ops.structured``) replaces the
dense ``(n, m)`` frequency matrix with stacked HD-Rademacher blocks: each
block of ``d = 2^ceil(log2 n)`` frequencies is

    B = c·H D_2 · c·H D_1 · c·H D_0          (c = d^{-1/2}, D_i Rademacher)

— an *exactly orthogonal* direction matrix (product of orthogonal factors)
whose rows get adapted-radius radial rescaling.  Projecting a point costs
three Walsh–Hadamard transforms instead of a ``(n, m)`` matvec.

WHT implementation: the Sylvester Hadamard matrix factorises as a Kronecker
product ``H_d = H_a ⊗ H_b`` (``a·b = d``, ``a, b ~ sqrt(d)``), so the
transform is two small dense contractions — ``O(d·(a+b)) = O(d^1.5)`` flops
per vector instead of the dense ``O(d^2)``, and (unlike the ``O(d log d)``
butterfly, which is a chain of memory-bound shuffles) it maps onto the MXU /
BLAS.  ``fwht`` is the shared jnp implementation used by the XLA path and by
the Pallas kernel bodies below.

The fused Pallas kernels mirror ``kernels/fourier_sketch.py``: a grid over
(frequency blocks, batch tiles) where each tile's projection — here the
diag/WHT chain instead of an MXU matmul against a dense ``w`` tile — stays in
VMEM through the trig and the weighted batch reduction, so the ``(N, m)``
projection never touches HBM.  ``quantized_structured_sketch_kernel`` is the
QCKM twin (dithered phases -> int32 code sums).  Off-TPU both run in
``interpret=True`` mode (callers in ``kernels/ops.py`` handle dispatch and
padding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


@functools.lru_cache(maxsize=None)
def _hadamard_np(k: int) -> np.ndarray:
    """Sylvester Hadamard matrix H_k (entries ±1), k a power of two."""
    assert k >= 1 and (k & (k - 1)) == 0, k
    h = np.ones((1, 1), np.float32)
    while h.shape[0] < k:
        h = np.block([[h, h], [h, -h]])
    return h


def kron_factors(d: int) -> tuple[int, int]:
    """Balanced Kronecker split ``d = a * b`` with ``a, b`` powers of two."""
    assert d >= 1 and (d & (d - 1)) == 0, d
    p = d.bit_length() - 1
    a = 1 << ((p + 1) // 2)
    return a, d // a


def hadamard(k: int, dtype=jnp.float32) -> jax.Array:
    """H_k as a jnp array (for the Kronecker-factored transform)."""
    return jnp.asarray(_hadamard_np(k), dtype)


def _kron_wht_2d(v: jax.Array, ha: jax.Array, hb: jax.Array) -> jax.Array:
    """(rows, d) -> (H_a ⊗ H_b) applied to each row (d = a·b)."""
    rows = v.shape[0]
    a, b = ha.shape[0], hb.shape[0]
    y = jnp.dot(v.reshape(rows * a, b), hb, preferred_element_type=v.dtype)
    y = jnp.einsum("ij,rjk->rik", ha, y.reshape(rows, a, b))
    return y.reshape(rows, a * b)


def fwht(v: jax.Array) -> jax.Array:
    """Unnormalised Walsh–Hadamard transform along the last axis.

    ``v: (..., d)`` with ``d`` a power of two; returns ``v @ H_d`` (``H_d``
    symmetric, so left- and right-application coincide).  Two Kronecker
    contractions — the XLA reference path of the structured operator.
    """
    d = v.shape[-1]
    if d == 1:
        return v
    a, b = kron_factors(d)
    ha = hadamard(a, v.dtype)
    hb = hadamard(b, v.dtype)
    return _kron_wht_2d(v.reshape(-1, d), ha, hb).reshape(v.shape)


def hd_chain(xp: jax.Array, diags: jax.Array) -> jax.Array:
    """The three-stage normalised HD chain of one (or many) blocks.

    ``xp: (..., d)`` zero-padded inputs, ``diags: (..., 3, d)`` Rademacher
    signs (leading axes broadcast, e.g. ``(nblocks, 3, d)`` against
    ``(N, 1, d)``).  Returns ``c·H D_2 (c·H D_1 (c·H D_0 xp))`` with
    ``c = d^{-1/2}`` — unit-norm rows, the direction half of the operator.
    """
    d = xp.shape[-1]
    c = jnp.asarray(d, xp.dtype) ** -0.5
    v = xp
    for s in range(3):
        v = fwht(v * diags[..., s, :]) * c
    return v


# ---------------------------------------------------------------------------
# Fused Pallas kernels
# ---------------------------------------------------------------------------


def _hd_chain_tile(v, dg, ha, hb, d):
    """In-VMEM HD chain for one (rows, d) tile; dg: (1, 3, d)."""
    c = jnp.asarray(d, v.dtype) ** -0.5
    for s in range(3):
        v = _kron_wht_2d(v * dg[0, s, :][None, :], ha, hb) * c
    return v


def _structured_sketch_kernel(
    x_ref, dg_ref, r_ref, ha_ref, hb_ref, b_ref, cos_ref, sin_ref
):
    """One (bN, d) tile: WHT-chain projection; accumulate weighted cos/sin."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cos_ref[...] = jnp.zeros_like(cos_ref)
        sin_ref[...] = jnp.zeros_like(sin_ref)

    d = x_ref.shape[-1]
    v = _hd_chain_tile(x_ref[...], dg_ref[...], ha_ref[...], hb_ref[...], d)
    proj = v * r_ref[...]  # (bN, d) * (1, d) — radial rescaling
    beta = b_ref[...]  # (bN, 1)
    cos_ref[...] += jnp.sum(jnp.cos(proj) * beta, axis=0, keepdims=True)
    sin_ref[...] += jnp.sum(jnp.sin(proj) * beta, axis=0, keepdims=True)


def _quantized_structured_sketch_kernel(
    x_ref, dg_ref, r_ref, dth_ref, ha_ref, hb_ref, v_ref, qcos_ref, qsin_ref,
    *, scale,
):
    """QCKM twin: dithered WHT-chain phases -> int32 code sums in VMEM."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        qcos_ref[...] = jnp.zeros_like(qcos_ref)
        qsin_ref[...] = jnp.zeros_like(qsin_ref)

    d = x_ref.shape[-1]
    v = _hd_chain_tile(x_ref[...], dg_ref[...], ha_ref[...], hb_ref[...], d)
    theta = v * r_ref[...] + dth_ref[...]
    c, s = jnp.cos(theta), jnp.sin(theta)
    if scale == 1:
        qc = jnp.where(c >= 0, 1, -1)
        qs = jnp.where(s >= 0, 1, -1)
    else:
        qc = jnp.round(c * float(scale)).astype(jnp.int32)
        qs = jnp.round(s * float(scale)).astype(jnp.int32)
    valid = v_ref[...].astype(jnp.int32)  # (bN, 1) 0/1 — zero padding rows
    qcos_ref[...] += jnp.sum(qc.astype(jnp.int32) * valid, axis=0, keepdims=True)
    qsin_ref[...] += jnp.sum(qs.astype(jnp.int32) * valid, axis=0, keepdims=True)


def _specs(nblocks, d, block_n, a, b, extra_freq_rows=0):
    """Shared in_specs for (x, diags, radii[, dither], ha, hb, per-row)."""
    specs = [
        pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        pl.BlockSpec((1, 3, d), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, d), lambda i, j: (i, 0)),
    ]
    specs += [pl.BlockSpec((1, d), lambda i, j: (i, 0))] * extra_freq_rows
    specs += [
        pl.BlockSpec((a, a), lambda i, j: (0, 0)),
        pl.BlockSpec((b, b), lambda i, j: (0, 0)),
        pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
    ]
    return specs


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def structured_sketch_kernel(
    x: jax.Array,  # (N, d) f32, zero-padded in both axes
    diags: jax.Array,  # (nblocks, 3, d)
    radii: jax.Array,  # (nblocks, d)
    beta: jax.Array,  # (N, 1)
    block_n: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw fused launch: inputs must be pre-padded/aligned (see ops.py).

    -> ``(cos_sums, sin_sums)`` of shape ``(nblocks, d)`` (flatten + slice to
    ``m`` in the caller).  The frequency-block width is ``d`` — the WHT needs
    the whole block resident, so there is no ``block_m`` knob here.
    """
    n_pts, d = x.shape
    nblocks = diags.shape[0]
    assert n_pts % block_n == 0, (n_pts, block_n)
    a, b = kron_factors(d)
    grid = (nblocks, n_pts // block_n)
    return pl.pallas_call(
        _structured_sketch_kernel,
        grid=grid,
        in_specs=_specs(nblocks, d, block_n, a, b),
        out_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, d), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, d), jnp.float32),
        ],
        interpret=interpret,
    )(x, diags, radii, hadamard(a), hadamard(b), beta)


@functools.partial(jax.jit, static_argnames=("scale", "block_n", "interpret"))
def quantized_structured_sketch_kernel(
    x: jax.Array,  # (N, d)
    diags: jax.Array,  # (nblocks, 3, d)
    radii: jax.Array,  # (nblocks, d)
    dither: jax.Array,  # (nblocks, d)
    valid: jax.Array,  # (N, 1)
    scale: int = 1,
    block_n: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw fused QCKM launch -> int32 ``(qcos, qsin)`` of shape (nblocks, d)."""
    n_pts, d = x.shape
    nblocks = diags.shape[0]
    assert n_pts % block_n == 0, (n_pts, block_n)
    a, b = kron_factors(d)
    grid = (nblocks, n_pts // block_n)
    return pl.pallas_call(
        functools.partial(_quantized_structured_sketch_kernel, scale=scale),
        grid=grid,
        in_specs=_specs(nblocks, d, block_n, a, b, extra_freq_rows=1),
        out_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, d), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, d), jnp.int32),
        ],
        interpret=interpret,
    )(x, diags, radii, dither, hadamard(a), hadamard(b), valid)
