"""Pallas TPU kernel: fused sketch-and-shift score/shift step.

The sketch-and-shift decoder (``core.decoders.sketch_shift``) ascends the
sketched density surrogate

    f(c)      = (1/m) sum_j [ cos(w_j^T c) z1_j - sin(w_j^T c) z2_j ]
    grad f(c) = (1/m) sum_j w_j [ -sin(w_j^T c) z1_j - cos(w_j^T c) z2_j ]

for a block of P candidate centroids per iteration (``z = [z1, z2]`` is the
stacked-real sketch).  The hot spot is the same shape as the sketch itself —
a ``(P, n) @ (n, m)`` projection, elementwise trig, and a reduction over m —
so it gets the same treatment: the projection tile stays in VMEM, the MXU
computes the candidate x frequency tile, the VPU applies trig and combines
with the sketch entries in place, and a second MXU pass contracts the
combined tile against ``W^T`` for the gradient.  The naive XLA path
materialises the ``(P, m)`` trig matrices in HBM each of the T mean-shift
iterations; here only candidates, frequencies, and the (P, n+1) outputs move.

Grid: ``(p_blocks, m_blocks)`` — the m (frequency) axis is the innermost grid
dimension so the ``(bP, 1)`` density and ``(bP, n)`` gradient output blocks
stay resident in VMEM while the frequencies stream through them (Pallas
revisiting semantics).

TPU alignment: callers (ops.py) pad P to the block size, m to a multiple of
the lane width (128) with zero frequency columns AND zero sketch entries
(zero-padded frequencies contribute ``cos(0)*z1_pad = 0`` to f and a zero
column to the gradient contraction), and n to a multiple of 8 with zero
features.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shift_kernel(c_ref, w_ref, z1_ref, z2_ref, f_ref, g_ref):
    """One (bP, bM) tile: proj = c @ w; accumulate density + gradient sums."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        f_ref[...] = jnp.zeros_like(f_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    # MXU: (bP, n) @ (n, bM) in f32.
    proj = jnp.dot(c_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z1 = z1_ref[...]  # (1, bM)
    z2 = z2_ref[...]
    cosp = jnp.cos(proj)
    sinp = jnp.sin(proj)
    # VPU: combine trig with the sketch entries, reduce over the m tile.
    f_ref[...] += jnp.sum(cosp * z1 - sinp * z2, axis=1, keepdims=True)
    # MXU: gradient contraction of the combined tile against W^T.
    t = -sinp * z1 - cosp * z2  # (bP, bM)
    g_ref[...] += jnp.dot(
        t, w_ref[...].T, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("block_p", "block_m", "interpret")
)
def sketch_shift_kernel(
    c: jax.Array,
    w: jax.Array,
    z1: jax.Array,
    z2: jax.Array,
    block_p: int = 256,
    block_m: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw kernel launch: inputs must be pre-padded/aligned (see ops.py).

    c: (P, n) f32, w: (n, m) f32, z1/z2: (1, m) f32
    -> (density sums (P, 1), gradient sums (P, n)) f32 — unnormalised (no 1/m).
    """
    p_cand, feat = c.shape
    m = w.shape[1]
    assert p_cand % block_p == 0 and m % block_m == 0, (p_cand, m)
    grid = (p_cand // block_p, m // block_m)
    return pl.pallas_call(
        _shift_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, feat), lambda i, j: (i, 0)),
            pl.BlockSpec((feat, block_m), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_p, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_p, feat), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p_cand, 1), jnp.float32),
            jax.ShapeDtypeStruct((p_cand, feat), jnp.float32),
        ],
        interpret=interpret,
    )(c, w, z1, z2)
