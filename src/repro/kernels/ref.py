"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fourier_sketch_ref(
    x: jax.Array, w: jax.Array, beta: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(cos_sums (m,), sin_sums (m,)) — unchunked, unfused reference."""
    proj = x.astype(jnp.float32) @ w.astype(jnp.float32)  # (N, m)
    b = beta.reshape(-1).astype(jnp.float32)
    return b @ jnp.cos(proj), b @ jnp.sin(proj)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, rep: int = 1,
    causal: bool = True, window: int = 0,
) -> jax.Array:
    """Plain softmax attention over flattened heads (the kernel's oracle).

    q: (BH, S_q, hd); k/v: (BKV, S_kv, hd); q row h attends k/v row h//rep.
    """
    bh, s_q, hd = q.shape
    kk = jnp.repeat(k, rep, axis=0)
    vv = jnp.repeat(v, rep, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / jnp.sqrt(hd)
    qpos = jnp.arange(s_q)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s_q, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def sketch_shift_scores_ref(
    c: jax.Array, w: jax.Array, z: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(density (P,), gradient (P, n)) of the sketched-density surrogate.

    Independent complex-arithmetic formulation (the kernel works in stacked
    reals): with ``Sk_j = z1_j + i z2_j`` the surrogate is
    ``f(c) = (1/m) sum_j Re(e^{i w_j^T c} Sk_j)`` and
    ``grad f(c) = -(1/m) sum_j w_j Im(e^{i w_j^T c} Sk_j)``.
    """
    m = w.shape[1]
    skc = jax.lax.complex(z[:m].astype(jnp.float32), z[m:].astype(jnp.float32))
    e = jnp.exp(1j * (c.astype(jnp.float32) @ w.astype(jnp.float32)))  # (P, m)
    val = e * skc[None, :]
    f = jnp.mean(jnp.real(val), axis=1)
    g = -(jnp.imag(val) @ w.T) / m
    return f, g


def structured_project_ref(x: jax.Array, diags, radii) -> jax.Array:
    """Dense-matrix oracle of the structured frequency transform.

    Builds the Sylvester Hadamard matrix *explicitly* (numpy recursion — an
    implementation independent of the Kronecker-factored ``fwht``) and
    applies the HD chain as plain matmuls:

        proj = (x_pad D_0 (H/sqrt(d)) D_1 (H/sqrt(d)) D_2 (H/sqrt(d))) * radii

    ``x: (N, n)``; ``diags: (nblocks, 3, d)``; ``radii: (nblocks, d)``.
    Returns the ``(N, nblocks*d)`` projection (caller slices to m).
    """
    import numpy as np

    nblocks, _, d = diags.shape
    h = np.ones((1, 1), np.float64)
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    hn = jnp.asarray(h / np.sqrt(d), jnp.float32)
    xp = jnp.pad(
        x.astype(jnp.float32), ((0, 0), (0, d - x.shape[1]))
    )
    outs = []
    for bidx in range(nblocks):
        v = xp
        for s in range(3):
            v = (v * diags[bidx, s][None, :]) @ hn
        outs.append(v * radii[bidx][None, :])
    return jnp.concatenate(outs, axis=-1)


def assign_argmin_ref(x: jax.Array, c: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(assignment (N,) i32, min squared distance (N,) f32) — full matrix."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    d2 = (
        jnp.sum(x * x, axis=1, keepdims=True)
        - 2.0 * x @ c.T
        + jnp.sum(c * c, axis=1)[None, :]
    )
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)
