"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fourier_sketch_ref(
    x: jax.Array, w: jax.Array, beta: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(cos_sums (m,), sin_sums (m,)) — unchunked, unfused reference."""
    proj = x.astype(jnp.float32) @ w.astype(jnp.float32)  # (N, m)
    b = beta.reshape(-1).astype(jnp.float32)
    return b @ jnp.cos(proj), b @ jnp.sin(proj)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, rep: int = 1,
    causal: bool = True, window: int = 0,
) -> jax.Array:
    """Plain softmax attention over flattened heads (the kernel's oracle).

    q: (BH, S_q, hd); k/v: (BKV, S_kv, hd); q row h attends k/v row h//rep.
    """
    bh, s_q, hd = q.shape
    kk = jnp.repeat(k, rep, axis=0)
    vv = jnp.repeat(v, rep, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / jnp.sqrt(hd)
    qpos = jnp.arange(s_q)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s_q, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def sketch_shift_scores_ref(
    c: jax.Array, w: jax.Array, z: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(density (P,), gradient (P, n)) of the sketched-density surrogate.

    Independent complex-arithmetic formulation (the kernel works in stacked
    reals): with ``Sk_j = z1_j + i z2_j`` the surrogate is
    ``f(c) = (1/m) sum_j Re(e^{i w_j^T c} Sk_j)`` and
    ``grad f(c) = -(1/m) sum_j w_j Im(e^{i w_j^T c} Sk_j)``.
    """
    m = w.shape[1]
    skc = jax.lax.complex(z[:m].astype(jnp.float32), z[m:].astype(jnp.float32))
    e = jnp.exp(1j * (c.astype(jnp.float32) @ w.astype(jnp.float32)))  # (P, m)
    val = e * skc[None, :]
    f = jnp.mean(jnp.real(val), axis=1)
    g = -(jnp.imag(val) @ w.T) / m
    return f, g


def structured_project_ref(x: jax.Array, diags, radii) -> jax.Array:
    """Dense-matrix oracle of the structured frequency transform.

    Builds the Sylvester Hadamard matrix *explicitly* (numpy recursion — an
    implementation independent of the Kronecker-factored ``fwht``) and
    applies the HD chain as plain matmuls:

        proj = (x_pad D_0 (H/sqrt(d)) D_1 (H/sqrt(d)) D_2 (H/sqrt(d))) * radii

    ``x: (N, n)``; ``diags: (nblocks, 3, d)``; ``radii: (nblocks, d)``.
    Returns the ``(N, nblocks*d)`` projection (caller slices to m).
    """
    import numpy as np

    nblocks, _, d = diags.shape
    h = np.ones((1, 1), np.float64)
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    hn = jnp.asarray(h / np.sqrt(d), jnp.float32)
    xp = jnp.pad(
        x.astype(jnp.float32), ((0, 0), (0, d - x.shape[1]))
    )
    outs = []
    for bidx in range(nblocks):
        v = xp
        for s in range(3):
            v = (v * diags[bidx, s][None, :]) @ hn
        outs.append(v * radii[bidx][None, :])
    return jnp.concatenate(outs, axis=-1)


def amp_denoise_ref(
    r: jax.Array, q: jax.Array, lower: jax.Array, upper: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Truncated-Gaussian posterior moments — the ``ops.amp_denoise`` oracle.

    Input-channel denoiser of the CL-AMP decoder: for each pseudo-data entry
    ``r`` with pseudo-variance ``q``, the posterior of a coordinate with a
    uniform box prior on ``[lower, upper]`` is ``N(r, q)`` truncated to the
    box.  Returns its (mean, variance) via the standard normal-CDF formulas
    (``jax.scipy.special.ndtr`` — implementation-independent of the erf-based
    kernel).  Edge cases mirrored exactly by kernel and XLA paths: infinite
    box edges contribute zero boundary terms, and when the Gaussian mass in
    the box underflows (``Z < 1e-12``, pseudo-data far outside the box) the
    posterior collapses to the nearest edge with a small residual variance.

    r: (K, n); q: scalar; lower/upper: (n,).  -> ((K, n) mean, (K, n) var).
    """
    from jax.scipy.special import ndtr

    r = r.astype(jnp.float32)
    q = jnp.maximum(jnp.asarray(q, jnp.float32), 1e-20)
    lo = jnp.broadcast_to(lower.astype(jnp.float32), r.shape)
    hi = jnp.broadcast_to(upper.astype(jnp.float32), r.shape)
    sig = jnp.sqrt(q)
    a = (lo - r) / sig
    b = (hi - r) / sig
    phi = lambda t: jnp.exp(-0.5 * t * t) / jnp.sqrt(2.0 * jnp.pi)  # noqa: E731
    pa, pb = phi(a), phi(b)
    bound = lambda t, pt: jnp.where(jnp.isfinite(t), t * pt, 0.0)  # noqa: E731
    # Phi(b) - Phi(a), tail-stable: evaluated through the CDF of whichever
    # tail the interval sits in (Phi(b) - Phi(a) == Phi(-a) - Phi(-b)), so
    # the mass survives in float32 far from the mean instead of rounding to
    # 1 - 1 = 0.
    z_mass = jnp.where(a + b > 0, ndtr(-a) - ndtr(-b), ndtr(b) - ndtr(a))
    z_mass = jnp.maximum(z_mass, 1e-30)
    inside = z_mass > 1e-12
    mean = r + sig * (pa - pb) / z_mass
    frac = (pa - pb) / z_mass
    var = q * (1.0 + (bound(a, pa) - bound(b, pb)) / z_mass - frac * frac)
    mean = jnp.where(inside, mean, jnp.clip(r, lo, hi))
    var = jnp.where(inside, var, q * 1e-6)
    return jnp.clip(mean, lo, hi), jnp.clip(var, q * 1e-12, q)


def assign_argmin_ref(x: jax.Array, c: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(assignment (N,) i32, min squared distance (N,) f32) — full matrix."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    d2 = (
        jnp.sum(x * x, axis=1, keepdims=True)
        - 2.0 * x @ c.T
        + jnp.sum(c * c, axis=1)[None, :]
    )
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)
