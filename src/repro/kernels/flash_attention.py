"""Pallas TPU kernel: flash attention (forward), online softmax.

WHY (EXPERIMENTS.md §Perf): every train/prefill cell in the roofline table is
memory-bound on attention score traffic — the (bq, S) QK^T blocks and the
probs make three HBM round-trips per layer in the XLA path.  This kernel
keeps the entire softmax pipeline in VMEM: HBM traffic collapses to
Q + K + V + O (+ the (bq,) online statistics), independent of S^2.

Mapping (one grid step = one (batch*head, q-block)):
- grid = (B*H, S_q / block_q)
- q tile   (block_q, hd)   VMEM
- k/v tile (S_kv, hd)      VMEM, consumed in block_k chunks by an inner
  fori_loop (online softmax) — GQA's h -> h // rep head mapping happens in
  the BlockSpec index_map, so the kernel body is head-agnostic
- accumulators: o (block_q, hd) f32, running max m and sum l (block_q,) f32 —
  the standard online-softmax recurrence (FlashAttention).
- causal + sliding-window masking enters as a position mask computed from
  absolute positions; fully-masked kv chunks still execute (static trip
  count) but contribute exp(-inf) = 0.

The backward pass is intentionally NOT implemented: training integration
needs the dO recomputation kernel (future work); serving (prefill)
integration goes through kernels/ops.py.  The forward emits the LSE so a
backward can be added without re-running the forward.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_k: int, s_kv: int,
    causal: bool, window: int, scale: float,
):
    _, bq, hd = q_ref.shape
    q_blk_idx = pl.program_id(1)
    q0 = q_blk_idx * bq  # absolute position of the first query in this tile
    q = q_ref[0].astype(jnp.float32) * scale

    def body(i, carry):
        o, m, l = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], i * block_k, block_k, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], i * block_k, block_k, 0)
        s = q @ k.astype(jnp.float32).T  # (bq, bk) on the MXU
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
        kpos = i * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (bq, block_k), 1
        )
        mask = jnp.ones((bq, block_k), bool)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        # online softmax update
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        o_new = o * alpha[:, None] + p @ v.astype(jnp.float32)
        return o_new, m_new, l_new

    o0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    o, m, l = jax.lax.fori_loop(0, s_kv // block_k, body, (o0, m0, l0))
    l_safe = jnp.maximum(l, 1e-30)
    o_ref[0, ...] = (o / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[0, ...] = (m + jnp.log(l_safe)).astype(lse_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("rep", "causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_kernel(
    q: jax.Array,  # (B*H, S_q, hd) — heads pre-flattened
    k: jax.Array,  # (B*KV, S_kv, hd)
    v: jax.Array,
    rep: int = 1,  # GQA replication: q row h reads k/v row h // rep
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
):
    """Raw launch: shapes must be pre-padded to the block sizes (see ops.py)."""
    bh, s_q, hd = q.shape
    s_kv = k.shape[1]
    assert s_q % block_q == 0 and s_kv % block_k == 0, (s_q, s_kv)
    grid = (bh, s_q // block_q)
    scale = 1.0 / (hd**0.5)
    kernel = functools.partial(
        _flash_kernel,
        block_k=block_k, s_kv=s_kv, causal=causal, window=window, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, s_kv, hd), lambda h, i: (h // rep, 0, 0)),
            pl.BlockSpec((1, s_kv, hd), lambda h, i: (h // rep, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, block_q), lambda h, i: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s_q, hd), q.dtype),
            jax.ShapeDtypeStruct((bh, s_q), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
