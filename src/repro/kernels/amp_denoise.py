"""Pallas TPU kernel: fused truncated-Gaussian posterior denoiser (CL-AMP).

The input channel of the CL-AMP decoder (``core.decoders.amp``) updates all K
centroid estimates at once: each pseudo-data entry ``r_kl`` with pseudo
-variance ``q`` is combined with the uniform box prior ``[lower_l, upper_l]``,
giving the truncated-normal posterior whose mean/variance drive the next GAMP
iteration.  The whole update is elementwise over the ``(K, n)`` estimate
matrix, so one VPU pass computes both moments in place — the unfused XLA path
materialises the five intermediate ``(K, n)`` arrays (a, b, Z, and the two
pdf terms) in HBM between elementwise ops; here only ``r`` and the two output
moments move.

Numerics (shared *exactly* with ``ops.amp_denoise``'s XLA path and mirrored
by the ``kernels.ref.amp_denoise_ref`` oracle):

    a = (lo - r)/sig,  b = (hi - r)/sig,       sig = sqrt(q)
    Z = Phi(b) - Phi(a)                        (Phi via erf)
    mean = r + sig (phi(a) - phi(b)) / Z
    var  = q [1 + (a phi(a) - b phi(b))/Z - ((phi(a) - phi(b))/Z)^2]

with the hardened edge cases: infinite box edges contribute zero boundary
terms (``a * phi(a)`` would be ``inf * 0``), and ``Z < 1e-12`` (pseudo-data
far outside the box — the regime a diverging AMP iterate visits) collapses
the posterior to the nearest box edge with a small residual variance instead
of 0/0 NaNs.

Grid: ``(k_blocks,)`` over rows of the estimate matrix; every block is
``(block_k, n)`` with the bounds/variance broadcast as ``(1, n)`` rows.  TPU
alignment: callers (ops.py) pad K to the block size and n to the lane width
(128) with benign values (r=0, lo=-1, hi=1, q=1); padded cells are sliced off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INV_SQRT2 = 0.7071067811865476
_INV_SQRT2PI = 0.3989422804014327


def _denoise_kernel(r_ref, q_ref, lo_ref, hi_ref, mean_ref, var_ref):
    """One (bK, n) tile: both truncated-normal moments in a single VPU pass."""
    r = r_ref[...]
    q = q_ref[...]  # (1, n), already clamped positive by the wrapper
    lo = lo_ref[...]
    hi = hi_ref[...]
    sig = jnp.sqrt(q)
    a = (lo - r) / sig
    b = (hi - r) / sig
    pa = _INV_SQRT2PI * jnp.exp(-0.5 * a * a)
    pb = _INV_SQRT2PI * jnp.exp(-0.5 * b * b)
    # Phi(b) - Phi(a), tail-stable: erfc keeps relative precision deep in
    # either tail where erf rounds to +-1 (Phi(b) - Phi(a) == Phi(-a) -
    # Phi(-b); the where picks the branch whose erfc arguments are positive).
    z_mass = 0.5 * jnp.where(
        a + b > 0,
        jax.lax.erfc(a * _INV_SQRT2) - jax.lax.erfc(b * _INV_SQRT2),
        jax.lax.erfc(-b * _INV_SQRT2) - jax.lax.erfc(-a * _INV_SQRT2),
    )
    z_mass = jnp.maximum(z_mass, 1e-30)
    inside = z_mass > 1e-12
    # Infinite box edges: the boundary terms t*phi(t) vanish (inf * 0 guard).
    apa = jnp.where(jnp.isfinite(a), a * pa, 0.0)
    bpb = jnp.where(jnp.isfinite(b), b * pb, 0.0)
    frac = (pa - pb) / z_mass
    mean = r + sig * frac
    var = q * (1.0 + (apa - bpb) / z_mass - frac * frac)
    mean = jnp.where(inside, mean, jnp.clip(r, lo, hi))
    var = jnp.where(inside, var, q * 1e-6)
    mean_ref[...] = jnp.clip(mean, lo, hi)
    var_ref[...] = jnp.clip(var, q * 1e-12, q)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def amp_denoise_kernel(
    r: jax.Array,
    q: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    block_k: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw kernel launch: inputs must be pre-padded/aligned (see ops.py).

    r: (K, n) f32; q/lo/hi: (1, n) f32 -> (mean (K, n), var (K, n)) f32.
    """
    k_est, feat = r.shape
    assert k_est % block_k == 0, (k_est, block_k)
    grid = (k_est // block_k,)
    return pl.pallas_call(
        _denoise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k, feat), lambda i: (i, 0)),
            pl.BlockSpec((1, feat), lambda i: (0, 0)),
            pl.BlockSpec((1, feat), lambda i: (0, 0)),
            pl.BlockSpec((1, feat), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_k, feat), lambda i: (i, 0)),
            pl.BlockSpec((block_k, feat), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_est, feat), jnp.float32),
            jax.ShapeDtypeStruct((k_est, feat), jnp.float32),
        ],
        interpret=interpret,
    )(r, q, lo, hi)
