"""Jit'd public wrappers around the Pallas kernels (see ``docs/api.md``).

Entry points
------------
- ``fourier_sketch_sums`` / ``fourier_sketch`` — fused float RFF sketch, the
  ``pallas`` backend of ``core.engine.SketchEngine``;
- ``quantized_fourier_sketch_sums`` — fused QCKM encoder: dithered phases ->
  integer sign / b-bit codes accumulated in int32 (``core.quantize``);
- ``sketch_shift_scores`` — density + gradient of the sketched characteristic
  function, the inner score/shift step of the ``sketch_shift`` decoder
  (``core.decoders.sketch_shift``); ``impl="xla" | "pallas"`` mirrors the
  sketch side's backend treatment;
- ``amp_denoise`` — truncated-Gaussian posterior moments over K centroid
  estimates, the input-channel denoiser of the ``amp`` decoder
  (``core.decoders.amp``); same ``impl="xla" | "pallas"`` dispatch;
- ``flash_attention`` — fused attention forward for the serving path;
- ``assign_argmin`` — fused nearest-centroid assignment.

Handles padding/alignment (lane width 128, sublane 8, block divisibility) and
backend dispatch: on TPU the compiled kernels run natively; on CPU (this
container) they run in ``interpret=True`` mode, which executes the kernel body
in Python for correctness validation.  Padded regions are constructed so they
cannot perturb results (zero weights / zero valid-masks, +inf distances), and
outputs are sliced back to logical shapes.

Frequency operators: the sketch-side ops take ``w`` as a
``core.freq_ops.FrequencyOperator``; raw ``(n, m)`` arrays are a
``TypeError`` since the one-release deprecation window closed (wrap with
``freq_ops.as_operator``).  Dispatch is per family: ``"dense"`` runs the original fused
matmul+trig kernels (``kernels/fourier_sketch.py``, bitwise-unchanged),
``"structured"`` runs the fused WHT-chain kernels
(``kernels/freq_transform.py``), and any user-registered operator falls back
to the chunked XLA path through ``op.apply`` (correct everywhere, unfused).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import assign_argmin as _assign
from repro.kernels import fourier_sketch as _sketch


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(a: jax.Array, axis: int, mult: int, value: float = 0.0) -> jax.Array:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _as_op(w):
    from repro.core import freq_ops

    if not isinstance(w, freq_ops.FrequencyOperator):
        raise TypeError(
            "kernels.ops sketch-side entry points require a "
            "core.freq_ops.FrequencyOperator; the raw (n, m) array path was "
            "removed after its one-release deprecation window (PR 5) — wrap "
            "with freq_ops.as_operator(w) or build one via "
            "freq_ops.make_operator(...)"
        )
    return w


def _structured_pad(x, op, block_n):
    """Pad a batch for the structured kernels: N to block, n to the WHT width
    (zero feature columns shift no phases — the operator itself zero-pads)."""
    x = _pad_to(jnp.asarray(x, jnp.float32), 0, block_n)
    return _pad_to(x, 1, op.d)[:, : op.d]


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def fourier_sketch_sums(
    x: jax.Array,
    w: jax.Array,
    beta: jax.Array,
    block_n: int = 1024,
    block_m: int = 512,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Raw fused sums ``(sum b cos(xW) (m,), sum b sin(xW) (m,))``.

    The mergeable-state entrypoint used by ``core.engine`` (pallas backend):
    no ``1/N`` normalisation, no stacked-real packaging.  Handles all TPU
    padding/alignment; off-TPU the kernels run in interpret mode.  ``w`` is a
    frequency operator (or raw matrix): dense -> the fused matmul kernel,
    structured -> the fused WHT-chain kernel, other registered families ->
    the chunked XLA fallback through ``op.apply``.
    """
    from repro.core import freq_ops
    from repro.core import sketch as core_sk

    if interpret is None:
        interpret = _on_cpu()
    op = _as_op(w)
    n_pts = x.shape[0]
    m = op.m
    x = jnp.asarray(x, jnp.float32)
    beta = jnp.asarray(beta, jnp.float32).reshape(-1, 1)
    block_n = min(block_n, max(8, 1 << (n_pts - 1).bit_length()))

    if isinstance(op, freq_ops.StructuredOperator):
        from repro.kernels import freq_transform as _ft

        xp = _structured_pad(x, op, block_n)
        beta_p = _pad_to(beta, 0, block_n)  # zero-weight rows are no-ops
        cos_s, sin_s = _ft.structured_sketch_kernel(
            xp, jnp.asarray(op.diags, jnp.float32),
            jnp.asarray(op.radii, jnp.float32), beta_p, block_n=block_n,
            interpret=interpret,
        )
        return cos_s.reshape(-1)[:m], sin_s.reshape(-1)[:m]
    if not isinstance(op, freq_ops.DenseOperator):
        # User-registered operator family: no fused kernel — chunked XLA path
        # through op.apply (same mergeable-sums contract).
        part = core_sk.sketch(
            x, op, weights=beta.reshape(-1), chunk=min(8192, max(n_pts, 1))
        )
        return part[:m], -part[m:]

    w = jnp.asarray(op.w, jnp.float32)
    block_m = min(block_m, max(128, 1 << (m - 1).bit_length()))
    # Pad: N to block (zero weight rows are no-ops), n to sublane multiple
    # (zero feature columns shift no phases), m to block (sliced off below).
    x = _pad_to(_pad_to(x, 0, block_n), 1, 8)
    beta = _pad_to(beta, 0, block_n)
    w = _pad_to(_pad_to(w, 0, 8), 1, block_m)
    cos_s, sin_s = _sketch.fourier_sketch_kernel(
        x, w, beta, block_n=block_n, block_m=block_m, interpret=interpret
    )
    return cos_s[0, :m], sin_s[0, :m]


@functools.partial(
    jax.jit, static_argnames=("bits", "block_n", "block_m", "interpret")
)
def quantized_fourier_sketch_sums(
    x: jax.Array,
    w: jax.Array,
    dither: jax.Array,
    valid: jax.Array | None = None,
    bits: int = 1,
    block_n: int = 1024,
    block_m: int = 512,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused QCKM encoder: int32 ``(q_cos_sums (m,), q_sin_sums (m,))``.

    The quantized mergeable-state entrypoint used by ``core.engine`` (pallas
    backend with a ``quantizer``): per point, quantize the dithered phase
    ``w^T x + xi`` to a 1-bit sign (``bits=1``) or ``b``-bit uniform code and
    accumulate integer sums — the XLA twin is ``core.sketch.sketch_quantized``.
    Padding rows carry ``valid=0`` so they contribute zero codes.
    """
    from repro.core import freq_ops
    from repro.core import quantize as qz
    from repro.core import sketch as core_sk
    from repro.kernels import fourier_sketch as _qsk

    if interpret is None:
        interpret = _on_cpu()
    op = _as_op(w)
    n_pts = x.shape[0]
    m = op.m
    x = jnp.asarray(x, jnp.float32)
    if valid is None:
        valid = jnp.ones((n_pts,), jnp.float32)
    valid = jnp.asarray(valid, jnp.float32).reshape(-1, 1)
    block_n = min(block_n, max(8, 1 << (n_pts - 1).bit_length()))

    if isinstance(op, freq_ops.StructuredOperator):
        from repro.kernels import freq_transform as _ft

        xp = _structured_pad(x, op, block_n)
        valid_p = _pad_to(valid, 0, block_n)  # valid=0 rows -> zero codes
        # Dither padded to the block tail with zeros (tail codes sliced off).
        dth = _pad_to(
            jnp.asarray(dither, jnp.float32).reshape(1, -1), 1,
            op.nblocks * op.d,
        ).reshape(op.nblocks, op.d)
        qcos, qsin = _ft.quantized_structured_sketch_kernel(
            xp, jnp.asarray(op.diags, jnp.float32),
            jnp.asarray(op.radii, jnp.float32), dth, valid_p,
            scale=qz.quantization_scale(bits), block_n=block_n,
            interpret=interpret,
        )
        return qcos.reshape(-1)[:m], qsin.reshape(-1)[:m]
    if not isinstance(op, freq_ops.DenseOperator):
        return core_sk.sketch_quantized(
            x, op, jnp.asarray(dither, jnp.float32),
            valid=valid.reshape(-1), bits=bits,
            chunk=min(8192, max(n_pts, 1)),
        )

    w = jnp.asarray(op.w, jnp.float32)
    dither = jnp.asarray(dither, jnp.float32).reshape(1, -1)
    block_m = min(block_m, max(128, 1 << (m - 1).bit_length()))
    # Pad: N to block (valid=0 rows contribute zero codes), n to sublane
    # multiple (zero feature columns shift no phases), m to block (sliced off).
    x = _pad_to(_pad_to(x, 0, block_n), 1, 8)
    valid = _pad_to(valid, 0, block_n)
    w = _pad_to(_pad_to(w, 0, 8), 1, block_m)
    dither = _pad_to(dither, 1, block_m)
    qcos, qsin = _qsk.quantized_fourier_sketch_kernel(
        x,
        w,
        dither,
        valid,
        scale=qz.quantization_scale(bits),
        block_n=block_n,
        block_m=block_m,
        interpret=interpret,
    )
    return qcos[0, :m], qsin[0, :m]


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def fourier_sketch(
    x: jax.Array,
    w: jax.Array,
    beta: jax.Array | None = None,
    block_n: int = 1024,
    block_m: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused sketch -> stacked-real ``(2m,)``: [sum b cos(xW), -sum b sin(xW)].

    Drop-in replacement for ``core.sketch.sketch`` (same convention).  ``beta``
    defaults to uniform ``1/N``.
    """
    if beta is None:
        beta = jnp.full((x.shape[0],), 1.0 / x.shape[0], jnp.float32)
    cos_s, sin_s = fourier_sketch_sums(
        x, w, beta, block_n=block_n, block_m=block_m, interpret=interpret
    )
    return jnp.concatenate([cos_s, -sin_s])


@functools.partial(
    jax.jit, static_argnames=("impl", "block_p", "block_m", "interpret")
)
def sketch_shift_scores(
    c: jax.Array,
    w: jax.Array,
    z: jax.Array,
    impl: str = "xla",
    block_p: int = 256,
    block_m: int = 512,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Sketched-density score + gradient at candidate centroids ``c: (P, n)``.

    The inner step of the sketch-and-shift decoder: for the stacked-real
    sketch ``z = [z1, z2]`` (``(2m,)``) and frequencies ``w: (n, m)`` returns

        f(c)  = (1/m) Σ_j [cos(w_j·c) z1_j - sin(w_j·c) z2_j]     -> (P,)
        ∇f(c) = (1/m) Σ_j w_j [-sin(w_j·c) z1_j - cos(w_j·c) z2_j] -> (P, n)

    which is a kernel-density surrogate of the data distribution (``f(c) =
    Σ_l β_l κ(c - x_l)`` with κ the frequency distribution's characteristic
    kernel) — mean-shift iterations ascend it.  ``impl`` selects the same two
    treatments the sketch side gets: ``"xla"`` (plain fused jnp through the
    operator's ``apply``/``adjoint`` — a fast transform for the structured
    family; runs anywhere — the default) or ``"pallas"`` (the fused
    VMEM-resident TPU kernel ``kernels.sketch_shift``; interpret mode
    off-TPU; non-dense operators are materialised for this kernel, so prefer
    ``"xla"`` with the structured family).
    """
    if impl not in ("xla", "pallas"):
        raise ValueError(f"unknown sketch_shift impl {impl!r}")
    op = _as_op(w)
    c = jnp.asarray(c, jnp.float32)
    z = jnp.asarray(z, jnp.float32)
    m = op.m
    z1, z2 = z[:m], z[m:]
    if impl == "xla":
        proj = jnp.asarray(op.apply(c), jnp.float32)  # (P, m)
        cosp, sinp = jnp.cos(proj), jnp.sin(proj)
        f = (cosp @ z1 - sinp @ z2) / m
        g = jnp.asarray(
            op.adjoint((-sinp) * z1[None, :] - cosp * z2[None, :]), jnp.float32
        ) / m
        return f, g
    if interpret is None:
        interpret = _on_cpu()
    from repro.kernels import sketch_shift as _shift

    w = jnp.asarray(op.materialize(), jnp.float32)
    p_cand, feat = c.shape
    block_p = min(block_p, max(8, 1 << (p_cand - 1).bit_length()))
    block_m = min(block_m, max(128, 1 << (m - 1).bit_length()))
    # Pad: P to block (garbage rows sliced off), n to sublane multiple (zero
    # feature columns shift no phases and add zero gradient columns), m to
    # block with zero frequency columns AND zero sketch entries (cos(0)*0
    # contributes nothing to f; zero w columns contribute nothing to grad).
    c_p = _pad_to(_pad_to(c, 0, block_p), 1, 8)
    w_p = _pad_to(_pad_to(w, 0, 8), 1, block_m)
    z1_p = _pad_to(z1.reshape(1, -1), 1, block_m)
    z2_p = _pad_to(z2.reshape(1, -1), 1, block_m)
    f_sums, g_sums = _shift.sketch_shift_kernel(
        c_p, w_p, z1_p, z2_p, block_p=block_p, block_m=block_m,
        interpret=interpret,
    )
    return f_sums[:p_cand, 0] / m, g_sums[:p_cand, :feat] / m


@functools.partial(
    jax.jit, static_argnames=("impl", "block_k", "interpret")
)
def amp_denoise(
    r: jax.Array,
    q: jax.Array,
    lower: jax.Array,
    upper: jax.Array,
    impl: str = "xla",
    block_k: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Truncated-Gaussian posterior denoiser over K centroid estimates.

    The input channel of the CL-AMP decoder (``core.decoders.amp``): for the
    pseudo-data matrix ``r: (K, n)`` with scalar pseudo-variance ``q`` and the
    engine's box bounds ``lower/upper: (n,)``, returns the posterior
    ``(mean (K, n), variance (K, n))`` of each coordinate under a uniform box
    prior — the truncated-normal moments.  ``impl`` selects the same two
    treatments the other decoder ops get: ``"xla"`` (plain fused jnp; runs
    anywhere — the default) or ``"pallas"`` (the single-VPU-pass kernel
    ``kernels.amp_denoise``; interpret mode off-TPU).  Hardened edge cases
    (identical across impls and the ``ref.py`` oracle): infinite box edges
    contribute zero boundary terms, and vanishing in-box mass (pseudo-data
    far outside the box) collapses to the nearest edge instead of NaN.
    """
    if impl not in ("xla", "pallas"):
        raise ValueError(f"unknown amp_denoise impl {impl!r}")
    r = jnp.asarray(r, jnp.float32)
    k_est, feat = r.shape
    q = jnp.maximum(jnp.asarray(q, jnp.float32).reshape(()), 1e-20)
    lo = jnp.broadcast_to(jnp.asarray(lower, jnp.float32), (feat,))
    hi = jnp.broadcast_to(jnp.asarray(upper, jnp.float32), (feat,))
    if impl == "xla":
        sig = jnp.sqrt(q)
        a = (lo[None, :] - r) / sig
        b = (hi[None, :] - r) / sig
        inv_sqrt2pi = 0.3989422804014327
        pa = inv_sqrt2pi * jnp.exp(-0.5 * a * a)
        pb = inv_sqrt2pi * jnp.exp(-0.5 * b * b)
        # Tail-stable Phi(b) - Phi(a) via erfc (see kernels/amp_denoise.py).
        inv_sqrt2 = 0.7071067811865476
        z_mass = 0.5 * jnp.where(
            a + b > 0,
            jax.lax.erfc(a * inv_sqrt2) - jax.lax.erfc(b * inv_sqrt2),
            jax.lax.erfc(-b * inv_sqrt2) - jax.lax.erfc(-a * inv_sqrt2),
        )
        z_mass = jnp.maximum(z_mass, 1e-30)
        inside = z_mass > 1e-12
        apa = jnp.where(jnp.isfinite(a), a * pa, 0.0)
        bpb = jnp.where(jnp.isfinite(b), b * pb, 0.0)
        frac = (pa - pb) / z_mass
        mean = r + sig * frac
        var = q * (1.0 + (apa - bpb) / z_mass - frac * frac)
        mean = jnp.where(inside, mean, jnp.clip(r, lo[None, :], hi[None, :]))
        var = jnp.where(inside, var, q * 1e-6)
        return (
            jnp.clip(mean, lo[None, :], hi[None, :]),
            jnp.clip(var, q * 1e-12, q),
        )
    if interpret is None:
        interpret = _on_cpu()
    from repro.kernels import amp_denoise as _amp

    block_k = min(block_k, max(8, 1 << (k_est - 1).bit_length()))
    # Pad: K to block (garbage rows sliced off), n to the lane width with
    # benign cells (r=0 inside a [-1, 1] box at unit variance cannot produce
    # non-finite intermediates).
    r_p = _pad_to(_pad_to(r, 0, block_k), 1, 128)
    q_p = jnp.broadcast_to(q, (1, r_p.shape[1]))
    lo_p = _pad_to(lo.reshape(1, -1), 1, 128, value=-1.0)
    hi_p = _pad_to(hi.reshape(1, -1), 1, 128, value=1.0)
    mean, var = _amp.amp_denoise_kernel(
        r_p, q_p, lo_p, hi_p, block_k=block_k, interpret=interpret
    )
    return mean[:k_est, :feat], var[:k_est, :feat]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, S_q, H, hd)
    k: jax.Array,  # (B, S_kv, KV, hd)
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused flash attention (forward) — drop-in for the q-chunked XLA path
    of ``models.layers.attention_apply`` at serving/prefill time.

    HBM traffic: Q+K+V+O only (vs O(S^2) score blocks).  GQA handled via the
    kernel's head->kv index map.  Returns (B, S_q, H*hd).
    """
    from repro.kernels import flash_attention as _fa

    if interpret is None:
        interpret = _on_cpu()
    b, s_q, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s_q, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, v.shape[1], hd)
    block_q = min(block_q, max(8, 1 << (s_q - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (k.shape[1] - 1).bit_length()))
    pad_q = (-s_q) % block_q
    pad_k = (-k.shape[1]) % block_k
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded kv positions sit at the causal future: masked out for every
        # real query by the position mask.
        assert causal, "kv padding requires the causal mask"
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    o, _lse = _fa.flash_attention_kernel(
        qf, kf, vf, rep=rep, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    o = o[:, :s_q].reshape(b, h, s_q, hd).transpose(0, 2, 1, 3)
    return o.reshape(b, s_q, h * hd)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def assign_argmin(
    x: jax.Array,
    c: jax.Array,
    block_n: int = 1024,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused nearest-centroid assignment: (labels (N,) i32, min d^2 (N,) f32)."""
    if interpret is None:
        interpret = _on_cpu()
    n_pts = x.shape[0]
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    block_n = min(block_n, max(8, 1 << (n_pts - 1).bit_length()))
    # Pad features with zeros (adds the same constant to every distance: the
    # argmin is unchanged and the constant is zero since pads match), pad K
    # with +inf-distance phantom centroids, pad N to block.
    x = _pad_to(_pad_to(x, 0, block_n), 1, 8)
    c = _pad_to(c, 1, 8)
    k = c.shape[0]
    pad_k = (-k) % 8
    if pad_k:
        # Phantom centroids far away: never win the argmin.
        far = jnp.full((pad_k, c.shape[1]), 1e18, c.dtype)
        c = jnp.concatenate([c, far], axis=0)
    idx, dist = _assign.assign_argmin_kernel(x, c, block_n=block_n, interpret=interpret)
    return idx[:n_pts], dist[:n_pts]
